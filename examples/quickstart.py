"""Quickstart: build a reduced model from the assigned-architecture pool,
run a forward pass, a prefill->decode round, and one Pallas kernel.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3-8b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    from repro.configs import available_archs, get_config, get_smoke_config
    from repro.models import forward, grow_cache, init_params

    print("available architectures:", ", ".join(available_archs()))
    full = get_config(args.arch)
    print(f"\n{full.name}: {full.num_layers}L d_model={full.d_model} "
          f"{full.num_heads}H (kv={full.num_kv_heads}) d_ff={full.d_ff} "
          f"vocab={full.vocab_size}  ~{full.param_count()/1e9:.1f}B params "
          f"[{full.citation}]")

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.key(0), cfg)
    print(f"reduced variant for CPU: {cfg.num_layers}L "
          f"d_model={cfg.d_model} -> {cfg.param_count()/1e6:.1f}M params")

    # forward pass
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    logits, _ = jax.jit(lambda p, t: forward(p, cfg, {"tokens": t}))(
        params, toks if cfg.modality == "text" else toks)
    if cfg.modality == "text":
        print("forward:", logits.shape, "logits ok:",
              bool(jnp.all(jnp.isfinite(logits))))

        # prefill -> decode
        _, cache = forward(params, cfg, {"tokens": toks},
                           return_cache=True)
        cache = grow_cache(cfg, cache, 32)
        dec_logits, cache = forward(
            params, cfg, {"tokens": toks[:, -1:]}, cache=cache,
            cache_len=jnp.full((2,), 16, jnp.int32))
        print("decode step:", dec_logits.shape)

    # one Pallas kernel (interpret mode on CPU)
    from repro.kernels.flash_prefill import flash_prefill
    from repro.kernels.ref import flash_prefill_ref
    q = jnp.asarray(np.random.normal(size=(1, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(np.random.normal(size=(1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(np.random.normal(size=(1, 128, 2, 64)), jnp.float32)
    out = flash_prefill(q, k, v, causal=True, interpret=True)
    ref = flash_prefill_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"pallas flash_prefill vs oracle: max |err| = {err:.2e}")


if __name__ == "__main__":
    main()
