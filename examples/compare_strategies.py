"""Strategy comparison on the simulated 32x L20 cluster (a mini Fig. 8):
EcoServe (PaDG) vs vLLM / Sarathi (NoDG) vs DistServe / MoonCake (FuDG)
serving Llama-30B, under any arrival scenario (poisson / bursty / diurnal
/ ramp / trace replay).

    PYTHONPATH=src python examples/compare_strategies.py \
        [--rate 24] [--scenario bursty]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=24.0)
    ap.add_argument("--model", default="llama-30b")
    ap.add_argument("--workload", default="sharegpt",
                    choices=["alpaca", "sharegpt", "longbench"])
    ap.add_argument("--scenario", default="poisson",
                    choices=["poisson", "bursty", "diurnal", "ramp",
                             "replay"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.baselines import make_system
    from repro.configs import get_config
    from repro.core.slo import DATASET_SLOS
    from repro.simulator.cost_model import GPU_L20, InstanceCostModel
    from repro.simulator.metrics import run_once
    from repro.simulator.scenarios import make_scenario

    cost = InstanceCostModel(cfg=get_config(args.model), hw=GPU_L20, tp=4)
    slo = DATASET_SLOS[args.workload]
    scenario = make_scenario(args.scenario, args.workload, args.rate,
                             seed=args.seed)
    print(f"{args.model} x {args.workload} [{args.scenario}] @ "
          f"{args.rate} req/s, 8 instances TP=4 on L20+10GbE "
          f"(SLO: ttft={slo.ttft}s, tpot={slo.tpot*1e3:.0f}ms)\n")
    labels = {
        "ecoserve": "EcoServe (PaDG)",
        "ecoserve++": "EcoServe++ (beyond-paper)",
        "vllm": "vLLM (NoDG)",
        "sarathi": "Sarathi (NoDG+chunked)",
        "distserve": "DistServe (FuDG intra)",
        "mooncake": "MoonCake (FuDG inter)",
    }
    print(f"{'system':28}{'attainment':>11}{'ttft_p90':>10}{'tpot_p90':>10}")
    for name, label in labels.items():
        m = run_once(lambda: make_system(name, cost, 8, slo), scenario,
                     args.rate, slo, duration=60.0, seed=args.seed)
        print(f"{label:28}{m['attainment']:11.2f}"
              f"{m.get('ttft_p90', 0):10.2f}{m.get('tpot_p90', 0):10.3f}")


if __name__ == "__main__":
    main()
