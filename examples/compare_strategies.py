"""Strategy comparison on the simulated 32x L20 cluster (a mini Fig. 8):
EcoServe (PaDG) vs vLLM / Sarathi (NoDG) vs DistServe / MoonCake (FuDG)
serving Llama-30B on the ShareGPT workload.

    PYTHONPATH=src python examples/compare_strategies.py [--rate 24]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=24.0)
    ap.add_argument("--model", default="llama-30b")
    ap.add_argument("--workload", default="sharegpt",
                    choices=["alpaca", "sharegpt", "longbench"])
    args = ap.parse_args()

    from repro.baselines import (DistServeSystem, MoonCakeSystem,
                                 SarathiSystem, VLLMSystem)
    from repro.configs import get_config
    from repro.core.padg_system import EcoServeSystem
    from repro.core.slo import DATASET_SLOS
    from repro.simulator.cost_model import GPU_L20, InstanceCostModel
    from repro.simulator.metrics import run_once
    from repro.simulator.workload import WORKLOADS

    cost = InstanceCostModel(cfg=get_config(args.model), hw=GPU_L20, tp=4)
    slo = DATASET_SLOS[args.workload]
    profile = WORKLOADS[args.workload]
    systems = {
        "EcoServe (PaDG)": lambda: EcoServeSystem(cost, 8, slo),
        "EcoServe++ (beyond-paper)":
            lambda: EcoServeSystem(cost, 8, slo, plus_plus=True),
        "vLLM (NoDG)": lambda: VLLMSystem(cost, 8),
        "Sarathi (NoDG+chunked)": lambda: SarathiSystem(cost, 8),
        "DistServe (FuDG intra)":
            lambda: DistServeSystem(cost, 8, prefill_ratio=0.25),
        "MoonCake (FuDG inter)":
            lambda: MoonCakeSystem(cost, 8, prefill_ratio=0.25),
    }
    print(f"{args.model} x {args.workload} @ {args.rate} req/s, "
          f"8 instances TP=4 on L20+10GbE (SLO: ttft={slo.ttft}s, "
          f"tpot={slo.tpot*1e3:.0f}ms)\n")
    print(f"{'system':28}{'attainment':>11}{'ttft_p90':>10}{'tpot_p90':>10}")
    for name, fac in systems.items():
        m = run_once(fac, profile, args.rate, slo, duration=60.0)
        print(f"{name:28}{m['attainment']:11.2f}"
              f"{m.get('ttft_p90', 0):10.2f}{m.get('tpot_p90', 0):10.3f}")


if __name__ == "__main__":
    main()
