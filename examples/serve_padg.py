"""End-to-end driver: serve a small model with batched requests through
the full EcoServe stack (real JAX execution, wall-clock scheduling).

Two PaDG instances serve a Poisson request trace; Algorithm 1 routes
stickily, Algorithm 2 checks constraints, instances alternate
prefill/decode slots (temporal disaggregation).

    PYTHONPATH=src python examples/serve_padg.py
"""
import dataclasses

import numpy as np


def main():
    from repro.configs import get_smoke_config
    from repro.core.request import Request
    from repro.core.slo import SLO
    from repro.data.pipeline import ByteTokenizer
    from repro.serving.engine import EngineConfig
    from repro.serving.padg_server import PaDGServer

    cfg = get_smoke_config("llama3-8b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, num_heads=2,
                              num_kv_heads=1, head_dim=64, d_ff=256,
                              vocab_size=300)
    tok = ByteTokenizer(cfg.vocab_size)
    slo = SLO(ttft=30.0, tpot=5.0)       # loose: CPU wall-clock
    server = PaDGServer(cfg, n_instances=2, slo=slo,
                        econf=EngineConfig(max_batch=4, max_seq_len=64,
                                           eos_token=-1))

    prompts = [
        "the quick brown fox", "ecoserve rolls activation",
        "prefill then decode", "macro instances cooperate",
        "temporal disaggregation", "commodity interconnects win",
        "rolling activation keeps ttft low", "mitosis scales instances",
    ]
    rng = np.random.default_rng(0)
    reqs = []
    t = 0.0
    for i, p in enumerate(prompts):
        ids = tok.encode(p)[:20]
        reqs.append(Request(rid=i, arrival_time=t, prompt_len=len(ids),
                            output_len=6, prompt_tokens=ids))
        t += float(rng.exponential(0.15))

    print(f"serving {len(reqs)} requests on 2 PaDG instances "
          f"({cfg.param_count()/1e6:.1f}M params each, CPU)...")
    stats = server.serve(reqs)
    s = stats.summary()
    print(f"\nfinished={s['finished']}  tokens={s['tokens']}")
    print(f"TTFT  p50={s['ttft_p50']*1e3:.0f}ms  p90={s['ttft_p90']*1e3:.0f}ms")
    print(f"TPOT  p50={s['tpot_p50']*1e3:.0f}ms")
    for r in stats.finished[:4]:
        print(f"  req {r.rid}: instance={r.instance_id} "
              f"ttft={r.ttft*1e3:.0f}ms tokens={r.generated}")


if __name__ == "__main__":
    main()
