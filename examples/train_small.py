"""Train a ~100M-parameter llama3-family model for a few hundred steps on
the synthetic corpus; loss must drop.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slow on CPU; default ~25M)")
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.data.pipeline import ByteTokenizer, TokenDataset, \
        synthetic_corpus
    from repro.training.optimizer import AdamW
    from repro.training.train_loop import train

    cfg = get_smoke_config("llama3-8b")
    if args.big:
        cfg = dataclasses.replace(
            cfg, name="llama3-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=512)
    else:
        cfg = dataclasses.replace(
            cfg, name="llama3-25m", num_layers=4, d_model=512, num_heads=8,
            num_kv_heads=4, head_dim=64, d_ff=1408, vocab_size=512)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    ds = TokenDataset.from_texts(synthetic_corpus(1024),
                                 ByteTokenizer(cfg.vocab_size))
    batches = ds.batches(args.batch, args.seq)
    _, losses = train(cfg, batches, steps=args.steps,
                      optimizer=AdamW(lr=6e-4), log_every=20,
                      checkpoint_path="experiments/ckpt/train_small.npz")
    drop = losses[0] - min(losses[-10:])
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
    assert drop > 0.5, "training must reduce loss"


if __name__ == "__main__":
    main()
