"""Regenerate the §Roofline markdown table from experiments/dryrun JSONs.
Usage: PYTHONPATH=src python scripts_gen_roofline_md.py > /tmp/roofline.md
"""
import glob
import json

rows = []
for f in sorted(glob.glob("experiments/dryrun/*.json")):
    rows.append(json.load(open(f)))

print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
      " dominant | useful | fits bf16 HBM |")
print("|---|---|---|---|---|---|---|---|---|")
for r in sorted((r for r in rows if r["status"] == "ok" and
                 r.get("variant", "baseline") == "baseline"),
                key=lambda r: (r["arch"], r["shape"], r["mesh"])):
    t = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
          f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
          f"| {t['collective_s']:.4f} | **{t['dominant']}** "
          f"| {r['useful_flops_ratio']:.3f} "
          f"| {'yes' if r['memory']['fits_hbm'] else 'NO'} |")

print("\nSkipped combinations:\n")
print("| arch | shape | mesh | reason |")
print("|---|---|---|---|")
for r in sorted((r for r in rows if r["status"] == "skipped"),
                key=lambda r: (r["arch"], r["shape"], r["mesh"])):
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['reason']} |")

print("\nPerf variants:\n")
print("| arch | shape | mesh | variant | compute_s | memory_s |"
      " collective_s | dominant | peak GB/dev (bf16) | fits |")
print("|---|---|---|---|---|---|---|---|---|---|")
for r in sorted((r for r in rows if r["status"] == "ok" and
                 r.get("variant", "baseline") != "baseline"),
                key=lambda r: (r["arch"], r["shape"], r["mesh"],
                               r["variant"])):
    t = r["roofline"]
    print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['variant']} "
          f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
          f"| {t['collective_s']:.4f} | {t['dominant']} "
          f"| {r['memory']['peak_bytes_bf16_projected']/1e9:.1f} "
          f"| {'yes' if r['memory']['fits_hbm'] else 'NO'} |")
