"""Builders for the distributed step functions (train / prefill / decode).

Each builder returns ``(step_fn, arg_sds, in_shardings, out_shardings)``
ready for ``jax.jit(step_fn, in_shardings=..., out_shardings=...)
.lower(*arg_sds).compile()`` — the multi-pod dry-run path — or for real
execution with materialized arrays of the same structure.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.input_specs import InputShape, batch_specs
from repro.models import forward, init_cache, init_params, make_loss_fn
from repro.models import shardings as sh
from repro.models.layers import MeshInfo
from repro.training.optimizer import AdamW, AdamWState


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=dtype),
        jax.random.key(0))


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------- #
def build_train_step(cfg: ModelConfig, mi: MeshInfo, shape: InputShape,
                     dtype=jnp.bfloat16, optimizer: AdamW = AdamW()):
    mesh = mi.mesh
    loss_fn = make_loss_fn(cfg, mi)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    p_sds = abstract_params(cfg, dtype)
    o_sds = jax.eval_shape(optimizer.init, p_sds)
    b_sds = batch_specs(cfg, shape, act_dtype=dtype)

    shard_batch = bool(mi.batch_axes)
    p_spec = sh.param_pspecs(cfg, p_sds, mi)
    o_spec = AdamWState(
        step=P(),
        m=sh.opt_state_pspecs(cfg, p_sds, mi),
        v=sh.opt_state_pspecs(cfg, p_sds, mi))
    b_spec = sh.batch_pspecs(cfg, b_sds, mi, shard_batch)

    in_sh = (_named(mesh, p_spec), _named(mesh, o_spec), _named(mesh, b_spec))
    out_sh = (in_sh[0], in_sh[1], NamedSharding(mesh, P()))
    return train_step, (p_sds, o_sds, b_sds), in_sh, out_sh


# --------------------------------------------------------------------------- #
def build_prefill_step(cfg: ModelConfig, mi: MeshInfo, shape: InputShape,
                       dtype=jnp.bfloat16):
    mesh = mi.mesh

    def prefill_step(params, batch):
        logits, cache = forward(params, cfg, batch, mi=mi, return_cache=True)
        return logits[:, -1], cache

    p_sds = abstract_params(cfg, dtype)
    b_sds = batch_specs(cfg, shape, act_dtype=dtype)
    shard_batch = bool(mi.batch_axes)
    p_spec = sh.param_pspecs(cfg, p_sds, mi)
    b_spec = sh.batch_pspecs(cfg, b_sds, mi, shard_batch)
    in_sh = (_named(mesh, p_spec), _named(mesh, b_spec))
    # let GSPMD place the returned cache/logits (inferred from producers)
    return prefill_step, (p_sds, b_sds), in_sh, None


# --------------------------------------------------------------------------- #
def build_decode_step(cfg: ModelConfig, mi: MeshInfo, shape: InputShape,
                      dtype=jnp.bfloat16):
    mesh = mi.mesh
    B, S = shape.global_batch, shape.seq_len

    def decode_step(params, cache, tokens, cache_len):
        logits, new_cache = forward(
            params, cfg, {"tokens": tokens}, mi=mi, cache=cache,
            cache_len=cache_len)
        return logits[:, 0], new_cache

    p_sds = abstract_params(cfg, dtype)
    c_sds = jax.eval_shape(
        functools.partial(init_cache, cfg, B, max_len=S, dtype=dtype))
    t_sds = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    l_sds = jax.ShapeDtypeStruct((B,), jnp.int32)

    shard_batch = bool(mi.batch_axes)
    p_spec = sh.param_pspecs(cfg, p_sds, mi)
    c_spec = sh.cache_pspecs(cfg, c_sds, mi, shard_batch)
    bspec = mi.batch_axes if shard_batch else None
    in_sh = (
        _named(mesh, p_spec),
        _named(mesh, c_spec),
        NamedSharding(mesh, P(bspec, None)),
        NamedSharding(mesh, P(bspec)),
    )
    out_sh = (NamedSharding(mesh, P(bspec, None)), in_sh[1])
    return decode_step, (p_sds, c_sds, t_sds, l_sds), in_sh, out_sh
