import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run entry point.

The two lines above MUST precede any jax-importing code: jax locks the
device count on first init, and the production meshes (16x16 and 2x16x16)
need 512 placeholder host devices.  Smoke tests / benches must NOT import
this module (they want 1 device); they use ``dryrun_lib`` in their own
subprocess when needed.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all          # full sweep, both meshes
"""
import argparse
import json
import sys


def main() -> int:
    from repro.configs import ASSIGNED
    from repro.launch.dryrun_lib import run_dryrun, save_result
    from repro.launch.input_specs import INPUT_SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    if args.all:
        combos = [(a, s, mp)
                  for a in ASSIGNED + ["llama3-8b-sw"]
                  for s in INPUT_SHAPES
                  for mp in (False, True)]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape, args.multi_pod)]

    rc = 0
    for arch, shape, mp in combos:
        res = run_dryrun(arch, shape, multi_pod=mp, variant=args.variant)
        path = save_result(res, args.out)
        line = {k: res.get(k) for k in
                ("arch", "shape", "mesh", "status", "compile_seconds")}
        if res["status"] == "ok":
            line["dominant"] = res["roofline"]["dominant"]
            line["fits_hbm"] = res["memory"]["fits_hbm"]
            print(json.dumps(line))
            print(f"  memory_analysis: peak={res['memory']['peak_bytes']/1e9:.2f}GB/device")
            print(f"  cost_analysis: flops/dev={res['cost']['flops_per_device']:.3e} "
                  f"bytes/dev={res['cost']['bytes_per_device']:.3e} "
                  f"wire/dev={res['cost']['wire_bytes_per_device']:.3e}")
        elif res["status"] == "skipped":
            line["reason"] = res["reason"]
            print(json.dumps(line))
        else:
            line["error"] = res["error"]
            print(json.dumps(line), file=sys.stderr)
            rc = 1
        print(f"  -> {path}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
