"""Input shapes and ShapeDtypeStruct stand-ins for every (arch x shape).

The four assigned input shapes; ``input_specs`` returns weak-type-correct,
shardable stand-ins with NO device allocation (ShapeDtypeStruct), exactly
what ``jax.jit(...).lower()`` needs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if the pair runs; otherwise the documented skip reason."""
    if shape.kind == "decode":
        if cfg.is_encoder:
            return "encoder-only architecture has no decode step"
        if shape.seq_len > 100_000 and not cfg.subquadratic:
            return ("pure full-attention arch: 524k dense KV cache is "
                    "quadratic; skipped per DESIGN.md (use *-sw variant)")
    return None


def batch_specs(cfg: ModelConfig, shape: InputShape,
                act_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model-input specs (tokens/frames/patches [+ labels for train])."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.modality == "audio":
        out["frames"] = SDS((B, S, cfg.frontend_dim), act_dtype)
        if shape.kind == "train":
            out["labels"] = SDS((B, S), jnp.int32)
        return out
    if cfg.modality == "vision" and shape.kind != "decode":
        P = cfg.num_patches
        out["tokens"] = SDS((B, S - P), jnp.int32)
        out["patches"] = SDS((B, P, cfg.frontend_dim), act_dtype)
        if shape.kind == "train":
            out["labels"] = SDS((B, S - P), jnp.int32)
        return out
    if shape.kind == "decode":
        out["tokens"] = SDS((B, 1), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
        if shape.kind == "train":
            out["labels"] = SDS((B, S), jnp.int32)
    return out


def to_sds(tree: Any) -> Any:
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)
