"""Dry-run core: lower + compile one (arch x shape x mesh) combination and
record memory / cost / collective analysis.  Import this ONLY from a
process whose XLA_FLAGS already force the wanted device count (see
``dryrun.py``)."""
from __future__ import annotations

import json
import math
import os
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.input_specs import INPUT_SHAPES, applicable
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step)
from repro.roofline.analysis import TPU_V5E, roofline_terms
from repro.roofline.hlo_costs import analyze_hlo


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode D=batch."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens            # forward only
    return 2.0 * n * shape.global_batch    # decode: one token per request


def run_dryrun(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    dump_hlo_dir: Optional[str] = None,
    variant: str = "baseline",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    n_chips = mesh.devices.size

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "variant": variant,
    }
    skip = applicable(cfg, shape)
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        return result

    mi = mesh_info(mesh, global_batch=shape.global_batch)
    # perf-iteration variants (§Perf in EXPERIMENTS.md)
    import dataclasses as _dc
    if "kv_headdim" in variant:
        mi = _dc.replace(mi, kv_shard="head_dim")
    if "fsdp" in variant:
        mi = _dc.replace(mi, fsdp_params=True)
    if "unroll" in variant:
        mi = _dc.replace(mi, unroll_layers=True)
    if "remat8" in variant:
        mi = _dc.replace(mi, remat_group=8)
    try:
        t0 = time.time()
        # f32 on purpose: the CPU backend legalizes bf16 compute by
        # inserting wholesale f32 conversions (copies of params + KV cache)
        # that the TPU target would never materialize.  We lower in f32 and
        # report bf16-projected memory/collective terms (/2) alongside raw.
        dt = jnp.float32
        if shape.kind == "train":
            step, sds, in_sh, out_sh = build_train_step(cfg, mi, shape, dt)
            donate = (0, 1)           # params + optimizer state
        elif shape.kind == "prefill":
            step, sds, in_sh, out_sh = build_prefill_step(cfg, mi, shape, dt)
            donate = ()
        else:
            step, sds, in_sh, out_sh = build_decode_step(cfg, mi, shape, dt)
            donate = (1,)             # KV cache updated in place

        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*sds)
            compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # instruction-level re-derivation: XLA's cost_analysis counts while
        # (layer-scan) bodies once; analyze_hlo multiplies by trip counts
        hc = analyze_hlo(hlo)

        flops_dev = hc.flops
        bytes_dev = hc.hbm_bytes
        wire_bytes = hc.wire_bytes
        # bf16 projection: every tensor in the f32-lowered program is 2 bytes
        # on the bf16 TPU target; compute stays (MXU bf16 rate).  Adam m/v &
        # softmax accumulators would stay f32 (~small undercount, documented)
        terms = roofline_terms(flops_dev, bytes_dev / 2, wire_bytes / 2)
        terms_raw_f32 = roofline_terms(flops_dev, bytes_dev, wire_bytes)
        mf = model_flops(cfg, shape)
        flops_global = flops_dev * n_chips

        result.update({
            "status": "ok",
            "compile_seconds": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": (
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)),
                # f32-lowered; bf16 target halves it (see dtype note above)
                "peak_bytes_bf16_projected": (
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)) / 2,
                "fits_hbm": (getattr(mem, "argument_size_in_bytes", 0)
                             + getattr(mem, "temp_size_in_bytes", 0)) / 2
                            < TPU_V5E.hbm_bytes,
            },
            "cost": {
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "wire_bytes_per_device": wire_bytes,
                "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
                "xla_cost_analysis_bytes": float(
                    cost.get("bytes accessed", 0.0)),
            },
            "roofline": terms,
            "roofline_raw_f32": terms_raw_f32,
            "model_flops": mf,
            "useful_flops_ratio": (mf / flops_global) if flops_global else 0.0,
            "collective_ops": hc.collectives,
        })
        if dump_hlo_dir:
            os.makedirs(dump_hlo_dir, exist_ok=True)
            fn = os.path.join(
                dump_hlo_dir, f"{arch}_{shape_name}_{mesh_name}.hlo.txt")
            with open(fn, "w") as f:
                f.write(hlo)
            result["hlo_path"] = fn
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    return result


def _summarize_collectives(ops):
    summary: Dict[str, Dict[str, float]] = {}
    for op in ops:
        s = summary.setdefault(op["kind"], {"count": 0, "wire_bytes": 0.0})
        s["count"] += op["trips"]
        s["wire_bytes"] += op["wire_bytes"]
    return summary


def save_result(result: Dict[str, Any], out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{result['arch']}_{result['shape']}_{result['mesh']}"
            f"_{result.get('variant', 'baseline')}.json")
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return path
