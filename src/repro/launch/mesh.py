"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips (TPU v5e pod); multi-pod adds a leading ``pod`` axis
(2 pods = 512 chips over DCN).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models.layers import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_info(mesh, global_batch: Optional[int] = None) -> MeshInfo:
    """Build MeshInfo; batch axes are dropped when the global batch does not
    divide them (e.g. long_500k batch=1 -> replicate, see DESIGN.md)."""
    axes = tuple(mesh.axis_names)
    batch_axes: Tuple[str, ...] = tuple(a for a in axes if a != "model")
    if global_batch is not None:
        n = 1
        for a in batch_axes:
            n *= mesh.shape[a]
        if global_batch % n != 0:
            batch_axes = ()
    model_axis = "model" if "model" in axes else None
    return MeshInfo(mesh=mesh, batch_axes=batch_axes, model_axis=model_axis)
