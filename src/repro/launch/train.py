"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a reduced (smoke) or full config; full configs on the production mesh
are exercised through dryrun.py (this container has one real device).
"""
import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override d_model (e.g. ~100M-param runs)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import ByteTokenizer, TokenDataset, \
        synthetic_corpus
    from repro.training.optimizer import AdamW
    from repro.training.train_loop import train

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    updates = {}
    if args.d_model:
        heads = max(1, args.d_model // 64) if cfg.num_heads else 0
        updates.update(d_model=args.d_model, num_heads=heads,
                       num_kv_heads=max(1, heads // 2) if heads else 0,
                       head_dim=64 if heads else 0, d_ff=args.d_model * 4)
    if args.layers:
        updates.update(num_layers=args.layers)
    if updates:
        cfg = dataclasses.replace(cfg, **updates)

    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")
    ds = TokenDataset.from_texts(synthetic_corpus(512),
                                 ByteTokenizer(cfg.vocab_size))
    batches = ds.batches(args.batch, args.seq)
    _, losses = train(cfg, batches, steps=args.steps,
                      optimizer=AdamW(lr=args.lr),
                      checkpoint_path=args.checkpoint)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
