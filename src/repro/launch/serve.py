"""Serving launcher: real-execution PaDG serving of a reduced model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --instances 2 --requests 12 --rate 4
"""
import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--out-tokens", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core.request import Request
    from repro.core.slo import SLO
    from repro.serving.engine import EngineConfig
    from repro.serving.padg_server import PaDGServer

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                              num_heads=2, num_kv_heads=1, head_dim=64,
                              d_ff=256, vocab_size=512)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")

    server = PaDGServer(cfg, n_instances=args.instances,
                        slo=SLO(ttft=60.0, tpot=10.0),
                        econf=EngineConfig(max_batch=args.max_batch,
                                           max_seq_len=96, eos_token=-1))
    rng = np.random.default_rng(0)
    reqs, t = [], 0.0
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        reqs.append(Request(
            rid=i, arrival_time=t, prompt_len=plen,
            output_len=args.out_tokens,
            prompt_tokens=[int(x) for x in rng.integers(2, 500, plen)]))
        t += float(rng.exponential(1.0 / args.rate))

    print(f"serving {len(reqs)} requests on {args.instances} instances "
          f"({cfg.name}, {cfg.param_count()/1e6:.1f}M params)")
    stats = server.serve(reqs)
    for k, v in stats.summary().items():
        print(f"  {k} = {v}")


if __name__ == "__main__":
    main()
