from repro.serving.engine import ServingEngine, EngineConfig  # noqa: F401
