"""Real-execution serving stack.

Lazy attribute access: ``repro.serving.calibration`` /
``repro.serving.replay`` are numpy-only and are imported by simulator
worker processes (the runner's calibrated-executor axis), so this
package must not eagerly pull the jax-backed engine.
"""
_ENGINE_EXPORTS = {"ServingEngine", "EngineConfig", "MeasuredExecutor"}


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine
        return getattr(engine, name)
    raise AttributeError(f"module 'repro.serving' has no attribute {name!r}")
