"""PaDG server: real-execution EcoServe over N ServingEngine instances.

Single-process cooperative loop (wall-clock): arrivals are admitted via
the macro-instance scheduler (Algorithm 1 + constraint check), instances
run temporal-disaggregated slots — a prefill burst when the scheduler
routed work to them, decode iterations otherwise.  This is the same
scheduling stack as the simulator, driven by measured durations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from repro.core.macro import MacroInstance
from repro.core.mitosis import register_instance
from repro.core.request import Request, RequestState
from repro.core.slo import SLO
from repro.serving.engine import EngineConfig, ServingEngine


@dataclasses.dataclass
class ServeStats:
    finished: List[Request]

    def summary(self) -> Dict[str, float]:
        import numpy as np
        done = self.finished
        if not done:
            return {"finished": 0}
        ttft = np.array([r.ttft for r in done])
        tpots = [r.avg_tpot for r in done if r.avg_tpot is not None]
        return {
            "finished": len(done),
            "ttft_p50": float(np.percentile(ttft, 50)),
            "ttft_p90": float(np.percentile(ttft, 90)),
            "tpot_p50": float(np.percentile(tpots, 50)) if tpots else 0.0,
            "tokens": int(sum(r.tokens_generated for r in done)),
        }


class RealInstance(Instance):
    """Scheduling instance bound to a real engine."""

    def __init__(self, iid: int, engine: ServingEngine, slo: SLO):
        super().__init__(
            iid, engine.executor,
            kv_capacity_tokens=engine.econf.max_batch
            * engine.econf.max_seq_len,
            max_decode_batch=engine.econf.max_batch,
            slo_tpot=slo.tpot, slo_ttft=slo.ttft)
        self.engine = engine


class PaDGServer:
    def __init__(self, cfg: ModelConfig, n_instances: int, slo: SLO,
                 econf: EngineConfig = EngineConfig(), seed: int = 0):
        self.slo = slo
        self.instances: List[RealInstance] = []
        for i in range(n_instances):
            eng = ServingEngine(cfg, seed=seed, econf=econf)
            inst = RealInstance(i, eng, slo)
            register_instance(inst)
            self.instances.append(inst)
        self.macro = MacroInstance(
            0, self.instances, slo,
            predict_prefill=lambda n: self.instances[0].executor
            .prefill_time([n]))
        self.finished: List[Request] = []

    # --------------------------------------------------------------- #
    def serve(self, requests: List[Request],
              time_scale: float = 1.0) -> ServeStats:
        """Serve a request trace (arrival_time in seconds, scaled by
        ``time_scale``).  Returns per-request latency stats."""
        self._t0 = time.perf_counter()
        self._scale = time_scale
        pending = sorted(requests, key=lambda r: r.arrival_time)
        queue: List[Request] = []

        def now() -> float:
            return (time.perf_counter() - self._t0) / time_scale

        while pending or queue or any(
                i.pending or i.decoding for i in self.instances):
            t = now()
            # 1. admit due arrivals through Algorithm 1
            while pending and pending[0].arrival_time <= t:
                queue.append(pending.pop(0))
            still = []
            for req in queue:
                inst = self.macro.route(req, t)
                if inst is None:
                    if t - req.arrival_time > 4 * self.slo.ttft:
                        self.macro.route_forced(req, t)
                    else:
                        still.append(req)
            queue = still

            # 2. each instance runs one slot of its current phase
            progressed = False
            for inst in self.instances:
                progressed |= self._step_instance(inst)
            if not progressed and not queue:
                if pending:
                    wait = max(0.0, pending[0].arrival_time - now())
                    time.sleep(min(wait, 0.01) * time_scale)
                else:
                    time.sleep(0.001)
        return ServeStats(self.finished)

    # --------------------------------------------------------------- #
    def _step_instance(self, inst: RealInstance) -> bool:
        eng = inst.engine
        if inst.pending and eng.free_slots() and \
                inst._slack_allows_prefill(self._now(inst)):
            req = inst.pending[0]
            inst.remove_pending(req)
            inst.phase = "prefill"
            eng.prefill(req)
            req.state = RequestState.DECODING
            req.first_token_time = self._now(inst)
            req.tokens_generated = 1
            if req.tokens_generated >= req.output_len:
                self._finish(inst, req)
            else:
                inst.add_decoding(req)
            return True
        if inst.decoding:
            inst.phase = "decode"
            eng.decode_step()
            tnow = self._now(inst)
            for req in list(inst.decoding):
                inst.sync_tokens(req, len(req.generated))
                if req.tokens_generated == 2:
                    req.second_token_time = tnow
                still_running = any(r is req for r in eng.slot_req)
                if not still_running:
                    inst.remove_decoding(req)
                    self._finish(inst, req)
            return True
        inst.phase = "idle"
        return False

    def _finish(self, inst: RealInstance, req: Request) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = self._now(inst)
        self.finished.append(req)

    def _now(self, inst=None) -> float:
        if not hasattr(self, "_t0"):
            return 0.0
        return (time.perf_counter() - self._t0) / self._scale
