"""PaDG server: real-execution EcoServe over N engine-backed instances.

The server IS the simulator's scheduling stack: requests flow through an
``EcoServeSystem`` (Algorithm 1 routing over macro instances, Algorithm 2
admission constraints, timeout-forced queueing) driven by a
``repro.serving.replay.ReplayEngine`` — a ``SimulationEngine`` whose slot
completions additionally execute on each instance's attached engine
backend (the jax ``ServingEngine`` or the deterministic ``FakeEngine``)
and whose timeline can follow a wall clock.  Because both stacks run the
identical admission/routing/slot code, the sim-to-real conformance suite
can assert decision-for-decision equality between a simulated run and a
served run of the same trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from repro.core.mitosis import register_instance, unregister_instance
from repro.core.padg_system import EcoServeSystem
from repro.core.request import Request, RequestState
from repro.core.slo import SLO
from repro.obs.events import attach_tracer
from repro.serving.replay import (FakeEngine, RealEngineBackend,
                                  ReplayEngine, WallClock)


@dataclasses.dataclass
class ServeStats:
    finished: List[Request]
    rejected: List[Request] = dataclasses.field(default_factory=list)
    # scheduling-decision trace (serve(record_decisions=True)); None when
    # not recorded
    decisions: Optional[list] = None

    def summary(self) -> Dict[str, float]:
        """Latency summary; always emits the full key set (zeros when no
        request finished) so JSONL rows keep a stable schema."""
        import numpy as np
        done = self.finished
        ttft = np.array([r.ttft for r in done
                         if r.ttft is not None]) if done else np.array([])
        tpots = [r.avg_tpot for r in done if r.avg_tpot is not None]
        return {
            "finished": len(done),
            "rejected": len(self.rejected),
            "ttft_p50": float(np.percentile(ttft, 50)) if len(ttft) else 0.0,
            "ttft_p90": float(np.percentile(ttft, 90)) if len(ttft) else 0.0,
            "tpot_p50": float(np.percentile(tpots, 50)) if tpots else 0.0,
            "tokens": int(sum(r.tokens_generated for r in done)),
        }


class _SchedulerModel:
    """Cost-model facade the scheduling system sees: prefill predictions
    come from the live executor (measured or analytic), capacity from the
    engine's slotted KV geometry."""

    def __init__(self, executor, kv_capacity: int):
        self.executor = executor
        self._kv_capacity = kv_capacity

    def predict_prefill(self, prompt_len: int) -> float:
        if hasattr(self.executor, "predict_prefill"):
            return self.executor.predict_prefill(prompt_len)
        return self.executor.prefill_time([prompt_len])

    def kv_capacity_tokens(self) -> int:
        return self._kv_capacity


class RealEcoServeSystem(EcoServeSystem):
    """EcoServeSystem whose instances carry engine backends and the
    engine's physical slot geometry (``max_decode_batch`` /
    ``max_prefill_batch`` = the engine's slot count)."""

    def __init__(self, executors, engines, econf, slo, scheduler_model,
                 **kw):
        # consumed by _make_instance, which runs inside super().__init__
        self._executors = executors
        self._engines = engines
        self._econf = econf
        super().__init__(scheduler_model, len(engines), slo, **kw)

    def _make_instance(self, iid: int) -> Instance:
        econf = self._econf
        inst = Instance(
            iid, self._executors[iid],
            kv_capacity_tokens=econf.max_batch * econf.max_seq_len,
            max_decode_batch=econf.max_batch,
            max_prefill_batch=econf.max_batch,
            slo_tpot=self.slo.tpot, slo_ttft=self.slo.ttft,
            slo_classes=self.slo_set)
        inst.engine = self._engines[iid]
        register_instance(inst)
        return inst


class PaDGServer:
    """Real-execution EcoServe server.

    ``backend="real"`` builds one jax ``ServingEngine`` per instance
    (tiny CPU configs by default); ``backend="fake"`` uses the
    deterministic ``FakeEngine`` (requires an explicit ``executor`` model
    — there is nothing to measure) for conformance tests and synthetic
    calibration runs.
    """

    def __init__(self, cfg: Optional[ModelConfig], n_instances: int,
                 slo: SLO, econf=None, seed: int = 0,
                 backend: str = "real", executor=None, recorder=None,
                 true_model=None):
        if econf is None:
            # imported lazily: the fake backend (conformance tests,
            # synthetic calibration) must not pull jax
            from repro.serving.engine import EngineConfig
            econf = EngineConfig()
        self.econf = econf
        self.slo = slo
        self._shutdown = False
        engines, executors = [], []
        for i in range(n_instances):
            if backend == "real":
                from repro.serving.engine import ServingEngine
                eng = ServingEngine(cfg, seed=seed, econf=econf,
                                    recorder=recorder)
                engines.append(RealEngineBackend(eng))
                executors.append(executor if executor is not None
                                 else eng.executor)
            elif backend == "fake":
                if executor is None:
                    raise ValueError(
                        "backend='fake' needs an explicit executor model")
                engines.append(FakeEngine(econf, true_model=true_model,
                                          recorder=recorder))
                executors.append(executor)
            else:
                raise ValueError(f"unknown backend {backend!r}")
        model = _SchedulerModel(executors[0],
                                econf.max_batch * econf.max_seq_len)
        self.system = RealEcoServeSystem(executors, engines, econf, slo,
                                         model)
        self.recorder = recorder
        self.finished: List[Request] = []

    @property
    def instances(self) -> List[Instance]:
        return self.system.instances

    # --------------------------------------------------------------- #
    def serve(self, requests: List[Request], time_scale: float = 1.0,
              clock=None, record_decisions: bool = False,
              horizon: float = float("inf"), tracer=None) -> ServeStats:
        """Serve a request trace.  ``time_scale`` > 1 stretches trace
        time on the default wall clock; pass a ``VirtualClock`` for a
        deterministic (conformance) replay.  ``tracer`` attaches a
        flight recorder to the served run — the same
        ``repro.obs.Tracer`` the simulator uses, with the recorder's
        per-op samples riding the same bus."""
        usable = self.econf.max_seq_len - 2
        accepted, rejected = [], []
        for r in requests:
            if r.prompt_len > usable or r.prompt_len <= 0:
                r.state = RequestState.FAILED
                rejected.append(r)
            else:
                accepted.append(r)

        if clock is None:
            clock = WallClock(time_scale)
        engine = ReplayEngine(self.system, clock=clock)
        log: Optional[list] = [] if record_decisions else None
        if record_decisions:
            engine.decision_log = log
            self.system.decision_log = log
        if tracer is not None:
            attach_tracer(tracer, engine=engine, system=self.system)
            if self.recorder is not None:
                self.recorder.tracer = tracer
        try:
            finished = engine.run(accepted, horizon=horizon)
        finally:
            if record_decisions:
                engine.decision_log = None
                self.system.decision_log = None
        self.finished.extend(finished)
        return ServeStats(list(finished), rejected=rejected, decisions=log)

    # --------------------------------------------------------------- #
    def shutdown(self) -> None:
        """Release the actor-registry entries taken in ``__init__`` (the
        mitosis registry is process-global; leaking entries across
        servers corrupts later registry-size accounting)."""
        if self._shutdown:
            return
        self._shutdown = True
        for inst in self.system.instances:
            unregister_instance(inst)

    def __enter__(self) -> "PaDGServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
