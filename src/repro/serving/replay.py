"""Replay harness: drive tagged traces through the live serving stack.

The scheduling stack (``EcoServeSystem`` + ``SimulationEngine``) is shared
verbatim between the simulator and the real server; what changes is *who
executes the slots* and *whose clock the timeline follows*.  This module
supplies those two axes:

- ``VirtualClock`` / ``WallClock``: a virtual clock keeps the replay a
  deterministic discrete-event run (slot durations come from the
  executor model — bit-reproducible, used by the conformance suite); a
  wall clock sleeps until each event's timestamp (scaled by
  ``time_scale``) and folds real elapsed time back into the timeline.
- ``FakeEngine`` / ``RealEngineBackend``: a slot-for-slot stand-in that
  emits deterministic junk tokens (and can report a ``SyntheticTruth``
  model's timings into a CalibrationRecorder), and an adapter over the
  jax ``ServingEngine`` with the same run_prefill/run_decode/release
  protocol.
- ``ReplayEngine``: a ``SimulationEngine`` subclass that, at every slot
  completion, first lets the instance's attached backend actually
  execute the slot, reconciles engine-side early finishes (EOS, seq cap)
  with the scheduler's token accounting, then applies the normal
  completion path — so admission, routing and slot ordering are decided
  by exactly the code the simulator runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.request import Request
from repro.simulator.engine import SimulationEngine


@dataclasses.dataclass(frozen=True)
class SlotConfig:
    """The slot geometry the fake backend and the scheduler need —
    duck-compatible with ``repro.serving.engine.EngineConfig`` without
    the jax import the latter carries."""
    max_batch: int = 8
    max_seq_len: int = 256


# --------------------------------------------------------------------- #
class VirtualClock:
    """Deterministic clock: time is whatever the event loop says it is."""

    def __init__(self) -> None:
        self._now = 0.0

    def start(self) -> None:
        pass

    def now(self) -> float:
        return self._now

    def sleep_until(self, t: float) -> None:
        if t > self._now:
            self._now = t


class WallClock:
    """Real clock; ``time_scale`` > 1 stretches trace time (a 1 s gap in
    the trace takes ``time_scale`` wall seconds — slower than real time,
    useful to keep tiny CPU configs inside SLO), < 1 compresses it."""

    def __init__(self, time_scale: float = 1.0) -> None:
        self.time_scale = time_scale
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        if self._t0 is None:
            return 0.0
        return (time.perf_counter() - self._t0) / self.time_scale

    def sleep_until(self, t: float) -> None:
        # chunked sleeps so shutdown/interrupt stays responsive
        while True:
            dt = t - self.now()
            if dt <= 0:
                return
            time.sleep(min(dt * self.time_scale, 0.05))


# --------------------------------------------------------------------- #
def requests_from_trace(records: Sequence[dict], *, max_prompt: int,
                        max_output: int, vocab_size: Optional[int] = None,
                        seed: int = 0, limit: Optional[int] = None,
                        start_at_zero: bool = True) -> List[Request]:
    """Convert tagged trace records (``repro.traces`` fixture schema:
    arrival_time / prompt_len / output_len [/ slo_class]) into engine-ready
    ``Request`` objects, clipping lengths to the engine's tiny config and
    synthesizing prompt token ids when ``vocab_size`` is given."""
    rng = np.random.default_rng(seed)
    recs = list(records)[:limit] if limit is not None else list(records)
    t0 = min((r["arrival_time"] for r in recs), default=0.0) \
        if start_at_zero else 0.0
    out: List[Request] = []
    for i, r in enumerate(recs):
        plen = max(1, min(int(r["prompt_len"]), max_prompt))
        olen = max(1, min(int(r["output_len"]), max_output))
        req = Request(rid=i, arrival_time=float(r["arrival_time"]) - t0,
                      prompt_len=plen, output_len=olen,
                      slo_class=r.get("slo_class") or "default")
        if vocab_size is not None:
            req.prompt_tokens = rng.integers(
                2, vocab_size - 1, size=plen).tolist()
        out.append(req)
    return out


# --------------------------------------------------------------------- #
class FakeEngine:
    """Deterministic stand-in for ``ServingEngine`` with the same slot
    discipline: one prefill lands one request in a slot, one decode step
    advances every occupied slot by one token.  Never emits EOS, so the
    scheduler's token accounting is the only finish criterion — which is
    what the conformance suite needs.  When ``true_model``/``recorder``
    are given, each op reports the model's timing as its 'measured' dt
    (the synthetic ground truth the calibration golden is fitted on)."""

    def __init__(self, econf, true_model=None, recorder=None):
        self.econf = econf
        B = econf.max_batch
        self.slot_req: List[Optional[Request]] = [None] * B
        self.lengths = np.zeros(B, np.int32)
        self.true_model = true_model
        self.recorder = recorder

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def run_prefill(self, reqs: List[Request]) -> None:
        for req in reqs:
            slots = self.free_slots()
            assert slots, "no free decode slot"
            slot = slots[0]
            self.slot_req[slot] = req
            self.lengths[slot] = req.prompt_len
            req.generated = [2 + req.rid % 97]
            if self.recorder is not None and self.true_model is not None:
                self.recorder.record_prefill(
                    req.prompt_len,
                    self.true_model.prefill_time([req.prompt_len]))

    def run_decode(self, reqs: List[Request]) -> List[Request]:
        """One decode iteration; returns requests the *engine* freed
        early (seq cap) that the scheduler still thinks are running."""
        occupied = [i for i, r in enumerate(self.slot_req)
                    if r is not None]
        if not occupied:
            return []
        ctx_sum = int(sum(self.lengths[i] for i in occupied))
        if self.recorder is not None and self.true_model is not None:
            self.recorder.record_decode(
                len(occupied), ctx_sum,
                self.true_model.decode_time(len(occupied),
                                            ctx_sum=ctx_sum))
        early: List[Request] = []
        for i in occupied:
            req = self.slot_req[i]
            self.lengths[i] += 1
            req.generated.append(2 + (req.rid + len(req.generated)) % 97)
            done = (len(req.generated) >= req.output_len
                    or self.lengths[i] >= self.econf.max_seq_len - 1)
            if done:
                self.slot_req[i] = None
                self.lengths[i] = 0
                if len(req.generated) < req.output_len:
                    early.append(req)
        return early

    def release(self, req: Request) -> None:
        for i, r in enumerate(self.slot_req):
            if r is req:
                self.slot_req[i] = None
                self.lengths[i] = 0
                return


class RealEngineBackend:
    """run_prefill/run_decode/release adapter over the jax ServingEngine."""

    def __init__(self, engine):
        self.engine = engine

    @property
    def econf(self):
        return self.engine.econf

    @property
    def executor(self):
        return self.engine.executor

    def free_slots(self) -> List[int]:
        return self.engine.free_slots()

    def run_prefill(self, reqs: List[Request]) -> None:
        for req in reqs:
            self.engine.prefill(req)

    def run_decode(self, reqs: List[Request]) -> List[Request]:
        before = {id(r): r for r in self.engine.slot_req if r is not None}
        self.engine.decode_step()
        after = {id(r) for r in self.engine.slot_req if r is not None}
        # engine-freed requests that finished early (EOS / seq cap)
        return [r for rid_, r in before.items()
                if rid_ not in after and len(r.generated) < r.output_len]

    def release(self, req: Request) -> None:
        self.engine.release(req)


# --------------------------------------------------------------------- #
class ReplayEngine(SimulationEngine):
    """SimulationEngine that executes slots on each instance's attached
    engine backend (``inst.engine``) and paces the timeline by a clock.

    With a ``VirtualClock`` (the default when ``clock`` is None) and an
    analytic executor model, a replay is a plain discrete-event run plus
    real token generation — decision-for-decision identical to the
    simulator, which is the sim-to-real conformance property.  With a
    ``WallClock``, measured execution time that overruns the modeled slot
    duration pushes the timeline forward (never backward), so SLO math
    reflects reality.
    """

    def __init__(self, system, clock=None):
        super().__init__(system)
        self.clock = clock if clock is not None else VirtualClock()

    # ------------------------------------------------------------------ #
    def _complete_slot(self, inst, kind, reqs, t_end):
        backend = getattr(inst, "engine", None)
        if backend is not None and inst.alive:
            if kind == "prefill":
                backend.run_prefill(reqs)
            else:
                for r in backend.run_decode(reqs):
                    # engine finished early (EOS or per-slot seq cap):
                    # clamp the scheduler's target so both sides agree
                    # this request is done
                    r.output_len = len(r.generated)
            t_real = self.clock.now()
            if t_real > t_end:
                t_end = t_real
                self.now = t_real
        n0 = len(self.finished)
        super()._complete_slot(inst, kind, reqs, t_end)
        if backend is not None:
            # requests the scheduler finished that still hold an engine
            # slot (e.g. one-token outputs done at prefill)
            for r in self.finished[n0:]:
                backend.release(r)

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request],
            horizon: float = float("inf")) -> List[Request]:
        arrivals = sorted(requests, key=lambda r: r.arrival_time)
        i, n = 0, len(arrivals)
        heap = self.heap
        self.clock.start()
        import heapq
        while True:
            t_arr = arrivals[i].arrival_time if i < n else None
            if heap and (t_arr is None or heap[0].time < t_arr):
                if heap[0].time > horizon:
                    break
                ev = heapq.heappop(heap)
                self.clock.sleep_until(ev.time)
                self.now = max(self.now, ev.time)
                ev.fn(*ev.args)
            elif t_arr is not None:
                if t_arr > horizon:
                    break
                self.clock.sleep_until(t_arr)
                self.now = max(self.now, t_arr)
                req = arrivals[i]
                i += 1
                trc = self.tracer
                if trc.enabled:
                    trc.arrive(self.now, req)
                self.system.submit(req, self.now, self)
            else:
                break
            if self.on_tick:
                self.on_tick(self.now)
        self._pump_stragglers(horizon)
        return self.finished

    def _pump_stragglers(self, horizon: float) -> None:
        """After the last event, requests can still sit in the system
        queue waiting for the timeout-forced admission to trip (in the
        simulator that deferral simply ends the run; a server must serve
        them).  Advance time to each pending forced-admission deadline
        and drain until the queue empties or stops making progress."""
        import heapq
        system = self.system
        queue = getattr(system, "queue", None)
        slo_set = getattr(system, "slo_set", None)
        factor = getattr(getattr(system, "admission", None),
                         "timeout_factor", None)
        if queue is None or slo_set is None or factor is None:
            return
        guard = 0
        while queue and guard < 10_000:
            guard += 1
            before = len(queue)
            t_force = min(r.arrival_time
                          + factor * slo_set.for_request(r).ttft
                          for r in queue)
            t = max(self.now, t_force) + 1e-9
            if t > horizon:
                return
            self.clock.sleep_until(t)
            self.now = max(self.now, t)
            system._drain_queue(self.now, self)
            while self.heap and self.heap[0].time <= horizon:
                ev = heapq.heappop(self.heap)
                self.clock.sleep_until(ev.time)
                self.now = max(self.now, ev.time)
                ev.fn(*ev.args)
            if len(queue) >= before:
                return
