"""Public serving API: text-in/text-out generation over the PaDG server,
with per-token streaming callbacks (the "typewriter mode" of §3.3)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.request import Request
from repro.core.slo import SLO
from repro.data.pipeline import ByteTokenizer
from repro.serving.engine import EngineConfig
from repro.serving.padg_server import PaDGServer


@dataclasses.dataclass
class GenerationResult:
    prompt: str
    text: str
    tokens: List[int]
    ttft_s: float
    avg_tpot_s: Optional[float]


class EcoServeAPI:
    """Batched generate() over N real PaDG instances."""

    def __init__(self, cfg: ModelConfig, n_instances: int = 2,
                 slo: SLO = SLO(ttft=60.0, tpot=10.0),
                 econf: EngineConfig = EngineConfig(), seed: int = 0):
        self.cfg = cfg
        self.tok = ByteTokenizer(cfg.vocab_size)
        self.server = PaDGServer(cfg, n_instances, slo, econf, seed=seed)
        self._stream_cb: Optional[Callable[[int, int], None]] = None

    def generate(self, prompts: List[str], max_new_tokens: int = 16,
                 stream: Optional[Callable[[int, int], None]] = None,
                 ) -> List[GenerationResult]:
        reqs = []
        for i, p in enumerate(prompts):
            ids = self.tok.encode(p)
            ids = ids[: self.server.instances[0].engine.econf.max_seq_len
                      - max_new_tokens - 1]
            reqs.append(Request(rid=i, arrival_time=0.0,
                                prompt_len=len(ids),
                                output_len=max_new_tokens,
                                prompt_tokens=ids))
        self.server.serve(reqs)
        # the local reqs carry the generated tokens and timings directly
        # (keying stats.finished by rid would collide across generate()
        # calls, which all number their requests from 0)
        out = []
        for i, p in enumerate(prompts):
            r = reqs[i]
            if stream:
                for t in r.generated:
                    stream(i, t)
            out.append(GenerationResult(
                prompt=p,
                text=self.tok.decode(r.generated),
                tokens=list(r.generated),
                ttft_s=r.ttft or 0.0,
                avg_tpot_s=r.avg_tpot))
        return out

    def close(self) -> None:
        """Release the server's actor-registry entries."""
        self.server.shutdown()

    def __enter__(self) -> "EcoServeAPI":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
