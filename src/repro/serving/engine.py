"""Real-execution serving engine: continuous batching over an actual JAX
model (runs a reduced config on CPU; the same code drives TPU instances).

One ``ServingEngine`` is one PaDG *instance*: it owns params, a slotted
KV cache, and executes prefill/decode slots for the scheduling ``Instance``
it is attached to.  The scheduler stack (macro instance, Algorithms 1+2,
mitosis) is exactly the one from ``repro.core`` — durations are measured
wall-clock instead of predicted, which is what `MeasuredExecutor` adapts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.models import forward, grow_cache, init_cache, init_params
from repro.models.layers import MeshInfo


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # decode slots
    max_seq_len: int = 256        # per-slot KV capacity
    dtype: object = jnp.float32
    eos_token: int = 1
    greedy: bool = True


class MeasuredExecutor:
    """ExecutorModel backed by observed wall-clock times (EWMA), used by
    the scheduling Instance attached to a real engine."""

    def __init__(self, fallback_prefill=2e-4, fallback_decode=5e-2):
        self._prefill_per_tok = fallback_prefill
        self._decode = fallback_decode

    def observe_prefill(self, tokens: int, dt: float) -> None:
        per = dt / max(1, tokens)
        self._prefill_per_tok = 0.7 * self._prefill_per_tok + 0.3 * per

    def observe_decode(self, dt: float) -> None:
        self._decode = 0.7 * self._decode + 0.3 * dt

    def prefill_time(self, lens: List[int]) -> float:
        return self._prefill_per_tok * sum(lens)

    def decode_time(self, batch: int, ctxs: List[int]) -> float:
        return self._decode


class ServingEngine:
    """Slot-based continuous batching with a fixed-shape decode step (no
    recompilation as requests come and go)."""

    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0,
                 econf: EngineConfig = EngineConfig()):
        assert not cfg.is_encoder, "decode engine serves decoder models"
        self.cfg = cfg
        self.econf = econf
        self.params = params if params is not None else init_params(
            jax.random.key(seed), cfg, econf.dtype)
        B, S = econf.max_batch, econf.max_seq_len
        self.cache = init_cache(cfg, B, max_len=S, dtype=econf.dtype)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = np.zeros(B, np.int32)          # context per slot
        self.slot_req: List[Optional[Request]] = [None] * B
        self.executor = MeasuredExecutor()

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._decode_fn = jax.jit(self._decode_impl, donate_argnums=(1,))

    # --------------------------------------------------------------- #
    def _prefill_impl(self, params, toks):
        logits, cache = forward(params, self.cfg, {"tokens": toks},
                                return_cache=True)
        return logits[:, -1], cache

    def _decode_impl(self, params, cache, toks, lengths):
        logits, cache = forward(params, self.cfg, {"tokens": toks},
                                cache=cache, cache_len=lengths)
        return logits[:, 0], cache

    # --------------------------------------------------------------- #
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def prefill(self, req: Request) -> int:
        """Run the prompt through the model, land the request in a decode
        slot.  Returns the generated first token."""
        slots = self.free_slots()
        assert slots, "no free decode slot"
        slot = slots[0]
        prompt = req.prompt_tokens
        t0 = time.perf_counter()
        toks = jnp.asarray(np.array(prompt, np.int32))[None, :]
        logits, pcache = self._prefill_fn(self.params, toks)
        first = int(jnp.argmax(logits[0]))
        pcache = grow_cache(self.cfg, pcache, self.econf.max_seq_len)
        self.cache = _merge_slot(self.cfg, self.cache, pcache, slot)
        dt = time.perf_counter() - t0
        self.executor.observe_prefill(len(prompt), dt)

        self.lengths[slot] = len(prompt)
        self.slot_req[slot] = req
        self.tokens = self.tokens.at[slot, 0].set(first)
        req.generated = [first]
        return first

    def decode_step(self) -> Dict[int, int]:
        """One decode iteration over all occupied slots.  Returns
        {slot: token} for slots that produced a token."""
        occupied = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not occupied:
            return {}
        t0 = time.perf_counter()
        lengths = jnp.asarray(self.lengths)
        logits, self.cache = self._decode_fn(
            self.params, self.cache, self.tokens, lengths)
        new_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        dt = time.perf_counter() - t0
        self.executor.observe_decode(dt)

        out: Dict[int, int] = {}
        for i in occupied:
            tok = int(new_tokens[i])
            self.lengths[i] += 1
            out[i] = tok
            req = self.slot_req[i]
            req.generated.append(tok)
            self.tokens = self.tokens.at[i, 0].set(tok)
            done = (tok == self.econf.eos_token
                    or len(req.generated) >= req.output_len
                    or self.lengths[i] >= self.econf.max_seq_len - 1)
            if done:
                self.slot_req[i] = None
                self.lengths[i] = 0
        return out


def _merge_slot(cfg, big_cache, pcache, slot: int):
    """Write a prefill-produced (B=1) cache into batch slot `slot`."""
    def merge(big, small):
        # identify the batch axis: scan leaves are (n_full, B, ...) and the
        # single-request cache has B == 1 there; tail leaves are (B, ...)
        axis = 1 if (big.ndim >= 2 and small.ndim == big.ndim
                     and small.shape[0] == big.shape[0]
                     and small.shape[1] == 1) else 0
        # pad small's seq dim up to big's if needed
        pads = []
        for d in range(big.ndim):
            if d == axis:
                pads.append((0, 0))
            else:
                pads.append((0, big.shape[d] - small.shape[d]))
        small = jnp.pad(small, pads)
        idx = [slice(None)] * big.ndim
        idx[axis] = slice(slot, slot + 1)
        return big.at[tuple(idx)].set(small)

    return jax.tree.map(merge, big_cache, pcache)
