"""Real-execution serving engine: continuous batching over an actual JAX
model (runs a reduced config on CPU; the same code drives TPU instances).

One ``ServingEngine`` is one PaDG *instance*: it owns params, a slotted
KV cache, and executes prefill/decode slots for the scheduling ``Instance``
it is attached to.  The scheduler stack (macro instance, Algorithms 1+2,
mitosis) is exactly the one from ``repro.core`` — durations are measured
wall-clock instead of predicted, which is what `MeasuredExecutor` adapts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.models import forward, grow_cache, init_cache, init_params
from repro.models.layers import MeshInfo


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # decode slots
    max_seq_len: int = 256        # per-slot KV capacity
    dtype: object = jnp.float32
    eos_token: int = 1
    greedy: bool = True


class MeasuredExecutor:
    """ExecutorModel backed by observed wall-clock times, used by the
    scheduling Instance attached to a real engine.

    Shape-aware: predictions follow the same linear forms as
    ``simulator.cost_model`` (prefill base + per-token; decode per-slot
    base + ctx-sum term), with the constants seeded by probing a cost
    model (``seed_model``) and a single EWMA *gain* per op tracking the
    observed/predicted ratio — so a slot with twice the batch really is
    predicted to take longer, and the first prediction before any
    observation is the model's estimate rather than a magic number.
    """

    # no sliding-window clamp on the real engine's slotted KV: advertise
    # the Instance ctx_sum fast path with an unbounded clamp
    ctx_clamp = 0

    def __init__(self, seed_model=None,
                 fallback_prefill=2e-4, fallback_decode=5e-2):
        if seed_model is not None:
            p1 = seed_model.prefill_time([1])
            p257 = seed_model.prefill_time([257])
            self._prefill_per_tok = max((p257 - p1) / 256.0, 1e-12)
            self._prefill_base = max(p1 - self._prefill_per_tok, 0.0)
            d10 = seed_model.decode_time(1, [0])
            d20 = seed_model.decode_time(2, [0, 0])
            d1k = seed_model.decode_time(1, [1024])
            self._decode_per_seq = max(d20 - d10, 0.0)
            self._decode_per_ctx = max((d1k - d10) / 1024.0, 0.0)
            self._decode_base = max(d10 - self._decode_per_seq, 0.0)
        else:
            # legacy flat fallbacks (no model to probe)
            self._prefill_per_tok = fallback_prefill
            self._prefill_base = 0.0
            self._decode_per_seq = fallback_decode
            self._decode_per_ctx = 0.0
            self._decode_base = 0.0
        self._prefill_gain = 1.0
        self._decode_gain = 1.0

    def observe_prefill(self, tokens: int, dt: float) -> None:
        pred = self._prefill_base + self._prefill_per_tok * max(1, tokens)
        if pred > 0:
            self._prefill_gain = (0.7 * self._prefill_gain
                                  + 0.3 * dt / pred)

    def observe_decode(self, dt: float, batch: int = 1,
                       ctx_sum: int = 0) -> None:
        pred = (self._decode_base + self._decode_per_seq * max(1, batch)
                + self._decode_per_ctx * ctx_sum)
        if pred > 0:
            self._decode_gain = 0.7 * self._decode_gain + 0.3 * dt / pred

    def prefill_time(self, lens: List[int],
                     kv_prefix_lens: Optional[List[int]] = None) -> float:
        if not lens:
            return 0.0
        tokens = sum(lens) + (sum(kv_prefix_lens) if kv_prefix_lens else 0)
        return self._prefill_gain * (self._prefill_base
                                     + self._prefill_per_tok * tokens)

    def decode_time(self, batch: int, ctx_lens: Optional[List[int]] = None,
                    *, ctx_sum: Optional[int] = None) -> float:
        if batch == 0:
            return 0.0
        if ctx_sum is None:
            ctx_sum = sum(ctx_lens) if ctx_lens else 0
        return self._decode_gain * (self._decode_base
                                    + self._decode_per_seq * batch
                                    + self._decode_per_ctx * ctx_sum)


class ServingEngine:
    """Slot-based continuous batching with a fixed-shape decode step (no
    recompilation as requests come and go)."""

    def __init__(self, cfg: ModelConfig, params=None, seed: int = 0,
                 econf: EngineConfig = EngineConfig(),
                 cost_model=None, recorder=None):
        assert not cfg.is_encoder, "decode engine serves decoder models"
        self.cfg = cfg
        self.econf = econf
        self.params = params if params is not None else init_params(
            jax.random.key(seed), cfg, econf.dtype)
        B, S = econf.max_batch, econf.max_seq_len
        self.cache = init_cache(cfg, B, max_len=S, dtype=econf.dtype)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = np.zeros(B, np.int32)          # context per slot
        self.slot_req: List[Optional[Request]] = [None] * B
        if cost_model is None:
            from repro.simulator.cost_model import (InstanceCostModel,
                                                    TPU_V5E_SIM)
            cost_model = InstanceCostModel(cfg=cfg, hw=TPU_V5E_SIM)
        self.executor = MeasuredExecutor(seed_model=cost_model)
        self.recorder = recorder      # optional CalibrationRecorder

        self._prefill_fn = jax.jit(self._prefill_impl)
        self._decode_fn = jax.jit(self._decode_impl, donate_argnums=(1,))

    # --------------------------------------------------------------- #
    def _prefill_impl(self, params, toks):
        logits, cache = forward(params, self.cfg, {"tokens": toks},
                                return_cache=True)
        return logits[:, -1], cache

    def _decode_impl(self, params, cache, toks, lengths):
        logits, cache = forward(params, self.cfg, {"tokens": toks},
                                cache=cache, cache_len=lengths)
        return logits[:, 0], cache

    # --------------------------------------------------------------- #
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def prefill(self, req: Request) -> int:
        """Run the prompt through the model, land the request in a decode
        slot.  Returns the generated first token."""
        slots = self.free_slots()
        assert slots, "no free decode slot"
        slot = slots[0]
        prompt = req.prompt_tokens
        t0 = time.perf_counter()
        toks = jnp.asarray(np.array(prompt, np.int32))[None, :]
        logits, pcache = self._prefill_fn(self.params, toks)
        first = int(jnp.argmax(logits[0]))
        pcache = grow_cache(self.cfg, pcache, self.econf.max_seq_len)
        self.cache = _merge_slot(self.cfg, self.cache, pcache, slot)
        dt = time.perf_counter() - t0
        self.executor.observe_prefill(len(prompt), dt)
        if self.recorder is not None:
            self.recorder.record_prefill(len(prompt), dt)

        self.lengths[slot] = len(prompt)
        self.slot_req[slot] = req
        self.tokens = self.tokens.at[slot, 0].set(first)
        req.generated = [first]
        return first

    def decode_step(self) -> Dict[int, int]:
        """One decode iteration over all occupied slots.  Returns
        {slot: token} for slots that produced a token."""
        occupied = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not occupied:
            return {}
        t0 = time.perf_counter()
        lengths = jnp.asarray(self.lengths)
        logits, self.cache = self._decode_fn(
            self.params, self.cache, self.tokens, lengths)
        new_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        dt = time.perf_counter() - t0
        ctx_sum = int(sum(self.lengths[i] for i in occupied))
        self.executor.observe_decode(dt, batch=len(occupied),
                                     ctx_sum=ctx_sum)
        if self.recorder is not None:
            self.recorder.record_decode(len(occupied), ctx_sum, dt)

        out: Dict[int, int] = {}
        for i in occupied:
            tok = int(new_tokens[i])
            self.lengths[i] += 1
            out[i] = tok
            req = self.slot_req[i]
            req.generated.append(tok)
            self.tokens = self.tokens.at[i, 0].set(tok)
            done = (tok == self.econf.eos_token
                    or len(req.generated) >= req.output_len
                    or self.lengths[i] >= self.econf.max_seq_len - 1)
            if done:
                self.slot_req[i] = None
                self.lengths[i] = 0
        return out

    def release(self, req: Request) -> None:
        """Free the slot holding ``req`` (scheduler-side early finish,
        e.g. a one-token request done at prefill)."""
        for i, r in enumerate(self.slot_req):
            if r is req:
                self.slot_req[i] = None
                self.lengths[i] = 0
                return


def _merge_slot(cfg, big_cache, pcache, slot: int):
    """Write a prefill-produced (B=1) cache into batch slot `slot`."""
    def merge(big, small):
        # identify the batch axis: scan leaves are (n_full, B, ...) and the
        # single-request cache has B == 1 there; tail leaves are (B, ...)
        axis = 1 if (big.ndim >= 2 and small.ndim == big.ndim
                     and small.shape[0] == big.shape[0]
                     and small.shape[1] == 1) else 0
        # pad small's seq dim up to big's if needed
        pads = []
        for d in range(big.ndim):
            if d == axis:
                pads.append((0, 0))
            else:
                pads.append((0, big.shape[d] - small.shape[d]))
        small = jnp.pad(small, pads)
        idx = [slice(None)] * big.ndim
        idx[axis] = slice(slot, slot + 1)
        return big.at[tuple(idx)].set(small)

    return jax.tree.map(merge, big_cache, pcache)
