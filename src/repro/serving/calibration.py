"""Sim-to-real calibration: fit cost-model constants from engine timings.

The live engine (``repro.serving.engine``) and the fake replay backend
(``repro.serving.replay``) both report per-op step timings into a
``CalibrationRecorder``: prefill as (tokens, dt) pairs and decode as
(batch, ctx_sum, dt) triples.  ``fit_constants`` least-squares-fits the
same linear forms ``simulator.cost_model.FittedExecutor`` evaluates, and
``CalibrationReport`` compares an analytic model's predictions against
the measurements (per-op relative error, unfitted vs fitted) in a
JSON-safe shape pinned by ``tests/golden/calibration_report.json``.

Deliberately import-light: numpy + the cost model only, never jax — the
simulator runner's worker processes load fitted constants through
``load_fitted_executor`` and must not pay (or require) a jax import.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.events import NULL_TRACER
from repro.simulator.cost_model import (FITTED_CONSTANT_FIELDS,  # noqa: F401
                                        FittedExecutor, InstanceCostModel)


class CalibrationRecorder:
    """Accumulates per-op engine timings for fitting and error reports.

    When a flight-recorder ``tracer`` is attached, every sample is also
    emitted as an ``op`` event — the same bus the simulator runs on, so
    sim-vs-real disagreement can be localized to a specific op/span
    rather than a run-level scalar."""

    tracer = NULL_TRACER

    def __init__(self) -> None:
        self.prefill: List[Tuple[int, float]] = []      # (tokens, dt)
        self.decode: List[Tuple[int, int, float]] = []  # (batch, ctx_sum, dt)

    def record_prefill(self, tokens: int, dt: float) -> None:
        self.prefill.append((int(tokens), float(dt)))
        trc = self.tracer
        if trc.enabled:
            trc.op(trc.now(), "prefill", int(tokens), 0, float(dt))

    def record_decode(self, batch: int, ctx_sum: int, dt: float) -> None:
        self.decode.append((int(batch), int(ctx_sum), float(dt)))
        trc = self.tracer
        if trc.enabled:
            trc.op(trc.now(), "decode", int(batch), int(ctx_sum), float(dt))

    def __len__(self) -> int:
        return len(self.prefill) + len(self.decode)


def fit_constants(rec: CalibrationRecorder) -> Dict[str, float]:
    """Least-squares fit of the FittedExecutor linear forms.

    prefill: dt ~ base + per_token * tokens
    decode:  dt ~ base + per_seq * batch + per_ctx_token * ctx_sum

    Negative coefficients are clamped to zero (a timing model must be
    monotone in work); degenerate sample sets (every prefill the same
    length, or too few rows for the design matrix) fall back to a pure
    per-token median so the fit never explodes.
    """
    out: Dict[str, float] = {}

    if rec.prefill:
        toks = np.array([t for t, _ in rec.prefill], dtype=float)
        dts = np.array([d for _, d in rec.prefill], dtype=float)
        if len(rec.prefill) >= 2 and len(set(toks.tolist())) >= 2:
            design = np.stack([np.ones_like(toks), toks], axis=1)
            coef, *_ = np.linalg.lstsq(design, dts, rcond=None)
            base, per_tok = float(coef[0]), float(coef[1])
        else:
            base, per_tok = 0.0, float(np.median(dts / np.maximum(toks, 1)))
        out["prefill_base"] = max(base, 0.0)
        out["prefill_per_token"] = max(per_tok, 0.0)

    if rec.decode:
        batch = np.array([b for b, _, _ in rec.decode], dtype=float)
        ctx = np.array([c for _, c, _ in rec.decode], dtype=float)
        dts = np.array([d for _, _, d in rec.decode], dtype=float)
        design = np.stack([np.ones_like(batch), batch, ctx], axis=1)
        if len(rec.decode) >= 3 and np.linalg.matrix_rank(design) == 3:
            coef, *_ = np.linalg.lstsq(design, dts, rcond=None)
            base, per_seq, per_ctx = (float(coef[0]), float(coef[1]),
                                      float(coef[2]))
        else:
            base = 0.0
            per_seq = float(np.median(dts / np.maximum(batch, 1)))
            per_ctx = 0.0
        out["decode_base"] = max(base, 0.0)
        out["decode_per_seq"] = max(per_seq, 0.0)
        out["decode_per_ctx_token"] = max(per_ctx, 0.0)

    return out


# --------------------------------------------------------------------- #
def _predict_prefill(model, tokens: int) -> float:
    return model.prefill_time([tokens])


def _predict_decode(model, batch: int, ctx_sum: int) -> float:
    try:
        return model.decode_time(batch, ctx_sum=ctx_sum)
    except TypeError:
        # shape-only executors without the ctx_sum keyword fast path
        return model.decode_time(batch, [ctx_sum])


def _rel_errors(rec: CalibrationRecorder, model) -> Tuple[List[float],
                                                          List[float]]:
    """Per-op |predicted - measured| / measured, prefill and decode."""
    pre = [abs(_predict_prefill(model, t) - dt) / dt
           for t, dt in rec.prefill if dt > 0]
    dec = [abs(_predict_decode(model, b, c) - dt) / dt
           for b, c, dt in rec.decode if dt > 0]
    return pre, dec


def _quantiles(pre: List[float], dec: List[float]) -> Dict[str, float]:
    def q(xs: List[float], p: float) -> float:
        return float(np.quantile(np.array(xs), p)) if xs else 0.0
    both = pre + dec
    return {
        "prefill_median": q(pre, 0.5), "prefill_p90": q(pre, 0.9),
        "decode_median": q(dec, 0.5), "decode_p90": q(dec, 0.9),
        "overall_median": q(both, 0.5),
    }


@dataclasses.dataclass
class CalibrationReport:
    """JSON-safe comparison of measured step times vs model predictions."""
    n_prefill: int
    n_decode: int
    unfitted: Dict[str, float]   # rel-error quantiles of the analytic model
    fitted: Dict[str, float]     # rel-error quantiles after the lstsq fit
    constants: Dict[str, float]  # the fitted FittedExecutor constants
    meta: Dict = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, rec: CalibrationRecorder, model,
              like: Optional[InstanceCostModel] = None,
              meta: Optional[Dict] = None) -> "CalibrationReport":
        consts = fit_constants(rec)
        fitted_model = FittedExecutor.from_constants(
            consts, like=like if like is not None else
            (model if isinstance(model, InstanceCostModel) else None))
        un_pre, un_dec = _rel_errors(rec, model)
        fi_pre, fi_dec = _rel_errors(rec, fitted_model)
        return cls(
            n_prefill=len(rec.prefill), n_decode=len(rec.decode),
            unfitted=_quantiles(un_pre, un_dec),
            fitted=_quantiles(fi_pre, fi_dec),
            constants=fitted_model.to_json(),
            meta=dict(meta or {}))

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "CalibrationReport":
        return cls(n_prefill=d["n_prefill"], n_decode=d["n_decode"],
                   unfitted=dict(d["unfitted"]), fitted=dict(d["fitted"]),
                   constants=dict(d["constants"]), meta=dict(d.get("meta",
                                                                   {})))

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")


def load_report(path) -> CalibrationReport:
    with open(path) as fh:
        return CalibrationReport.from_dict(json.load(fh))


def load_fitted_executor(path, like: Optional[InstanceCostModel] = None
                         ) -> FittedExecutor:
    """Runner hook: turn a saved CalibrationReport into the executor a
    simulator cell schedules with (``ExperimentRunner.calibration``)."""
    report = load_report(path)
    return FittedExecutor.from_constants(report.constants, like=like)


# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SyntheticTruth:
    """Deterministic 'ground truth' executor for fake-backend calibration:
    an affine warp of a base analytic model, so the fitted constants have
    a known target and the calibration golden is reproducible without
    hardware."""
    base: object
    prefill_scale: float = 1.0
    prefill_offset: float = 0.0
    decode_scale: float = 1.0
    decode_offset: float = 0.0

    def prefill_time(self, prompt_lens, kv_prefix_lens=None) -> float:
        if not prompt_lens:
            return 0.0
        return (self.prefill_scale
                * self.base.prefill_time(prompt_lens, kv_prefix_lens)
                + self.prefill_offset)

    def decode_time(self, batch_size, ctx_lens=None, *,
                    ctx_sum=None) -> float:
        if batch_size == 0:
            return 0.0
        return (self.decode_scale
                * _predict_decode(self.base, batch_size,
                                  ctx_sum if ctx_sum is not None
                                  else sum(ctx_lens or []))
                + self.decode_offset)
