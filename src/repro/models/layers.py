"""Composable transformer building blocks (pure functional JAX).

Every function takes an explicit params dict and returns arrays; no
global state.  Blocks come in four kinds (see ``repro.configs.base``):
global attention, sliding-window attention, RG-LRU (Griffin), and RWKV-6.

Attention is computed blockwise over query chunks (flash-style online
softmax) so 32k-token prefills never materialize a (T, T) score matrix.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

try:                                   # jax >= 0.5 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:                 # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

Params = Dict[str, Any]

Q_CHUNK = 512          # query chunk for blockwise attention
RWKV_CHUNK = 128       # chunk length for the chunked WKV recurrence
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Axis names of the active mesh (None -> single-device execution).

    kv_shard selects the KV-cache layout:
      * "heads":    (B, S, kv->model, hd)  — replicates when kv % model != 0
      * "head_dim": (B, S, kv, hd->model)  — always divides (hd is 128/256);
        QK^T becomes a partial-sum contraction (one small score all-reduce
        per layer) but the cache shards fully (§Perf hillclimb variant)
    """
    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    kv_shard: str = "heads"
    fsdp_params: bool = False   # additionally shard weights over batch axes
    unroll_layers: bool = False  # python loop instead of lax.scan (lets
    #                              FSDP gathers stay per-layer inside)
    remat_group: int = 1         # checkpoint every G cycles (sqrt-L remat)
    #                              instead of every cycle — §Perf H4

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]


# --------------------------------------------------------------------------- #
# Small primitives
# --------------------------------------------------------------------------- #
def rms_norm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def _head_rms_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm over the last (head_dim) axis; x: (..., heads, head_dim)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def soft_cap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# --------------------------------------------------------------------------- #
# Rotary embeddings (full / half / mrope)
# --------------------------------------------------------------------------- #
def _rope_freqs(head_dim: int, theta: float, n_freq: int) -> jnp.ndarray:
    exponent = jnp.arange(0, n_freq, dtype=jnp.float32) / n_freq
    return 1.0 / (theta ** exponent)


def _apply_rotary(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (..., 2*n_freq) pairs-first layout; angles: broadcastable (..., n_freq)."""
    n = angles.shape[-1]
    x1, x2 = x[..., :n], x[..., n:2 * n]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if x.shape[-1] > 2 * n:  # "half" rope: pass the rest through
        rotated = jnp.concatenate([rotated, x[..., 2 * n:]], axis=-1)
    return rotated


def apply_rope(cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, heads, head_dim); positions: (B, T) or (B, T, 3) for mrope."""
    hd = x.shape[-1]
    if cfg.rope == "none":
        return x
    if cfg.rope == "half":
        n_freq = hd // 4          # rotary on the first half of head_dim
    else:
        n_freq = hd // 2
    freqs = _rope_freqs(hd, cfg.rope_theta, n_freq)
    if cfg.rope == "mrope":
        # Split frequency slots into (temporal, height, width) sections 2:1:1.
        s1 = n_freq // 2
        s2 = (n_freq - s1) // 2
        s3 = n_freq - s1 - s2
        pos = positions.astype(jnp.float32)           # (B, T, 3)
        ang = jnp.concatenate(
            [
                pos[..., 0:1] * freqs[:s1],
                pos[..., 1:2] * freqs[s1:s1 + s2],
                pos[..., 2:3] * freqs[s1 + s2:],
            ],
            axis=-1,
        )                                             # (B, T, n_freq)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, T, n_freq)
    return _apply_rotary(x, ang[:, :, None, :])       # broadcast over heads


# --------------------------------------------------------------------------- #
# Blockwise (flash-style) attention — prefill / training path
# --------------------------------------------------------------------------- #
def blockwise_attention(
    q: jnp.ndarray,                # (B, T, Hq, D)
    k: jnp.ndarray,                # (B, S, Hkv, D)
    v: jnp.ndarray,                # (B, S, Hkv, D)
    *,
    causal: bool,
    window: int = 0,               # 0 -> unbounded
    q_offset: int = 0,             # absolute position of q[0] (chunked prefill)
    kv_valid_len: Optional[jnp.ndarray] = None,  # (B,) valid kv length
    q_chunk: int = Q_CHUNK,
) -> jnp.ndarray:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv                    # query heads per kv head (GQA group)
    scale = D ** -0.5

    q_chunk = min(q_chunk, T)
    n_chunks = -(-T // q_chunk)
    pad = n_chunks * q_chunk - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # grouped-query layout: never materialize a repeated KV cache
    qc = q.reshape(B, n_chunks, q_chunk, Hkv, G, D)

    kv_pos = jnp.arange(S)[None, :]                          # (1, S)

    def chunk_fn(carry, inputs):
        idx, q_blk = inputs                            # (B, qc, Hkv, G, D)
        q_pos = q_offset + idx * q_chunk + jnp.arange(q_chunk)  # (qc,)
        s = jnp.einsum("bqhgd,bshd->bhgqs", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((B, q_chunk, S), dtype=bool)
        if causal:
            mask &= kv_pos[None] <= q_pos[None, :, None]
        if window:
            mask &= kv_pos[None] > q_pos[None, :, None] - window
        if kv_valid_len is not None:
            mask &= kv_pos < kv_valid_len[:, None, None]
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        att = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqs,bshd->bqhgd", att, v,
                         preferred_element_type=jnp.float32)
        return carry, out.astype(v.dtype)

    _, outs = jax.lax.scan(chunk_fn, None, (jnp.arange(n_chunks),
                                            jnp.swapaxes(qc, 0, 1)))
    out = jnp.swapaxes(outs, 0, 1).reshape(B, n_chunks * q_chunk, Hq, D)
    return out[:, :T]


def decode_attention_jnp(
    q: jnp.ndarray,                # (B, 1, Hq, D)
    k_cache: jnp.ndarray,          # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    kv_valid_len: jnp.ndarray,     # (B,) number of valid cache entries
) -> jnp.ndarray:
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = jnp.arange(S)[None, :] < kv_valid_len[:, None]    # (B, S)
    s = jnp.where(mask[:, None, None, None], s, NEG_INF)
    att = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", att, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Attention block (global or sliding-window)
# --------------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, hq * hd), dtype) * std,
        "wk": jax.random.normal(k2, (d, hkv * hd), dtype) * std,
        "wv": jax.random.normal(k3, (d, hkv * hd), dtype) * std,
        "wo": jax.random.normal(k4, (hq * hd, d), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attention_block(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                     # (B, T, d)
    positions: jnp.ndarray,             # (B, T) or (B, T, 3)
    *,
    window: int,                        # 0 for global
    layer_cache: Optional[Params],      # {"k","v"} or None
    cache_len: Optional[jnp.ndarray],   # (B,) tokens already in cache
    mi: MeshInfo,
    return_cache: bool,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, T, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, T, hq, hd)
    k = k.reshape(B, T, hkv, hd)
    v = v.reshape(B, T, hkv, hd)
    if cfg.qk_norm:
        q = _head_rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = _head_rms_norm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    if mi.model_axis is not None:
        if mi.kv_shard == "head_dim":
            spec = P(*_bspec(mi), None, None, mi.model_axis)
        else:
            spec = P(*_bspec(mi), None, mi.model_axis, None)
        q = jax.lax.with_sharding_constraint(q, spec)
        k = jax.lax.with_sharding_constraint(k, spec)
        v = jax.lax.with_sharding_constraint(v, spec)

    new_cache = None
    if layer_cache is not None and T == 1:
        # ---- decode: scatter kv into the cache ring and attend over it ----
        S = layer_cache["k"].shape[1]
        idx = (cache_len % S).astype(jnp.int32)              # ring index (B,)
        bidx = jnp.arange(B)
        k_cache = layer_cache["k"].at[bidx, idx].set(k[:, 0])
        v_cache = layer_cache["v"].at[bidx, idx].set(v[:, 0])
        valid = jnp.minimum(cache_len + 1, S)
        out = decode_attention_jnp(q, k_cache, v_cache, valid)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        # ---- prefill / training: blockwise attention over this sequence ----
        causal = not cfg.is_encoder
        out = blockwise_attention(q, k, v, causal=causal, window=window)
        if return_cache:
            if window and window < T:
                # keep only the trailing window in a ring-ordered buffer:
                # position p lives at slot p % window
                tail = jax.lax.dynamic_slice_in_dim(k, T - window, window, axis=1)
                tailv = jax.lax.dynamic_slice_in_dim(v, T - window, window, axis=1)
                shift = T % window
                k_ring = jnp.roll(tail, shift, axis=1)
                v_ring = jnp.roll(tailv, shift, axis=1)
                new_cache = {"k": k_ring, "v": v_ring}
            elif window and window > T:
                # ring buffer sized `window`, slots T..W-1 still empty
                padw = ((0, 0), (0, window - T), (0, 0), (0, 0))
                new_cache = {"k": jnp.pad(k, padw), "v": jnp.pad(v, padw)}
            else:
                new_cache = {"k": k, "v": v}

    out = out.reshape(B, T, hq * hd)
    return out @ params["wo"], new_cache


def _bspec(mi: MeshInfo):
    return (mi.batch_axes,) if mi.batch_axes else (None,)


# --------------------------------------------------------------------------- #
# Gated MLP (SwiGLU)
# --------------------------------------------------------------------------- #
def init_mlp(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d, f), dtype) * std,
        "w_up": jax.random.normal(k2, (d, f), dtype) * std,
        "w_down": jax.random.normal(k3, (f, d), dtype) * (f ** -0.5),
    }


def mlp_block(params: Params, x: jnp.ndarray, mi: MeshInfo) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    if mi.model_axis is not None:
        h = jax.lax.with_sharding_constraint(
            h, P(*_bspec(mi), None, mi.model_axis))
    return h @ params["w_down"]


# --------------------------------------------------------------------------- #
# Mixture of Experts — expert parallelism over the `model` axis
# --------------------------------------------------------------------------- #
def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    return {
        "router": jax.random.normal(k1, (d, e), dtype) * std,
        "w_gate": jax.random.normal(k2, (e, d, f), dtype) * std,
        "w_up": jax.random.normal(k3, (e, d, f), dtype) * std,
        "w_down": jax.random.normal(k4, (e, f, d), dtype) * (f ** -0.5),
    }


def _moe_local(params: Params, cfg: ModelConfig, x: jnp.ndarray,
               expert_lo: int, n_local: int) -> jnp.ndarray:
    """Capacity-routed MoE over experts [expert_lo, expert_lo+n_local).

    x: (T, d) local tokens.  Returns the partial output contributed by the
    local experts only (caller psums across expert shards).
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    cap = max(1, int(T * k / E * cfg.capacity_factor))

    logits = (x @ params["router"]).astype(jnp.float32)       # (T, E)
    weights, experts = jax.lax.top_k(logits, k)               # (T, k)
    weights = jax.nn.softmax(weights, axis=-1)

    # position of each (token, choice) within its expert's queue
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)      # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                     # exclusive cumsum
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)          # (T, k)
    keep = pos < cap

    out = jnp.zeros((T, d), jnp.float32)
    for j in range(n_local):
        e = expert_lo + j
        sel = (experts == e) & keep                           # (T, k)
        # slot of each token in expert e's buffer (cap entries)
        slot = jnp.where(sel, pos, cap)                       # cap = dropped
        slot_t = jnp.min(slot, axis=-1)                       # (T,)
        w_t = jnp.sum(jnp.where(sel, weights, 0.0), axis=-1)  # (T,)
        buf = jnp.zeros((cap + 1, d), x.dtype).at[slot_t].add(x)
        buf = buf[:cap]
        h = jax.nn.silu(buf @ params["w_gate"][j]) * (buf @ params["w_up"][j])
        eo = (h @ params["w_down"][j]).astype(jnp.float32)    # (cap, d)
        # gather back: token t reads buffer slot slot_t (if kept)
        gathered = jnp.take(jnp.vstack([eo, jnp.zeros((1, d))]),
                            jnp.minimum(slot_t, cap), axis=0)
        out = out + gathered * w_t[:, None]
    return out


def _moe_local_wtp(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                   expert_lo: int, n_local: int,
                   d_idx, n_d: int, model_axis: str,
                   data_axes) -> jnp.ndarray:
    """Weight-tensor-parallel MoE for the batch-replicated case (batch=1
    long-context decode): each expert's d_model contraction is split over
    the otherwise-idle data axes.  Partial matmuls + psum reconstruct the
    exact math; expert weights shard model*data ways (16x memory).
    Returns the FULL (already psum'ed over model+data) output.
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    f = cfg.d_ff
    cap = max(1, int(T * k / E * cfg.capacity_factor))
    d_loc, f_loc = d // n_d, f // n_d

    logits = (x @ params["router"]).astype(jnp.float32)     # router replicated
    weights, experts = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(weights, axis=-1)
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(pos * flat, axis=-1).reshape(T, k)
    keep = pos < cap

    x_slice = jax.lax.dynamic_slice_in_dim(x, d_idx * d_loc, d_loc, axis=1)
    out = jnp.zeros((T, d), jnp.float32)
    for j in range(n_local):
        e = expert_lo + j
        sel = (experts == e) & keep
        slot = jnp.where(sel, pos, cap)
        slot_t = jnp.min(slot, axis=-1)
        w_t = jnp.sum(jnp.where(sel, weights, 0.0), axis=-1)
        buf = jnp.zeros((cap + 1, d_loc), x.dtype).at[slot_t].add(x_slice)
        buf = buf[:cap]
        # partial over the d_in contraction -> psum over data axes
        a = jax.lax.psum(buf @ params["w_gate"][j], data_axes)
        b = jax.lax.psum(buf @ params["w_up"][j], data_axes)
        h = jax.nn.silu(a) * b                               # (cap, f) full
        h_slice = jax.lax.dynamic_slice_in_dim(
            h, d_idx * f_loc, f_loc, axis=1)
        eo = (h_slice @ params["w_down"][j]).astype(jnp.float32)  # partial
        gathered = jnp.take(jnp.vstack([eo, jnp.zeros((1, d))]),
                            jnp.minimum(slot_t, cap), axis=0)
        out = out + gathered * w_t[:, None]
    # partial over (f contraction x expert shards)
    return jax.lax.psum(out, (model_axis,) + tuple(data_axes))


def moe_block(params: Params, cfg: ModelConfig, x: jnp.ndarray,
              mi: MeshInfo) -> jnp.ndarray:
    """MoE FFN; experts sharded over the `model` axis via shard_map.

    Activations are replicated across the model axis (Megatron pattern), so
    each model shard routes all its data-shard tokens to *its own* experts
    and the shards' partial outputs are psum'ed — one all-reduce per MoE
    layer, no all-to-all.

    When the batch cannot use the data axes (batch=1 decode) and
    ``mi.fsdp_params`` is set, expert weights additionally split their
    contraction dims over the data axes (weight tensor parallelism) —
    §Perf H3 variant.
    """
    B, T, d = x.shape
    E = cfg.num_experts

    if mi.mesh is None or mi.model_axis is None:
        y = _moe_local(params, cfg, x.reshape(B * T, d), 0, E)
        return y.reshape(B, T, d).astype(x.dtype)

    n_model = mi.model_size
    if E % n_model != 0:
        # experts don't divide the model axis: replicate them and compute
        # the full MoE on every shard (only hit in reduced smoke settings)
        y = _moe_local(params, cfg, x.reshape(B * T, d), 0, E)
        return y.reshape(B, T, d).astype(x.dtype)
    n_local = E // n_model
    batch_ok = bool(mi.batch_axes) and B % _axes_size(mi) == 0
    bspec = mi.batch_axes if batch_ok else None

    data_axes = tuple(a for a in mi.mesh.axis_names if a != mi.model_axis)
    n_d = 1
    for a in data_axes:
        n_d *= mi.mesh.shape[a]
    use_wtp = (mi.fsdp_params and not batch_ok and n_d > 1
               and d % n_d == 0 and cfg.d_ff % n_d == 0)

    def local_fn(p_loc, x_loc):
        lo = jax.lax.axis_index(mi.model_axis) * n_local
        Bl, Tl, _ = x_loc.shape
        if use_wtp:
            d_idx = jnp.zeros((), jnp.int32)
            mult = 1
            for a in reversed(data_axes):
                d_idx = d_idx + jax.lax.axis_index(a) * mult
                mult *= mi.mesh.shape[a]
            y = _moe_local_wtp(p_loc, cfg, x_loc.reshape(Bl * Tl, d),
                               lo, n_local, d_idx, n_d, mi.model_axis,
                               data_axes)
        else:
            y = _moe_local(p_loc, cfg, x_loc.reshape(Bl * Tl, d),
                           lo, n_local)
            y = jax.lax.psum(y, mi.model_axis)
        return y.reshape(Bl, Tl, d).astype(x_loc.dtype)

    pspec = {
        "router": P(),
        "w_gate": P(mi.model_axis, data_axes if use_wtp else None, None),
        "w_up": P(mi.model_axis, data_axes if use_wtp else None, None),
        "w_down": P(mi.model_axis, data_axes if use_wtp else None, None),
    }
    y = _shard_map(
        local_fn,
        mesh=mi.mesh,
        in_specs=(
            {k: pspec[k] for k in params},
            P(bspec, None, None),
        ),
        out_specs=P(bspec, None, None),
    )(params, x)
    return y


def _axes_size(mi: MeshInfo) -> int:
    n = 1
    for a in mi.batch_axes:
        n *= mi.mesh.shape[a]
    return n


# --------------------------------------------------------------------------- #
# RG-LRU recurrent block (Griffin / RecurrentGemma)
# --------------------------------------------------------------------------- #
CONV_WIDTH = 4
RGLRU_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "w_x": jax.random.normal(ks[0], (d, d), dtype) * std,
        "w_gate": jax.random.normal(ks[1], (d, d), dtype) * std,
        "w_out": jax.random.normal(ks[2], (d, d), dtype) * std,
        "conv_w": jax.random.normal(ks[3], (CONV_WIDTH, d), dtype) * 0.1,
        "w_in_gate": jax.random.normal(ks[4], (d, d), dtype) * std,
        "w_rec_gate": jax.random.normal(ks[5], (d, d), dtype) * std,
        "lambda": jnp.full((d,), 1.0, dtype),   # softplus(1.0) ~ 1.31
    }


def _rglru_coeffs(params: Params, u: jnp.ndarray):
    """u: (..., d) conv output.  Returns (log_a, gated_input) in f32."""
    i_gate = jax.nn.sigmoid((u @ params["w_in_gate"]).astype(jnp.float32))
    r_gate = jax.nn.sigmoid((u @ params["w_rec_gate"]).astype(jnp.float32))
    log_a = -RGLRU_C * r_gate * jax.nn.softplus(
        params["lambda"].astype(jnp.float32))
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i_gate * u.astype(jnp.float32)
    return log_a, b


def rglru_scan_jnp(log_a: jnp.ndarray, b: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Associative scan of h_t = exp(log_a_t) * h_{t-1} + b_t over axis 1.

    log_a, b: (B, T, d) float32.  h0: (B, d) initial state or None.
    """
    if h0 is not None:
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(b.dtype), b], axis=1)

    def op(l, r):
        (la1, b1), (la2, b2) = l, r
        return la1 + la2, b1 * jnp.exp(la2) + b2

    _, h = jax.lax.associative_scan(op, (log_a, b), axis=1)
    return h[:, 1:] if h0 is not None else h


def rglru_block(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                    # (B, T, d)
    layer_cache: Optional[Params],     # {"conv": (B, W-1, d), "h": (B, d)}
    mi: MeshInfo,
    return_cache: bool,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, T, d = x.shape
    gate = jax.nn.gelu((x @ params["w_gate"]))
    xin = x @ params["w_x"]

    # temporal conv (width 4, causal)
    if layer_cache is not None and T == 1:
        hist = jnp.concatenate([layer_cache["conv"], xin], axis=1)  # (B, W, d)
        u = jnp.einsum("bwd,wd->bd", hist, params["conv_w"])[:, None]
        new_conv = hist[:, 1:]
    else:
        pad = jnp.zeros((B, CONV_WIDTH - 1, d), xin.dtype)
        hist = jnp.concatenate([pad, xin], axis=1)
        u = jnp.stack(
            [hist[:, i:i + T] for i in range(CONV_WIDTH)], axis=0)
        u = jnp.einsum("wbtd,wd->btd", u, params["conv_w"])
        new_conv = hist[:, -(CONV_WIDTH - 1):]

    log_a, b = _rglru_coeffs(params, u)
    if layer_cache is not None and T == 1:
        h_prev = layer_cache["h"].astype(jnp.float32)
        h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h.astype(x.dtype)}
    else:
        h0 = layer_cache["h"].astype(jnp.float32) if layer_cache else None
        y = rglru_scan_jnp(log_a, b, h0)
        new_cache = (
            {"conv": new_conv, "h": y[:, -1].astype(x.dtype)}
            if return_cache else None
        )
    out = (y.astype(x.dtype) * gate) @ params["w_out"]
    return out, new_cache


# --------------------------------------------------------------------------- #
# RWKV-6 (Finch) time-mix block with data-dependent decay
# --------------------------------------------------------------------------- #
DECAY_LORA = 64


def init_rwkv6(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 10)
    std = d ** -0.5
    return {
        "w_r": jax.random.normal(ks[0], (d, d), dtype) * std,
        "w_k": jax.random.normal(ks[1], (d, d), dtype) * std,
        "w_v": jax.random.normal(ks[2], (d, d), dtype) * std,
        "w_g": jax.random.normal(ks[3], (d, d), dtype) * std,
        "w_o": jax.random.normal(ks[4], (d, d), dtype) * std,
        "mu": jax.random.uniform(ks[5], (4, d), dtype),       # r,k,v,g shifts
        "decay_base": jnp.full((d,), -6.0, dtype),
        "decay_lora_a": jax.random.normal(ks[6], (d, DECAY_LORA), dtype) * std,
        "decay_lora_b": jax.random.normal(
            ks[7], (DECAY_LORA, d), dtype) * (DECAY_LORA ** -0.5),
        "bonus_u": jax.random.normal(ks[8], (cfg.num_heads, hd), dtype) * 0.1,
        "ln_out_scale": jnp.zeros((d,), dtype),
    }


def rwkv6_chunked_jnp(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,   # (B, T, H, D) f32
    w: jnp.ndarray,                                   # (B, T, H, D) decay in (0,1)
    u: jnp.ndarray,                                   # (H, D) bonus
    s0: Optional[jnp.ndarray] = None,                 # (B, H, D, D)
    chunk: int = RWKV_CHUNK,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked linear-attention form of the WKV6 recurrence.

    State S (per head, D_k x D_v):  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Output: o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1}).
    Returns (o: (B,T,H,D), final state).
    """
    B, T, H, D = r.shape
    n = -(-T // chunk)
    pad = n * chunk - T
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)

    rc = r.reshape(B, n, chunk, H, D)
    kc = k.reshape(B, n, chunk, H, D)
    vc = v.reshape(B, n, chunk, H, D)
    logw = jnp.log(jnp.maximum(w, 1e-12)).reshape(B, n, chunk, H, D)

    s_init = (jnp.zeros((B, H, D, D), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))

    def chunk_fn(S, inp):
        rb, kb, vb, lwb = inp          # (B, c, H, D)
        cum = jnp.cumsum(lwb, axis=1)                  # inclusive decay sums
        # decay from chunk start to just BEFORE step t:
        dec_in = jnp.exp(cum - lwb)                    # (B, c, H, D)
        # contribution of carried-in state: o_intra_state = r_t . (decayed S)
        r_dec = rb * dec_in
        o_state = jnp.einsum("bchd,bhde->bche", r_dec, S)
        # within-chunk token-to-token: A[t,s] = r_t . diag(decay s+1..t-1... )
        # k_s effective: k_s * exp(cum_t - cum_s)  for s < t
        kin = kb * jnp.exp(-(cum))                     # k_s / prod decay <= s
        att = jnp.einsum("bchd,bshd->bhcs", r_dec, kin)
        c_idx = jnp.arange(rb.shape[1])
        causal_mask = c_idx[:, None] > c_idx[None, :]  # strictly lower
        att = jnp.where(causal_mask[None, None], att, 0.0)
        o_intra = jnp.einsum("bhcs,bshd->bchd", att, vb)
        # bonus diagonal term
        o_diag = jnp.einsum("bchd,hd,bchd->bch", rb, u.astype(jnp.float32),
                            kb)[..., None] * vb
        # update state to end of chunk
        dec_all = jnp.exp(cum[:, -1])                  # (B, H, D)
        k_end = kb * jnp.exp(cum[:, -1][:, None] - cum)
        S_new = S * dec_all[..., None] + jnp.einsum(
            "bchd,bche->bhde", k_end, vb)
        return S_new, o_state + o_intra + o_diag

    xs = (jnp.swapaxes(rc, 0, 1), jnp.swapaxes(kc, 0, 1),
          jnp.swapaxes(vc, 0, 1), jnp.swapaxes(logw, 0, 1))
    S_fin, outs = jax.lax.scan(chunk_fn, s_init, xs)
    o = jnp.swapaxes(outs, 0, 1).reshape(B, n * chunk, H, D)[:, :T]
    return o, S_fin


def rwkv6_block(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                   # (B, T, d)
    layer_cache: Optional[Params],    # {"shift": (B, d), "state": (B,H,D,D)}
    mi: MeshInfo,
    return_cache: bool,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    B, T, d = x.shape
    H, D = cfg.num_heads, cfg.head_dim

    if layer_cache is not None and T == 1:
        x_prev = layer_cache["shift"][:, None]
    else:
        first = (layer_cache["shift"][:, None] if layer_cache
                 else jnp.zeros((B, 1, d), x.dtype))
        x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)

    mu = params["mu"]
    mix = lambda i: x * mu[i] + x_prev * (1.0 - mu[i])
    r = (mix(0) @ params["w_r"]).reshape(B, T, H, D).astype(jnp.float32)
    k = (mix(1) @ params["w_k"]).reshape(B, T, H, D).astype(jnp.float32)
    v = (mix(2) @ params["w_v"]).reshape(B, T, H, D).astype(jnp.float32)
    g = jax.nn.silu(mix(3) @ params["w_g"])

    # data-dependent decay (the Finch signature)
    dd = (x @ params["decay_lora_a"]) @ params["decay_lora_b"]
    logit = params["decay_base"].astype(jnp.float32) + dd.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logit)).reshape(B, T, H, D)          # in (0, 1)

    s0 = layer_cache["state"] if layer_cache is not None else None
    if layer_cache is not None and T == 1:
        # single-step recurrence
        S = s0.astype(jnp.float32)
        o = jnp.einsum("bhd,hd,bhd->bh", r[:, 0], params["bonus_u"].astype(
            jnp.float32), k[:, 0])[..., None] * v[:, 0]
        o = o + jnp.einsum("bhd,bhde->bhe", r[:, 0], S)
        S_new = S * w[:, 0][..., None] + jnp.einsum(
            "bhd,bhe->bhde", k[:, 0], v[:, 0])
        o = o[:, None]
        new_state = S_new
    else:
        o, new_state = rwkv6_chunked_jnp(r, k, v, w, params["bonus_u"])

    o = o.reshape(B, T, d).astype(x.dtype)
    # group norm over heads ~ rms per head group, simplified to rms over d
    o = rms_norm({"scale": params["ln_out_scale"]}, o, cfg.norm_eps)
    out = (o * g) @ params["w_o"]

    new_cache = None
    if return_cache or (layer_cache is not None and T == 1):
        new_cache = {"shift": x[:, -1], "state": new_state.astype(jnp.float32)}
    return out, new_cache


# --------------------------------------------------------------------------- #
# RWKV channel mix (used as the FFN for rwkv blocks)
# --------------------------------------------------------------------------- #
def init_channel_mix(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": jax.random.normal(k1, (d, f), dtype) * d ** -0.5,
        "w_out": jax.random.normal(k2, (f, d), dtype) * f ** -0.5,
    }


def channel_mix(params: Params, x: jnp.ndarray, mi: MeshInfo) -> jnp.ndarray:
    h = jnp.square(jax.nn.relu(x @ params["w_in"]))
    if mi.model_axis is not None:
        h = jax.lax.with_sharding_constraint(
            h, P(*_bspec(mi), None, mi.model_axis))
    return h @ params["w_out"]
