from repro.models.model import (  # noqa: F401
    MeshInfo,
    init_params,
    forward,
    init_cache,
    grow_cache,
    make_loss_fn,
)
