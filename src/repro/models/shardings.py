"""Partition-spec rules for params / optimizer state / caches / batches.

Megatron-style tensor parallelism over the ``model`` axis; batch over the
(``pod``,) ``data`` axes.  GSPMD pads non-divisible dims (e.g. 40 heads on a
16-way axis, GQA kv=8 on 16), which the roofline notes call out.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import MeshInfo

# leaf name -> spec builder(model_axis M) ------------------------------------
def _param_spec(path: Tuple[str, ...], leaf, M: str) -> P:
    name = path[-1]
    ndim = leaf.ndim - (1 if any(p == "layers_scan" for p in path) else 0)
    up = {"wq", "wk", "wv", "w_gate", "w_up", "w_x", "w_in_gate",
          "w_rec_gate", "w_r", "w_k", "w_v", "w_g", "w_in",
          "decay_lora_b"}
    down = {"wo", "w_down", "w_out", "w_o"}
    if name == "embed":
        return P(M, None)
    if name == "lm_head":
        return P(None, M)
    if name == "frontend":
        return P(None, None)
    if name == "router":
        return P()
    if name in up:
        if ndim == 3:                # moe expert weights (E, d, f)
            return P(M, None, None)
        return P(None, M)
    if name in down:
        if ndim == 3:                # (E, f, d)
            return P(M, None, None)
        return P(M, None)
    if name in ("bq", "bk", "bv", "lambda", "decay_base"):
        return P(M)
    if name == "conv_w":
        return P(None, M)
    if name == "bonus_u":
        return P(M, None)
    # norms, mu, lora_a, scales: replicated
    return P()


def _pad_scan_dim(path: Tuple[str, ...], spec: P) -> P:
    """Stacked scan params have a leading layer dim -> prepend None."""
    if any(p == "layers_scan" for p in path):
        return P(None, *spec)
    return spec


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return tuple(names)


def fit_spec(spec: P, shape, mi: MeshInfo) -> P:
    """Drop (replicate) axes whose mesh size does not divide the dim —
    explicit jit in_shardings require divisibility.  The replication cost
    (e.g. GQA kv=8 on a 16-way model axis) is visible in the roofline and
    attacked in the perf iterations."""
    if mi.mesh is None:
        return P()
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, parts):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mi.mesh.shape[a]
        out.append(ax if dim % n == 0 else None)
    return P(*out)


def param_pspecs(cfg: ModelConfig, params: Any, mi: MeshInfo) -> Any:
    M = mi.model_axis
    # FSDP sharding uses the mesh's non-model axes even when the batch
    # itself is too small to shard (e.g. batch=1 long-context decode)
    data_axes = mi.batch_axes
    if mi.fsdp_params and not data_axes and mi.mesh is not None:
        data_axes = tuple(a for a in mi.mesh.axis_names if a != M)

    def fn(path, leaf):
        names = _path_names(path)
        spec = _param_spec(names, leaf, M)
        spec = fit_spec(_pad_scan_dim(names, spec), leaf.shape, mi)
        if mi.fsdp_params and data_axes and leaf.size >= 1 << 20:
            # FSDP-style: shard the first still-replicated big dim over the
            # batch axes (XLA all-gathers the shard before use)
            parts = list(spec) + [None] * (leaf.ndim - len(spec))
            n = _size(mi, data_axes)
            for i, (dim, s) in enumerate(zip(leaf.shape, parts)):
                if s is None and dim % n == 0 and dim >= n:
                    parts[i] = data_axes
                    break
            spec = P(*parts)
        return spec

    return jax.tree_util.tree_map_with_path(fn, params)


def opt_state_pspecs(cfg: ModelConfig, params: Any, mi: MeshInfo,
                     zero1: bool = True) -> Any:
    """Adam m/v: param sharding + ZeRO-1-style extra sharding of the first
    still-replicated dim over the data axis (needed for 32B+ models)."""
    base = param_pspecs(cfg, params, mi)
    if not zero1 or not mi.batch_axes:
        return base
    data_axes = mi.batch_axes

    def widen(path, leaf, spec):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (dim, s) in enumerate(zip(leaf.shape, parts)):
            if s is None and dim % _size(mi, data_axes) == 0 and dim >= 1024:
                parts[i] = data_axes
                break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda p, l: widen(p, l, base_at(base, p)), params)


def base_at(tree, path):
    node = tree
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            node = node[p.key]
        elif isinstance(p, jax.tree_util.SequenceKey):
            node = node[p.idx]
    return node


def _size(mi: MeshInfo, axes) -> int:
    n = 1
    for a in axes:
        n *= mi.mesh.shape[a]
    return n


def cache_pspecs(cfg: ModelConfig, cache: Any, mi: MeshInfo,
                 shard_batch: bool) -> Any:
    """KV / state caches: batch over data axes, heads over model axis."""
    B = mi.batch_axes if shard_batch else None
    M = mi.model_axis

    def fn(path, leaf):
        names = _path_names(path)
        scan = "scan" in names
        name = names[-1]
        if name in ("k", "v"):                    # (B, S, kv, hd)
            spec = (P(B, None, None, M) if mi.kv_shard == "head_dim"
                    else P(B, None, M, None))
        elif name == "state":                     # (B, H, hd, hd)
            spec = P(B, M, None, None)
        elif name in ("conv", "h", "shift"):      # (B, ..., d) channel-wise
            spec = (P(B, None, M) if leaf.ndim - (1 if scan else 0) == 3
                    else P(B, M))
        else:  # pragma: no cover
            spec = P()
        spec = P(None, *spec) if scan else spec
        return fit_spec(spec, leaf.shape, mi)

    return jax.tree_util.tree_map_with_path(fn, cache)


def batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any], mi: MeshInfo,
                 shard_batch: bool) -> Dict[str, Any]:
    B = mi.batch_axes if shard_batch else None
    out = {}
    for k, v in batch.items():
        out[k] = fit_spec(P(B, *([None] * (v.ndim - 1))), v.shape, mi)
    return out


def to_named(tree_specs, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
