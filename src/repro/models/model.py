"""Composable model definition: init / forward / cache for all families.

Layers are stacked per cycle-position of ``cfg.block_pattern`` and executed
with ``lax.scan`` over full pattern cycles (remainder layers are unrolled),
with ``jax.checkpoint`` on the cycle body — this keeps 64-layer 512-device
lowering tractable and bounds activation memory.

Forward modes:
  * training / encoder forward:  full sequence, no cache
  * prefill:                     full sequence, returns a decode cache
  * decode:                      T == 1 step against an existing cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV6, ModelConfig
from repro.models import layers as L
from repro.models.layers import MeshInfo

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _init_block(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in (ATTN, LOCAL_ATTN):
        core = L.init_attention(k1, cfg, dtype)
    elif kind == RGLRU:
        core = L.init_rglru(k1, cfg, dtype)
    elif kind == RWKV6:
        core = L.init_rwkv6(k1, cfg, dtype)
    else:  # pragma: no cover
        raise ValueError(kind)
    if kind == RWKV6:
        ffn = L.init_channel_mix(k2, cfg, dtype)
    elif cfg.is_moe:
        ffn = L.init_moe(k2, cfg, dtype)
    else:
        ffn = L.init_mlp(k2, cfg, dtype)
    return {
        "norm1": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "core": core,
        "norm2": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "ffn": ffn,
    }


def _split_layers(cfg: ModelConfig) -> Tuple[int, int]:
    """(number of full pattern cycles scanned, number of tail layers)."""
    plen = len(cfg.block_pattern)
    n_full = cfg.num_layers // plen
    n_tail = cfg.num_layers - n_full * plen
    return n_full, n_tail


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    n_full, n_tail = _split_layers(cfg)
    plen = len(cfg.block_pattern)
    keys = jax.random.split(key, 4)

    params: Params = {
        "embed": jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), dtype) * 0.02,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), dtype) * 0.02
    if cfg.frontend_dim:
        params["frontend"] = jax.random.normal(
            keys[2], (cfg.frontend_dim, cfg.d_model), dtype) * 0.02

    layer_keys = jax.random.split(keys[3], cfg.num_layers)
    scan_params: Dict[str, Params] = {}
    for pos in range(plen):
        kind = cfg.block_pattern[pos]
        per_cycle = [
            _init_block(layer_keys[c * plen + pos], cfg, kind, dtype)
            for c in range(n_full)
        ]
        scan_params[f"pos{pos}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *per_cycle)
    params["layers_scan"] = scan_params
    params["layers_tail"] = tuple(
        _init_block(layer_keys[n_full * plen + i], cfg,
                    cfg.block_pattern[i % plen], dtype)
        for i in range(n_tail)
    )
    return params


# --------------------------------------------------------------------------- #
# Cache
# --------------------------------------------------------------------------- #
def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype) -> Params:
    if kind == ATTN:
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == LOCAL_ATTN:
        w = cfg.sliding_window
        shape = (batch, w, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == RGLRU:
        return {
            "conv": jnp.zeros((batch, L.CONV_WIDTH - 1, cfg.d_model), dtype),
            "h": jnp.zeros((batch, cfg.d_model), dtype),
        }
    if kind == RWKV6:
        return {
            "shift": jnp.zeros((batch, cfg.d_model), dtype),
            "state": jnp.zeros(
                (batch, cfg.num_heads, cfg.head_dim, cfg.head_dim),
                jnp.float32),
        }
    raise ValueError(kind)  # pragma: no cover


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> Params:
    n_full, n_tail = _split_layers(cfg)
    plen = len(cfg.block_pattern)
    scan_cache = {}
    for pos in range(plen):
        kind = cfg.block_pattern[pos]
        one = _block_cache(cfg, kind, batch, max_len, dtype)
        scan_cache[f"pos{pos}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_full,) + x.shape).copy(), one)
    tail_cache = tuple(
        _block_cache(cfg, cfg.block_pattern[i % plen], batch, max_len, dtype)
        for i in range(n_tail)
    )
    return {"scan": scan_cache, "tail": tail_cache}


def grow_cache(cfg: ModelConfig, cache: Params, max_len: int) -> Params:
    """Pad a prefill-returned cache so global-attention blocks have room for
    ``max_len`` total positions (local/ring + recurrent caches are fixed)."""
    plen = len(cfg.block_pattern)

    def pad_kv(kind, c, stacked):
        if kind != ATTN or c is None:
            return c
        axis = 2 if stacked else 1
        cur = c["k"].shape[axis]
        if cur >= max_len:
            return c
        pad = [(0, 0)] * c["k"].ndim
        pad[axis] = (0, max_len - cur)
        return {"k": jnp.pad(c["k"], pad), "v": jnp.pad(c["v"], pad)}

    scan = {
        f"pos{p}": pad_kv(cfg.block_pattern[p], cache["scan"][f"pos{p}"], True)
        for p in range(plen)
    } if cache["scan"] is not None else None
    tail = tuple(
        pad_kv(cfg.block_pattern[i % plen], c, False)
        for i, c in enumerate(cache["tail"]))
    return {"scan": scan, "tail": tail}


# --------------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------------- #
def _apply_block(
    kind: str,
    cfg: ModelConfig,
    bp: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    layer_cache: Optional[Params],
    cache_len: Optional[jnp.ndarray],
    mi: MeshInfo,
    return_cache: bool,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    h = L.rms_norm(bp["norm1"], x, cfg.norm_eps)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.sliding_window if kind == LOCAL_ATTN else 0
        core, new_cache = L.attention_block(
            bp["core"], cfg, h, positions, window=window,
            layer_cache=layer_cache, cache_len=cache_len, mi=mi,
            return_cache=return_cache)
    elif kind == RGLRU:
        core, new_cache = L.rglru_block(
            bp["core"], cfg, h, layer_cache, mi, return_cache)
    elif kind == RWKV6:
        core, new_cache = L.rwkv6_block(
            bp["core"], cfg, h, layer_cache, mi, return_cache)
    else:  # pragma: no cover
        raise ValueError(kind)
    x = x + core

    h = L.rms_norm(bp["norm2"], x, cfg.norm_eps)
    if kind == RWKV6:
        ffn = L.channel_mix(bp["ffn"], h, mi)
    elif cfg.is_moe:
        ffn = L.moe_block(bp["ffn"], cfg, h, mi)
    else:
        ffn = L.mlp_block(bp["ffn"], h, mi)
    return x + ffn, new_cache


def _default_positions(cfg: ModelConfig, batch: int, seqlen: int,
                       num_patches: int = 0) -> jnp.ndarray:
    if cfg.rope == "mrope":
        if num_patches:
            g = max(1, int(num_patches ** 0.5))
            pi = jnp.arange(num_patches)
            patch_pos = jnp.stack([jnp.zeros_like(pi), pi // g, pi % g], -1)
            tj = jnp.arange(seqlen - num_patches) + g
            text_pos = jnp.stack([tj, tj, tj], -1)
            pos = jnp.concatenate([patch_pos, text_pos], axis=0)
        else:
            t = jnp.arange(seqlen)
            pos = jnp.stack([t, t, t], -1)
        return jnp.broadcast_to(pos, (batch,) + pos.shape)
    return jnp.broadcast_to(jnp.arange(seqlen), (batch, seqlen))


def _embed_inputs(params: Params, cfg: ModelConfig,
                  batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    if cfg.modality == "audio":
        return batch["frames"] @ params["frontend"]
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.modality == "vision" and "patches" in batch:
        patch_emb = batch["patches"] @ params["frontend"]
        x = jnp.concatenate([patch_emb, x], axis=1)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    *,
    mi: MeshInfo = MeshInfo(),
    cache: Optional[Params] = None,
    cache_len: Optional[jnp.ndarray] = None,   # (B,) context length so far
    return_cache: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Returns (logits, new_cache).

    decode:  batch["tokens"] has T == 1 and ``cache``/``cache_len`` given.
    prefill: full sequence + return_cache=True.
    train:   full sequence, no cache.
    """
    x = _embed_inputs(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    decoding = cache is not None and T == 1

    if "positions" in batch:
        positions = batch["positions"]
    elif decoding:
        pos = cache_len[:, None]
        positions = (jnp.repeat(pos[..., None], 3, axis=-1)
                     if cfg.rope == "mrope" else pos)
    else:
        positions = _default_positions(
            cfg, B, T, batch.get("patches", jnp.zeros((1, 0))).shape[1]
            if cfg.modality == "vision" else 0)

    n_full, n_tail = _split_layers(cfg)
    plen = len(cfg.block_pattern)
    want_cache = return_cache or decoding

    def cycle_body(carry, xs):
        xcur = carry
        cyc_params, cyc_cache = xs
        new_caches = {}
        for pos in range(plen):
            kind = cfg.block_pattern[pos]
            lc = cyc_cache[f"pos{pos}"] if cyc_cache is not None else None
            xcur, nc = _apply_block(
                kind, cfg, cyc_params[f"pos{pos}"], xcur, positions,
                lc, cache_len, mi, want_cache)
            new_caches[f"pos{pos}"] = nc if nc is not None else 0
        return xcur, new_caches if want_cache else None

    scan_cache = cache["scan"] if cache is not None else None
    G = mi.remat_group
    if (n_full > 0 and G > 1 and n_full % G == 0 and cache is None
            and not want_cache):
        # sqrt-L remat: checkpoint every G cycles; activation checkpoints
        # drop from n_full to n_full/G at the cost of one extra forward of
        # each G-block during backward (§Perf H4)
        n_outer = n_full // G
        stacked = jax.tree.map(
            lambda a: a.reshape((n_outer, G) + a.shape[1:]),
            params["layers_scan"])

        def outer_body(xcur, xs_outer):
            # NESTED remat: the inner cycles must checkpoint too, else the
            # outer block's backward holds every cycle's internals live
            def inner(x2, xs):
                x2, _ = cycle_body(x2, (xs, None))
                return x2, None
            x2, _ = jax.lax.scan(jax.checkpoint(inner), xcur, xs_outer)
            return x2, None

        x, _ = jax.lax.scan(jax.checkpoint(outer_body), x, stacked)
        new_scan_cache = None
    elif n_full > 0:
        body = jax.checkpoint(cycle_body)
        if mi.unroll_layers:
            # python loop: per-layer FSDP all-gathers stay inside the step
            # (XLA hoists them out of a lax.scan, defeating the sharding)
            caches_per_cycle = []
            for c in range(n_full):
                cyc_p = jax.tree.map(lambda a: a[c], params["layers_scan"])
                cyc_c = (jax.tree.map(lambda a: a[c], scan_cache)
                         if scan_cache is not None else None)
                x, nc = body(x, (cyc_p, cyc_c))
                caches_per_cycle.append(nc)
            new_scan_cache = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *caches_per_cycle)
                if want_cache else None)
        else:
            x, new_scan_cache = jax.lax.scan(
                body, x, (params["layers_scan"], scan_cache))
    else:
        new_scan_cache = None

    new_tail = []
    for i in range(n_tail):
        kind = cfg.block_pattern[i % plen]
        lc = cache["tail"][i] if cache is not None else None
        x, nc = _apply_block(kind, cfg, params["layers_tail"][i], x,
                             positions, lc, cache_len, mi, want_cache)
        new_tail.append(nc)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    logits = L.soft_cap(logits, cfg.logit_soft_cap)

    new_cache = None
    if want_cache:
        new_cache = {"scan": new_scan_cache, "tail": tuple(new_tail)}
    return logits, new_cache


# --------------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------------- #
def make_loss_fn(cfg: ModelConfig, mi: MeshInfo = MeshInfo()):
    """Next-token CE for decoders; per-frame label CE for encoders."""

    def loss_fn(params, batch):
        logits, _ = forward(params, cfg, batch, mi=mi)
        labels = batch["labels"]
        if not cfg.is_encoder:
            logits = logits[:, :-1]
            labels = labels[:, 1:]
        if logits.shape[1] != labels.shape[1]:
            # vlm: patches were prepended; score only the text positions
            logits = logits[:, -labels.shape[1]:]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)

    return loss_fn
