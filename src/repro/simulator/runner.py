"""Unified experiment runner: strategy x scenario x rate sweeps.

``ExperimentRunner`` fans the cross product of serving strategies
(EcoServe/PaDG, vLLM-NoDG, Sarathi-NoDG, DistServe-FuDG, MoonCake-FuDG),
arrival scenarios (``repro.simulator.scenarios``), and request rates over
a ``multiprocessing`` pool.  Every cell derives its own RNG seed from
(base_seed, strategy, scenario, rate) via CRC32 — not Python's ``hash``,
which is salted per process — so the result grid is bit-exactly
reproducible regardless of worker count or scheduling order.  The grid
feeds ``benchmarks/bench_scenarios.py`` and the golden regression test in
``tests/test_scenarios.py``.
"""
from __future__ import annotations

import dataclasses
import json
import multiprocessing
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import (GPU_A800, GPU_L20, TPU_V5E_SIM,
                                        InstanceCostModel)
from repro.simulator.metrics import run_once
from repro.simulator.scenarios import SCENARIO_KINDS, make_scenario

HARDWARE = {"L20": GPU_L20, "A800": GPU_A800, "tpu-v5e": TPU_V5E_SIM}

# metrics kept in the persisted grid (attainment + tail latency summary)
SUMMARY_KEYS = ("attainment", "completion", "finished",
                "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")


def cell_seed(base_seed: int, strategy: str, scenario: str,
              rate: float) -> int:
    """Deterministic per-cell seed, stable across processes and runs."""
    key = f"{strategy}|{scenario}|{rate:.6f}".encode()
    return (zlib.crc32(key) ^ (base_seed * 2654435761)) & 0x7FFFFFFF


def _run_cell(spec: Dict) -> Dict:
    """Worker entry point: one (strategy, scenario, rate) simulation."""
    # imported here (not module level): repro.baselines pulls in the
    # system classes, which import repro.simulator — a cycle at load time
    from repro.baselines import make_system
    cost = InstanceCostModel(cfg=get_config(spec["model"]),
                             hw=HARDWARE[spec["hw"]],
                             tp=spec["tp"], pp=spec["pp"])
    slo = DATASET_SLOS[spec["workload"]]
    scenario = make_scenario(spec["scenario"], spec["workload"],
                             spec["rate"], seed=spec["seed"])

    def factory():
        return make_system(spec["strategy"], cost, spec["n_instances"], slo)

    metrics = run_once(factory, scenario, spec["rate"], slo,
                       duration=spec["duration"], warmup=spec["warmup"],
                       seed=spec["seed"])
    summary = {k: metrics[k] for k in SUMMARY_KEYS if k in metrics}
    return {**spec, "metrics": summary}


@dataclasses.dataclass
class ExperimentRunner:
    """Sweeps strategies x scenarios x rates into a tidy result grid."""

    strategies: Optional[Sequence[str]] = None   # None: every registered one
    scenarios: Sequence[str] = tuple(
        k for k in SCENARIO_KINDS if k != "ramp")
    rates: Sequence[float] = (8.0,)
    model: str = "llama-30b"
    hw: str = "L20"
    tp: int = 4
    pp: int = 1
    n_instances: int = 8
    workload: str = "sharegpt"
    duration: float = 60.0
    warmup: Optional[float] = None
    base_seed: int = 0
    n_workers: Optional[int] = None   # None: one per core, capped by cells

    def __post_init__(self):
        if self.strategies is None:
            from repro.baselines import STRATEGIES
            self.strategies = STRATEGIES

    def cells(self) -> List[Dict]:
        common = dict(model=self.model, hw=self.hw, tp=self.tp, pp=self.pp,
                      n_instances=self.n_instances, workload=self.workload,
                      duration=self.duration, warmup=self.warmup)
        out = []
        for strat in self.strategies:
            for scen in self.scenarios:
                for rate in self.rates:
                    out.append({**common, "strategy": strat,
                                "scenario": scen, "rate": rate,
                                "seed": cell_seed(self.base_seed, strat,
                                                  scen, rate)})
        return out

    def run(self) -> Dict:
        specs = self.cells()
        workers = self.n_workers
        if workers is None:
            workers = min(len(specs), multiprocessing.cpu_count())
        if workers > 1:
            # spawn, not fork: the parent may have imported jax (pytest,
            # notebooks), and forking a multithreaded process can deadlock
            ctx = multiprocessing.get_context("spawn")
            with ctx.Pool(workers) as pool:
                rows = pool.map(_run_cell, specs)
        else:
            rows = [_run_cell(s) for s in specs]
        meta = dataclasses.asdict(self)
        meta.pop("n_workers")        # parallelism does not affect results
        meta["strategies"] = list(self.strategies)
        meta["scenarios"] = list(self.scenarios)
        meta["rates"] = list(self.rates)
        return {"meta": meta, "cells": rows}

    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(results: Dict) -> Dict[str, Dict[str, Dict[float, Dict]]]:
        """Pivot the flat cell list to [strategy][scenario][rate]."""
        out: Dict[str, Dict[str, Dict[float, Dict]]] = {}
        for cell in results["cells"]:
            out.setdefault(cell["strategy"], {}) \
               .setdefault(cell["scenario"], {})[cell["rate"]] = \
               cell["metrics"]
        return out

    @staticmethod
    def save(results: Dict, path) -> None:
        with open(path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")

    @staticmethod
    def load(path) -> Dict:
        with open(path) as f:
            return json.load(f)


# --------------------------------------------------------------------- #
# The canonical regression grid: small enough to run in CI, wide enough
# to pin every strategy x scenario pair.  bench_scenarios --write-golden
# regenerates tests/golden/scenario_grid.json from exactly this spec.
# --------------------------------------------------------------------- #

def regression_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "sarathi", "distserve", "mooncake"),
        scenarios=("poisson", "bursty", "diurnal", "replay"),
        rates=(6.0,),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=20.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)
