"""Unified experiment runner: strategy x scenario x rate sweeps.

``ExperimentRunner`` fans the cross product of serving strategies
(EcoServe/PaDG, vLLM-NoDG, Sarathi-NoDG, DistServe-FuDG, MoonCake-FuDG),
arrival scenarios (``repro.simulator.scenarios``), and request rates over
a ``multiprocessing`` pool.  Every cell derives its own RNG seed from
(base_seed, strategy, scenario, rate) via CRC32 — not Python's ``hash``,
which is salted per process — so the result grid is bit-exactly
reproducible regardless of worker count or scheduling order.  The grid
feeds ``benchmarks/bench_scenarios.py`` and the golden regression test in
``tests/test_scenarios.py``.

Two per-cell modes:

* ``mode="fixed"`` (default) — one simulation per (strategy, scenario,
  rate) cell, reporting SLO attainment at that fixed rate.
* ``mode="goodput"`` — one cell per (strategy, scenario): the worker
  binary-searches the highest request rate whose attainment still meets
  ``target_attainment`` (DistServe-style goodput search, the paper's
  Fig. 8 frontier per traffic shape).  Practical only because the
  simulator hot path is fast enough to run the ~10 probe simulations a
  search needs inside a single worker.

Strategies are ``StrategySpec`` names resolved by ``repro.baselines``:
registered specs (``"vllm"``, ``"ecoserve++"``) or ``"base+policy"``
grammar compositions (``"vllm+priority"``, ``"mooncake+spf"``) — grid
cells name policy bundles directly, and every result row carries the
resolved ``describe()`` bundle under ``"system"`` (also in the streamed
JSONL), so rows are self-documenting.

Three more grid axes (all seed-disambiguated through ``cell_seed``'s
``extra`` component, so legacy single-axis grids keep their historical
seeds):

* ``tenants=("alpaca", "longbench")`` — every cell becomes a
  multi-tenant ``MixedScenario`` with one stream per listed Table 4
  workload, tagged with that workload name as its ``slo_class`` and
  scored against its own SLO; rows carry ``attainment_by_class`` and
  ``attainment_min``, and goodput mode bisects on the min-over-classes
  attainment (one starved tenant caps the frontier).  Entries may pin a
  rate share and a per-tenant arrival shape:
  ``tenants=(("alpaca", 0.7, "bursty"), ("longbench", 0.3, "diurnal"))``
  (plain-name tuples keep their PR 3 seeds).
* ``n_instances=(1, 2, 4)`` — the instance count as a grid axis (Fig. 9
  static scaling, folded from the old standalone bench loop).
* ``tp=((4, 1), (2, 2))`` — the parallelism degree as a grid axis
  (ints or (tp, pp) pairs); with ``slo_override=(ttft, tpot)`` this
  folds the Fig. 11 PP-compatibility bench into the runner.
* ``autoscale=(None, "band", "threshold")`` — the closed-loop
  autoscaling controller (``repro.control``) as a grid axis; ``None``
  cells run static.  Deliberately seed-neutral: every controller variant
  replays the identical arrival sequence, so attainment deltas isolate
  the controller.  ``phases=K`` adds per-phase attainment columns
  (fixed-rate mode only; goodput mode rejects ``autoscale``).
  Scenario kinds ``"trace:azure"`` / ``"trace:burstgpt"`` replay the
  converted real-trace excerpts (``repro.traces``) rate-normalized to
  the cell rate.
* ``fleet="chat=llama-30b/ecoserve/4,...;budget=24"`` — multi-model
  fleet serving (``repro.fleet``): every cell builds N model pools under
  one GPU budget, the ``strategies`` slot names routing policies, and
  ``autoscale="rebalance"`` installs the budget-constrained rebalancer.
  Seed-neutral like ``autoscale`` (constant "fleet" seed label), so all
  router/rebalance variants replay identical arrivals; rows carry
  ``attainment_by_pool`` / ``attainment_pool_min`` and a ``fleet``
  routing/budget digest.

Cells run through ``imap_unordered`` with per-cell error capture: a
crashing cell yields a row carrying its spec and the error string instead
of poisoning the whole ``pool.map``.  Pass ``stream_path`` to append one
JSONL row per *finished* cell so long sweeps survive interruption.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import multiprocessing
import traceback
import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import get_config
from repro.core.slo import DATASET_SLOS, SLO, SLOClassSet
from repro.simulator.cost_model import (GPU_A800, GPU_L20, TPU_V5E_SIM,
                                        InstanceCostModel)
from repro.simulator.metrics import goodput, run_once
from repro.simulator.scenarios import (SCENARIO_KINDS, make_mixed_scenario,
                                       make_scenario)

HARDWARE = {"L20": GPU_L20, "A800": GPU_A800, "tpu-v5e": TPU_V5E_SIM}

# metrics kept in the persisted grid (attainment + tail latency summary;
# the *_by_class / *_min keys appear only on multi-tenant cells, so
# single-class golden grids keep their legacy rows)
SUMMARY_KEYS = ("attainment", "attainment_min", "attainment_by_class",
                "attainment_by_phase", "attainment_phase_min",
                "attainment_by_pool", "attainment_pool_min", "fleet",
                "timeline", "faults", "completion", "finished",
                "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")
GOODPUT_SUMMARY_KEYS = ("goodput", "target", "probes", "attainment",
                        "attainment_min", "attainment_by_class",
                        "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")

# runner fields that parameterize the goodput search; excluded from the
# persisted meta in fixed mode so pre-existing golden grids stay valid
_GOODPUT_FIELDS = ("mode", "target_attainment", "goodput_lo", "goodput_hi",
                   "goodput_tol")


def cell_seed(base_seed: int, strategy: str, scenario: str,
              rate: float, extra: str = "") -> int:
    """Deterministic per-cell seed, stable across processes and runs.
    ``extra`` disambiguates additional grid axes (tenant mixes, swept
    instance counts); an empty ``extra`` reproduces the historical seed
    for every pre-existing golden cell."""
    key = f"{strategy}|{scenario}|{rate:.6f}".encode()
    if extra:
        key += f"|{extra}".encode()
    return (zlib.crc32(key) ^ (base_seed * 2654435761)) & 0x7FFFFFFF


def tenant_names(tenants: Sequence) -> List[str]:
    """Workload names out of a tenant axis spec (entries are names or
    ``(name, share[, shape])`` tuples/lists)."""
    return [e if isinstance(e, str) else e[0] for e in tenants]


def _run_cell(spec: Dict) -> Dict:
    """Worker entry point: one (strategy, scenario, rate) simulation, or
    one per-(strategy, scenario) goodput search when spec["mode"] is
    "goodput".  Every row carries the strategy's ``describe()`` bundle
    under ``"system"`` so results are self-documenting."""
    # imported here (not module level): repro.baselines pulls in the
    # system classes, which import repro.simulator — a cycle at load time
    from repro.baselines import describe_strategy, make_system
    tenants = spec.get("tenants")
    if tenants:
        # one SLO class per tenant workload (Table 4 budgets); requests
        # are tagged by MixedScenario and scored per class
        slo = SLOClassSet.make(
            {w: DATASET_SLOS[w] for w in tenant_names(tenants)})
    elif spec.get("slo_override"):
        # pinned scalar budgets (the PP-compatibility sweep relaxes TPOT
        # away from any Table 4 workload)
        slo = SLO(ttft=spec["slo_override"][0], tpot=spec["slo_override"][1])
    else:
        slo = DATASET_SLOS[spec["workload"]]

    if spec.get("fleet"):
        # fleet cell: the strategy slot names a ROUTER; the pools carry
        # their own models, strategies, and cost models from the spec
        # string, so the cell-level model/n_instances fields don't apply
        from repro.fleet import FleetSystem

        def factory():
            return FleetSystem(spec["fleet"], slo, hw=spec["hw"],
                               tp=spec["tp"], pp=spec["pp"],
                               router=spec["strategy"])

        describe = factory().describe()
    else:
        cost = InstanceCostModel(cfg=get_config(spec["model"]),
                                 hw=HARDWARE[spec["hw"]],
                                 tp=spec["tp"], pp=spec["pp"])
        if spec.get("calibration"):      # None = analytic (roofline) cell
            # measured-constants executor: timing from the saved
            # CalibrationReport fit, capacity/transfer geometry inherited
            # from the analytic model it replaces (import is numpy-only)
            from repro.serving.calibration import load_fitted_executor
            cost = load_fitted_executor(spec["calibration"], like=cost)
        describe = describe_strategy(spec["strategy"])

        def factory():
            return make_system(spec["strategy"], cost, spec["n_instances"],
                               slo)

    if spec.get("mode") == "goodput":
        # rate knob stays live inside the search: each probe regenerates
        # the scenario at the probed rate under the cell's fixed seed
        if tenants:
            scen_factory = functools.partial(
                make_mixed_scenario, spec["scenario"], tenants)
        else:
            scen_factory = functools.partial(
                make_scenario, spec["scenario"], spec["workload"])
        g = goodput(factory, scen_factory, slo,
                    target_attainment=spec["target_attainment"],
                    lo=spec["goodput_lo"], hi=spec["goodput_hi"],
                    tol=spec["goodput_tol"], duration=spec["duration"],
                    warmup=spec["warmup"], seed=spec["seed"])
        summary = {k: g[k] for k in GOODPUT_SUMMARY_KEYS if k in g}
        return {**spec, "metrics": summary, "system": describe}

    if tenants:
        scenario = make_mixed_scenario(spec["scenario"], tenants,
                                       spec["rate"], seed=spec["seed"])
    else:
        scenario = make_scenario(spec["scenario"], spec["workload"],
                                 spec["rate"], seed=spec["seed"])
    run_kw = {}
    if spec.get("autoscale"):        # None = static cell, no control loop
        run_kw["control"] = spec["autoscale"]
    if spec.get("phases"):
        run_kw["phases"] = spec["phases"]
    if spec.get("faults"):           # None = fault-free cell
        run_kw["faults"] = spec["faults"]
    if spec.get("trace"):            # None = untraced cell (legacy)
        run_kw["trace"] = spec["trace"]
    metrics = run_once(factory, scenario, spec["rate"], slo,
                       duration=spec["duration"], warmup=spec["warmup"],
                       seed=spec["seed"], **run_kw)
    summary = {k: metrics[k] for k in SUMMARY_KEYS if k in metrics}
    return {**spec, "metrics": summary, "system": describe}


def _run_cell_safe(item: Tuple[int, Dict]) -> Tuple[int, Dict]:
    """imap_unordered entry: never raises — a failed cell reports its spec
    and the error so the rest of the grid survives."""
    idx, spec = item
    try:
        return idx, _run_cell(spec)
    except Exception as exc:  # noqa: BLE001 — deliberate catch-all
        return idx, {**spec,
                     "error": f"{type(exc).__name__}: {exc}",
                     "traceback": traceback.format_exc(limit=8)}


@dataclasses.dataclass
class ExperimentRunner:
    """Sweeps strategies x scenarios x rates into a tidy result grid."""

    strategies: Optional[Sequence[str]] = None   # None: every registered one
    scenarios: Sequence[str] = tuple(
        k for k in SCENARIO_KINDS if k != "ramp")
    rates: Sequence[float] = (8.0,)
    model: str = "llama-30b"
    hw: str = "L20"
    # a bare int (legacy) or a sequence: a sequence makes the parallelism
    # degree a grid axis (Fig. 11 PP compatibility folded into the
    # runner).  Sequence entries are ints (``pp`` applies) or (tp, pp)
    # pairs for joint sweeps like ``tp=((4, 1), (2, 2))``.
    tp: Union[int, Sequence] = 4
    pp: int = 1
    # a bare int (legacy) or a sequence: a sequence makes the instance
    # count a grid axis (Fig. 9 static scaling folded into the runner)
    n_instances: Union[int, Sequence[int]] = 8
    workload: str = "sharegpt"
    # multi-tenant mode: tenant workload names (Table 4); each cell runs a
    # MixedScenario with one tenant stream per entry, tagged with that
    # workload name as its slo_class, scored against DATASET_SLOS per
    # class.  Entries are names (equal share, the cell's scenario shape)
    # or (name, share[, shape]) tuples pinning that tenant's fraction of
    # the rate and optionally its own arrival shape, e.g.
    # ``tenants=(("alpaca", 0.7, "bursty"), ("longbench", 0.3, "diurnal"))``.
    # None = legacy single-class cells (``workload`` applies).
    tenants: Optional[Sequence] = None
    # pinned (ttft, tpot) overriding the workload's Table 4 budgets
    # (single-class only; the PP sweep relaxes TPOT past any workload's)
    slo_override: Optional[Sequence[float]] = None
    # autoscaling axis (closed-loop control plane, repro.control): None =
    # every cell static (legacy); a controller spec string ("band",
    # "threshold", "band:max=8") or a sequence of them — None entries
    # mean "static baseline" — makes the controller a grid level.
    # Deliberately NOT folded into cell seeds: an autoscaled cell and its
    # static baseline replay the IDENTICAL arrival sequence, so their
    # attainment difference is the controller's doing alone.
    autoscale: Union[None, str, Sequence[Optional[str]]] = None
    # fault-injection axis (repro.faults): None = every cell fault-free
    # (legacy); a fault-spec string ("crash:t=14", "spot:mtbf=20,notice=2"),
    # a named interruption trace ("itrace:gentle"), or a sequence of them
    # — None entries mean "fault-free baseline" — makes the fault schedule
    # a grid level.  Seed-neutral like ``autoscale``: a faulted cell and
    # its clean baseline replay the IDENTICAL arrival sequence, so the
    # attainment delta isolates the faults (the schedule itself derives
    # its own RNG stream from (spec, cell seed)).
    faults: Union[None, str, Sequence[Optional[str]]] = None
    # calibrated-executor axis (sim-to-real write-back): None = every
    # cell analytic (legacy); a path to a saved CalibrationReport JSON
    # (benchmarks/bench_calibration.py) — or a sequence of paths/None —
    # makes the cost model a grid level: None cells schedule with the
    # roofline model, path cells with a FittedExecutor carrying the
    # report's measured constants.  Seed-neutral like ``autoscale``: a
    # calibrated cell and its analytic baseline replay the IDENTICAL
    # arrival sequence, so the metric delta isolates the cost model.
    calibration: Union[None, str, Sequence[Optional[str]]] = None
    # multi-model fleet axis (repro.fleet): None = every cell single-pool
    # (legacy); a fleet spec string "name=model/strategy/n,...;budget=G"
    # — or a sequence of spec strings — makes the fleet a grid level.
    # With a fleet, the ``strategies`` slot names ROUTERS ("pinned" /
    # "cheapest-feasible" / "quality-tiered"; default all three) and the
    # ``autoscale`` axis takes the "rebalance[:k=v,...]" spec.  Seed
    # discipline: cell seeds use the constant label "fleet" in the
    # strategy slot and exclude the fleet value itself, so every router x
    # fleet x autoscale variant replays the IDENTICAL arrival sequence —
    # routing and rebalancing deltas isolate the policy, not the draw.
    fleet: Union[None, str, Sequence[str]] = None
    # split the scored window into this many equal attainment phases
    # (rows gain attainment_by_phase / attainment_phase_min)
    phases: Optional[int] = None
    # flight-recorder capture (repro.obs): None = untraced (legacy); a
    # directory path makes every cell write its event stream to
    # ``<dir>/cell<idx>.trace.jsonl``.  Seed-neutral BY CONSTRUCTION, not
    # just by seed bookkeeping: tracing is observation-only, so a traced
    # cell's metrics are bit-identical to the untraced cell's (the
    # property test pins this), and "trace" never enters SUMMARY_KEYS so
    # golden rows can't see it.
    trace: Optional[str] = None
    duration: float = 60.0
    warmup: Optional[float] = None
    base_seed: int = 0
    n_workers: Optional[int] = None   # None: one per core, capped by cells
    # ---- goodput mode (Fig. 8 frontier) ------------------------------- #
    mode: str = "fixed"               # "fixed" | "goodput"
    target_attainment: float = 0.9
    goodput_lo: float = 0.25          # search bracket (req/s)
    goodput_hi: float = 32.0
    goodput_tol: float = 0.10         # relative rate tolerance
    # append one JSONL row per finished cell (crash/interrupt recovery)
    stream_path: Optional[str] = None

    def __post_init__(self):
        if self.strategies is None:
            if self.fleet is not None:
                # with a fleet the strategy slot names routers
                from repro.fleet import ROUTERS
                self.strategies = tuple(ROUTERS)
            else:
                from repro.baselines import STRATEGIES
                self.strategies = STRATEGIES
        if self.fleet is not None:
            if self.mode == "goodput":
                raise ValueError("fleet cells are fixed-rate only: the "
                                 "rebalancer's capacity moves and the "
                                 "goodput search's rate knob would chase "
                                 "each other")
            if self.calibration is not None:
                raise ValueError("calibration is single-pool only; fleet "
                                 "pools own their per-model cost models")
            if self.slo_override is not None:
                raise ValueError("slo_override is single-pool only; fleet "
                                 "cells score against per-class Table 4 "
                                 "SLOs")
            if any(f is None for f in self._fleet_axis()):
                raise ValueError("fleet axis entries must be fleet spec "
                                 "strings: a None (no-fleet) entry would "
                                 "reinterpret the strategy slot mid-grid")
        if self.mode not in ("fixed", "goodput"):
            raise ValueError(f"unknown mode {self.mode!r}; "
                             "expected 'fixed' or 'goodput'")
        if self.tenants is not None and len(self.tenants) == 0:
            raise ValueError("tenants must be None or a non-empty sequence")
        if self.tenants is not None and self.slo_override is not None:
            raise ValueError("slo_override is single-class only; tenant "
                             "cells score against per-class Table 4 SLOs")
        if self.autoscale is not None and self.mode == "goodput":
            raise ValueError("autoscale cells are fixed-rate only: the "
                             "goodput search's rate knob and the "
                             "controller's capacity knob would chase "
                             "each other")
        if self.faults is not None and self.mode == "goodput":
            raise ValueError("fault cells are fixed-rate only: the "
                             "schedule is laid out over the cell's fixed "
                             "duration, and a fault mid-bisection would "
                             "make the frontier measure luck, not "
                             "capacity")
        if self.calibration is not None and self.mode == "goodput":
            raise ValueError("calibration cells are fixed-rate only for "
                             "now: a frontier over mixed cost models "
                             "would hide which model moved it")
        if self.trace is not None and self.mode == "goodput":
            raise ValueError("trace capture is fixed-rate only: the "
                             "goodput search runs ~10 probe simulations "
                             "per cell and each would overwrite the "
                             "cell's trace file")

    # ---- grid axes ---------------------------------------------------- #
    def _instance_counts(self) -> Tuple[int, ...]:
        if isinstance(self.n_instances, int):
            return (self.n_instances,)
        return tuple(self.n_instances)

    def _tp_pairs(self) -> Tuple[Tuple[int, int], ...]:
        if isinstance(self.tp, int):
            return ((self.tp, self.pp),)
        return tuple((t, self.pp) if isinstance(t, int)
                     else (int(t[0]), int(t[1])) for t in self.tp)

    def _autoscale_axis(self) -> Tuple[Optional[str], ...]:
        if self.autoscale is None:
            return (None,)
        if isinstance(self.autoscale, str):
            return (self.autoscale,)
        return tuple(self.autoscale)

    def _faults_axis(self) -> Tuple[Optional[str], ...]:
        if self.faults is None:
            return (None,)
        if isinstance(self.faults, str):
            return (self.faults,)
        return tuple(self.faults)

    def _calibration_axis(self) -> Tuple[Optional[str], ...]:
        if self.calibration is None:
            return (None,)
        if isinstance(self.calibration, str):
            return (self.calibration,)
        return tuple(self.calibration)

    def _fleet_axis(self) -> Tuple[Optional[str], ...]:
        if self.fleet is None:
            return (None,)
        if isinstance(self.fleet, str):
            return (self.fleet,)
        return tuple(self.fleet)

    def _norm_tenants(self) -> Optional[List]:
        """JSON-able tenant entries for cell specs: names stay strings
        (legacy golden cells keep their exact spec), rich entries become
        [name, share, shape] lists — widened to [name, share, shape,
        model] ONLY for entries that carry a model tag, so pre-fleet
        golden specs stay byte-identical."""
        if self.tenants is None:
            return None
        out: List = []
        for e in self.tenants:
            if isinstance(e, str):
                out.append(e)
            else:
                width = 4 if len(e) > 3 else 3
                seq = list(e) + [None] * (width - len(e))
                row = [seq[0],
                       None if seq[1] is None else float(seq[1]),
                       seq[2]]
                if width == 4:
                    row.append(seq[3])
                out.append(row)
        return out

    def _seed_extra(self, n: int, tp_pair: Tuple[int, int]) -> str:
        """Extra seed-key components for the new grid axes.  Empty for a
        legacy single-class, single-count, single-tp grid — those cells
        keep their historical seeds and golden fixtures.  Plain-name
        tenant tuples keep the PR 3 encoding (and therefore seeds);
        share/shape-qualified entries encode all three fields."""
        parts = []
        if self.tenants:
            enc = []
            for e in self.tenants:
                if isinstance(e, str):
                    enc.append(e)
                else:
                    seq = tuple(e)
                    share = "" if len(seq) < 2 or seq[1] is None \
                        else f"{float(seq[1]):g}"
                    shape = seq[2] if len(seq) > 2 and seq[2] else ""
                    key = f"{seq[0]}:{share}:{shape}"
                    if len(seq) > 3 and seq[3]:
                        # model tag appended only for 4-field entries:
                        # 3-field entries keep their pre-fleet seeds
                        key += f":{seq[3]}"
                    enc.append(key)
            parts.append("tenants=" + "+".join(enc))
        if len(self._instance_counts()) > 1:
            parts.append(f"n={n}")
        if len(self._tp_pairs()) > 1:
            parts.append(f"tp={tp_pair[0]}x{tp_pair[1]}")
        return "|".join(parts)

    def cells(self) -> List[Dict]:
        common = dict(model=self.model, hw=self.hw,
                      workload=self.workload,
                      duration=self.duration, warmup=self.warmup)
        tenants = self._norm_tenants()
        if tenants:
            common["tenants"] = tenants
        if self.slo_override is not None:
            common["slo_override"] = [float(x) for x in self.slo_override]
        if self.phases is not None:
            common["phases"] = int(self.phases)
        out = []
        if self.mode == "goodput":
            common.update(mode="goodput",
                          target_attainment=self.target_attainment,
                          goodput_lo=self.goodput_lo,
                          goodput_hi=self.goodput_hi,
                          goodput_tol=self.goodput_tol)
            for strat in self.strategies:
                for scen in self.scenarios:
                    for n in self._instance_counts():
                        for t, p in self._tp_pairs():
                            # rate 0.0 = the search's seed sentinel: one
                            # seed per (strategy, scenario[, axes]),
                            # shared by every probe
                            out.append({**common, "strategy": strat,
                                        "scenario": scen, "n_instances": n,
                                        "tp": t, "pp": p,
                                        "seed": cell_seed(
                                            self.base_seed, strat, scen,
                                            0.0,
                                            extra=self._seed_extra(
                                                n, (t, p)))})
            return out
        for strat in self.strategies:
            for scen in self.scenarios:
                for rate in self.rates:
                    for n in self._instance_counts():
                        for t, p in self._tp_pairs():
                            for ctrl in self._autoscale_axis():
                              for fv in self._faults_axis():
                                for cal in self._calibration_axis():
                                  for fl in self._fleet_axis():
                                    # fleet cells seed under the constant
                                    # label "fleet": every router variant
                                    # replays identical arrivals, so
                                    # routing deltas isolate the policy
                                    seed_label = ("fleet"
                                                  if self.fleet is not None
                                                  else strat)
                                    cell = {**common, "strategy": strat,
                                            "scenario": scen, "rate": rate,
                                            "n_instances": n,
                                            "tp": t, "pp": p,
                                            "seed": cell_seed(
                                                self.base_seed, seed_label,
                                                scen, rate,
                                                extra=self._seed_extra(
                                                    n, (t, p)))}
                                    if self.autoscale is not None:
                                        # same seed across controller
                                        # values: static vs autoscaled
                                        # cells replay identical arrivals
                                        # by design
                                        cell["autoscale"] = ctrl
                                    if self.faults is not None:
                                        # ditto: faulted vs clean cells
                                        # share arrivals by design
                                        cell["faults"] = fv
                                    if self.calibration is not None:
                                        # ditto: calibrated vs analytic
                                        # cells share arrivals by design
                                        cell["calibration"] = cal
                                    if self.fleet is not None:
                                        # ditto: every fleet spec variant
                                        # shares arrivals by design
                                        cell["fleet"] = fl
                                    out.append(cell)
        if self.trace is not None:
            import os
            for i, cell in enumerate(out):
                cell["trace"] = os.path.join(
                    self.trace, f"cell{i:04d}.trace.jsonl")
        return out

    def run(self) -> Dict:
        specs = self.cells()
        workers = self.n_workers
        if workers is None:
            workers = min(len(specs), multiprocessing.cpu_count())
        rows: List[Optional[Dict]] = [None] * len(specs)
        stream = open(self.stream_path, "a") if self.stream_path else None
        try:
            if workers > 1:
                # spawn, not fork: the parent may have imported jax
                # (pytest, notebooks), and forking a multithreaded process
                # can deadlock
                ctx = multiprocessing.get_context("spawn")
                with ctx.Pool(workers) as pool:
                    for idx, row in pool.imap_unordered(
                            _run_cell_safe, list(enumerate(specs))):
                        rows[idx] = row
                        self._stream_row(stream, idx, row)
            else:
                for idx, spec in enumerate(specs):
                    _, row = _run_cell_safe((idx, spec))
                    rows[idx] = row
                    self._stream_row(stream, idx, row)
        finally:
            if stream is not None:
                stream.close()
        meta = dataclasses.asdict(self)
        meta.pop("n_workers")        # parallelism does not affect results
        meta.pop("stream_path")      # neither does streaming
        if self.mode == "fixed":     # keep legacy golden meta stable
            for k in _GOODPUT_FIELDS:
                meta.pop(k)
        if self.tenants is None:     # legacy single-class grids keep the
            meta.pop("tenants")      # pre-multi-tenant meta shape
        else:
            meta["tenants"] = self._norm_tenants()
        if self.slo_override is None:   # ditto for the pinned-SLO knob
            meta.pop("slo_override")
        else:
            meta["slo_override"] = [float(x) for x in self.slo_override]
        if self.autoscale is None:      # and for the autoscale/phase axes
            meta.pop("autoscale")
        else:
            meta["autoscale"] = list(self._autoscale_axis())
        if self.faults is None:         # and for the fault axis
            meta.pop("faults")
        else:
            meta["faults"] = list(self._faults_axis())
        if self.calibration is None:    # and for the calibration axis
            meta.pop("calibration")
        else:
            meta["calibration"] = list(self._calibration_axis())
        if self.fleet is None:          # and for the fleet axis
            meta.pop("fleet")
        else:
            meta["fleet"] = list(self._fleet_axis())
        if self.trace is None:          # and for the trace capture axis
            meta.pop("trace")
        if self.phases is None:
            meta.pop("phases")
        if not isinstance(self.n_instances, int):
            meta["n_instances"] = list(self.n_instances)
        if not isinstance(self.tp, int):
            meta["tp"] = [list(p) for p in self._tp_pairs()]
        meta["strategies"] = list(self.strategies)
        meta["scenarios"] = list(self.scenarios)
        meta["rates"] = list(self.rates)
        results = {"meta": meta, "cells": rows}
        errors = [r for r in rows if r is not None and "error" in r]
        if errors:
            results["errors"] = [
                {k: v for k, v in r.items() if k != "traceback"}
                for r in errors]
        return results

    @staticmethod
    def _stream_row(stream, idx: int, row: Dict) -> None:
        if stream is None:
            return
        stream.write(json.dumps({"cell_index": idx, **row},
                                sort_keys=True) + "\n")
        stream.flush()

    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(results: Dict) -> Dict[str, Dict[str, Dict[float, Dict]]]:
        """Pivot the flat cell list to [strategy][scenario][rate]
        (fixed mode) or [strategy][scenario] (goodput mode).  Swept axes
        insert their own levels after [scenario] so cells can't overwrite
        each other: a ``tp`` sweep keys ``"tp{T}pp{P}"``, an
        ``n_instances`` sweep keys the count, an ``autoscale`` sweep keys
        the controller spec (``"static"`` for None), a ``faults`` sweep
        keys the fault spec (``"none"`` for None), a ``calibration``
        sweep keys the report path (``"analytic"`` for None), and a
        ``fleet`` sweep keys the fleet spec string, in that order."""
        cells = results["cells"]
        multi_n = len({c.get("n_instances") for c in cells}) > 1
        multi_tp = len({(c.get("tp"), c.get("pp")) for c in cells}) > 1
        multi_as = len({c.get("autoscale") for c in cells}) > 1
        multi_f = len({c.get("faults") for c in cells}) > 1
        multi_cal = len({c.get("calibration") for c in cells}) > 1
        multi_fl = len({c.get("fleet") for c in cells}) > 1
        out: Dict[str, Dict[str, Dict]] = {}
        for cell in cells:
            leaf = cell.get("metrics", cell)
            keys: List = [cell["scenario"]]
            if multi_tp:
                keys.append(f"tp{cell['tp']}pp{cell['pp']}")
            if multi_n:
                keys.append(cell["n_instances"])
            if multi_as:
                keys.append(cell.get("autoscale") or "static")
            if multi_f:
                keys.append(cell.get("faults") or "none")
            if multi_cal:
                keys.append(cell.get("calibration") or "analytic")
            if multi_fl:
                keys.append(cell.get("fleet") or "none")
            if cell.get("mode") != "goodput":
                keys.append(cell["rate"])
            node = out.setdefault(cell["strategy"], {})
            for k in keys[:-1]:
                node = node.setdefault(k, {})
            node[keys[-1]] = leaf
        return out

    @staticmethod
    def save(results: Dict, path) -> None:
        with open(path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")

    @staticmethod
    def load(path) -> Dict:
        with open(path) as f:
            return json.load(f)


# --------------------------------------------------------------------- #
# The canonical regression grid: small enough to run in CI, wide enough
# to pin every strategy x scenario pair.  bench_scenarios --write-golden
# regenerates tests/golden/scenario_grid.json from exactly this spec.
# --------------------------------------------------------------------- #

def regression_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "sarathi", "distserve", "mooncake"),
        scenarios=("poisson", "bursty", "diurnal", "replay"),
        rates=(6.0,),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=20.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)


def goodput_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    """The canonical goodput-frontier grid (Fig. 8 per traffic shape),
    sized for CI; pinned by tests/golden/goodput_frontier.json.  The
    duration/lo pairing keeps >= ~24 scored requests per probe so a
    single end-of-window straggler can't sink the completion factor.
    ``vllm+priority`` (a composed ``StrategySpec``) rides along so the
    policy-grammar construction path is exercised by the frontier too.
    The strategy rows cover all four paper baselines (sarathi/distserve
    joined in PR 5) and the shapes cover all four rate-parameterized
    arrival processes — per-cell CRC seeds mean the widened grid keeps
    every pre-existing cell's metrics bit-exact.

    The ROADMAP composition sweep rides the same frontier:
    ``distserve+priority`` (EDF queue + backpressure admission on FuDG)
    and ``ecoserve+spf`` (shortest-prompt-first on PaDG) probe whether
    either composed policy Pareto-dominates its base across the shapes
    (notes in benchmarks/README.md)."""
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "sarathi", "distserve",
                    "mooncake", "vllm+priority",
                    "distserve+priority", "ecoserve+spf"),
        scenarios=("poisson", "bursty", "diurnal", "ramp"),
        mode="goodput", target_attainment=0.9,
        goodput_lo=1.0, goodput_hi=24.0, goodput_tol=0.35,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=24.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)


def tenant_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    """The canonical multi-tenant regression grid: two SLO classes with a
    15x TTFT spread (alpaca 1.0 s vs longbench 15 s, Table 4) mixed into
    every cell, across two traffic shapes; pinned bit-exactly by
    tests/golden/tenant_grid.json.  Every row carries the per-class
    attainment grid plus the min-over-classes scalar.  The SLO-aware
    NoDG compositions (``vllm+priority``, ``sarathi+priority``) run next
    to blind vLLM so the grid compares EcoServe against a priority-queue
    NoDG, not just a blind one (ROADMAP item 1)."""
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "mooncake",
                    "vllm+priority", "sarathi+priority"),
        scenarios=("poisson", "bursty"),
        rates=(6.0,),
        tenants=("alpaca", "longbench"),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        duration=20.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)


def dynamic_scaling_runner(n_workers: Optional[int] = None
                           ) -> ExperimentRunner:
    """The canonical closed-loop autoscaling grid (paper Fig. 10 under
    non-stationary traffic); pinned by tests/golden/dynamic_scaling.json.

    EcoServe under every load-shifting shape — MMPP bursty, diurnal,
    ramp, and the two converted real-trace excerpts (Azure LLM
    inference, BurstGPT; ``repro.traces``) — each cell run three ways
    over the IDENTICAL arrival sequence (autoscale is seed-neutral):
    static 4-instance baseline (None), the closed-loop target-band
    controller, and the trace-oblivious threshold baseline for ablation.
    Rows carry per-phase attainment (6 phases) and the recorded scaling
    timeline, so the golden pins both the attainment dips/recoveries and
    the exact scale-decision sequence."""
    return ExperimentRunner(
        strategies=("ecoserve",),
        scenarios=("bursty", "diurnal", "ramp",
                   "trace:azure", "trace:burstgpt"),
        rates=(16.0,),
        autoscale=(None, "band", "threshold"),
        phases=6,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=72.0, warmup=6.0,
        base_seed=42, n_workers=n_workers)


def fault_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    """The canonical fault-degradation grid: EcoServe vs both FuDG
    baselines under the "gentle" interruption trace (one crash at t=14,
    one spot preemption with a 2 s notice at t=26) next to their
    fault-free baselines, every system running the same migrate failure
    policy, with and without the closed-loop band controller; pinned by
    tests/golden/fault_scenarios.json.

    The claim the golden pins: temporal disaggregation degrades
    gracefully under instance loss — any EcoServe survivor still serves
    both phases, so preemption notices migrate decodes to peers and the
    control loop's repair path re-provisions the lost capacity — whereas
    FuDG's role-partitioned pools collapse when a fault lands on the
    scarce role (a dead lone prefill instance starves the whole pool,
    and KV caches in flight to a dead decoder are simply lost).
    Seed-neutrality of the faults axis means each strategy's faulted and
    clean cells replay the identical arrival sequence."""
    return ExperimentRunner(
        strategies=("ecoserve+migrate", "distserve+migrate",
                    "mooncake+migrate"),
        scenarios=("bursty",),
        rates=(8.0,),
        autoscale=(None, "band"),
        faults=(None, "itrace:gentle"),
        phases=6,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=48.0, warmup=6.0,
        base_seed=42, n_workers=n_workers)


def interconnect_runner(n_workers: Optional[int] = None
                        ) -> ExperimentRunner:
    """The canonical interconnect-sensitivity grid: EcoServe, a NoDG
    baseline (vLLM), and both FuDG baselines swept over commodity-link
    degradation grades — from a clean fabric through a modestly
    oversubscribed one to a saturated lossy link; pinned by
    tests/golden/interconnect_sensitivity.json.

    The claim the golden pins (the paper's commodity-interconnect
    premise): FuDG moves every request's KV cache across the fabric
    between prefill and decode, so its goodput tracks link quality and
    collapses when bandwidth divides away and losses force
    retry/timeout churn — while EcoServe and NoDG keep all phases of a
    request on one instance, exchange only control-plane messages, and
    hold their clean-link attainment across every grade.  The fault
    axis is seed-neutral: each strategy's degraded cells replay the
    identical arrival sequence as its clean cell, so the attainment
    delta isolates the interconnect."""
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "distserve", "mooncake"),
        scenarios=("bursty",),
        rates=(4.0,),
        faults=(None,
                "netdelay:40",
                "netdegrade:2;netdelay:120",
                "netdegrade:8;netdelay:240;netloss:0.02",
                "netdegrade:48;netdelay:480;netloss:0.08"),
        phases=4,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=48.0, warmup=6.0,
        base_seed=42, n_workers=n_workers)


def fleet_grid_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    """The canonical multi-model fleet grid (repro.fleet); pinned
    bit-exactly by tests/golden/fleet_grid.json.

    Two model pools — a qwen1.5-32b "chat" pool and a llama-30b "code"
    pool, both EcoServe stacks — share a 24-GPU budget.  Two tenant
    streams with opposite mid-run mix shifts (``shift:4,1`` vs
    ``shift:1,4``, model-tagged) swap which pool carries the load
    halfway through, while every router x rebalance cell replays the
    IDENTICAL arrival sequence (fleet cells seed under the constant
    label "fleet").  The surging tenant (longbench) rides the SMALLER
    model, so quality-tiered routing may legally spill its breaching
    requests up-tier into the draining qwen pool — the grid separates
    what routing alone recovers from what capacity movement recovers.

    The claims the golden pins: the static partition strands capacity
    on the wrong side of the shift — its min-over-pools attainment
    collapses in the post-shift phases — while budget-constrained
    rebalancing moves instances from the draining pool to the filling
    one and holds ``attainment_pool_min`` STRICTLY above the static
    cell's, under every routing policy, without ever exceeding the
    budget or emptying a pool; and quality-tiered spillover lifts the
    static floor well above pinned's even before any capacity moves."""
    return ExperimentRunner(
        strategies=("pinned", "cheapest-feasible", "quality-tiered"),
        scenarios=("poisson",),
        rates=(6.0,),
        tenants=(("sharegpt", 0.5, "shift:4,1", "qwen1.5-32b"),
                 ("longbench", None, "shift:1,4", "llama-30b")),
        fleet="chat=qwen1.5-32b/ecoserve/4,code=llama-30b/ecoserve/2"
              ";budget=24",
        autoscale=(None, "rebalance"),
        phases=4,
        model="llama-30b", hw="L20", tp=4, pp=1,
        duration=48.0, warmup=6.0,
        base_seed=42, n_workers=n_workers)


def static_scaling_runner(n_workers: Optional[int] = None
                          ) -> ExperimentRunner:
    """Fig. 9 static scaling folded into the unified runner: the instance
    count is a grid axis (each count gets its own CRC-derived cell seed);
    pinned by tests/golden/static_scaling.json."""
    return ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",), rates=(6.0,),
        n_instances=(2, 4),
        model="llama-30b", hw="L20", tp=4, pp=1,
        workload="sharegpt", duration=20.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)
