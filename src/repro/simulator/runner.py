"""Unified experiment runner: strategy x scenario x rate sweeps.

``ExperimentRunner`` fans the cross product of serving strategies
(EcoServe/PaDG, vLLM-NoDG, Sarathi-NoDG, DistServe-FuDG, MoonCake-FuDG),
arrival scenarios (``repro.simulator.scenarios``), and request rates over
a ``multiprocessing`` pool.  Every cell derives its own RNG seed from
(base_seed, strategy, scenario, rate) via CRC32 — not Python's ``hash``,
which is salted per process — so the result grid is bit-exactly
reproducible regardless of worker count or scheduling order.  The grid
feeds ``benchmarks/bench_scenarios.py`` and the golden regression test in
``tests/test_scenarios.py``.

Two per-cell modes:

* ``mode="fixed"`` (default) — one simulation per (strategy, scenario,
  rate) cell, reporting SLO attainment at that fixed rate.
* ``mode="goodput"`` — one cell per (strategy, scenario): the worker
  binary-searches the highest request rate whose attainment still meets
  ``target_attainment`` (DistServe-style goodput search, the paper's
  Fig. 8 frontier per traffic shape).  Practical only because the
  simulator hot path is fast enough to run the ~10 probe simulations a
  search needs inside a single worker.

Cells run through ``imap_unordered`` with per-cell error capture: a
crashing cell yields a row carrying its spec and the error string instead
of poisoning the whole ``pool.map``.  Pass ``stream_path`` to append one
JSONL row per *finished* cell so long sweeps survive interruption.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import multiprocessing
import traceback
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import (GPU_A800, GPU_L20, TPU_V5E_SIM,
                                        InstanceCostModel)
from repro.simulator.metrics import goodput, run_once
from repro.simulator.scenarios import SCENARIO_KINDS, make_scenario

HARDWARE = {"L20": GPU_L20, "A800": GPU_A800, "tpu-v5e": TPU_V5E_SIM}

# metrics kept in the persisted grid (attainment + tail latency summary)
SUMMARY_KEYS = ("attainment", "completion", "finished",
                "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")
GOODPUT_SUMMARY_KEYS = ("goodput", "target", "probes", "attainment",
                        "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99")

# runner fields that parameterize the goodput search; excluded from the
# persisted meta in fixed mode so pre-existing golden grids stay valid
_GOODPUT_FIELDS = ("mode", "target_attainment", "goodput_lo", "goodput_hi",
                   "goodput_tol")


def cell_seed(base_seed: int, strategy: str, scenario: str,
              rate: float) -> int:
    """Deterministic per-cell seed, stable across processes and runs."""
    key = f"{strategy}|{scenario}|{rate:.6f}".encode()
    return (zlib.crc32(key) ^ (base_seed * 2654435761)) & 0x7FFFFFFF


def _run_cell(spec: Dict) -> Dict:
    """Worker entry point: one (strategy, scenario, rate) simulation, or
    one per-(strategy, scenario) goodput search when spec["mode"] is
    "goodput"."""
    # imported here (not module level): repro.baselines pulls in the
    # system classes, which import repro.simulator — a cycle at load time
    from repro.baselines import make_system
    cost = InstanceCostModel(cfg=get_config(spec["model"]),
                             hw=HARDWARE[spec["hw"]],
                             tp=spec["tp"], pp=spec["pp"])
    slo = DATASET_SLOS[spec["workload"]]

    def factory():
        return make_system(spec["strategy"], cost, spec["n_instances"], slo)

    if spec.get("mode") == "goodput":
        # rate knob stays live inside the search: each probe regenerates
        # the scenario at the probed rate under the cell's fixed seed
        scen_factory = functools.partial(make_scenario, spec["scenario"],
                                         spec["workload"])
        g = goodput(factory, scen_factory, slo,
                    target_attainment=spec["target_attainment"],
                    lo=spec["goodput_lo"], hi=spec["goodput_hi"],
                    tol=spec["goodput_tol"], duration=spec["duration"],
                    warmup=spec["warmup"], seed=spec["seed"])
        summary = {k: g[k] for k in GOODPUT_SUMMARY_KEYS if k in g}
        return {**spec, "metrics": summary}

    scenario = make_scenario(spec["scenario"], spec["workload"],
                             spec["rate"], seed=spec["seed"])
    metrics = run_once(factory, scenario, spec["rate"], slo,
                       duration=spec["duration"], warmup=spec["warmup"],
                       seed=spec["seed"])
    summary = {k: metrics[k] for k in SUMMARY_KEYS if k in metrics}
    return {**spec, "metrics": summary}


def _run_cell_safe(item: Tuple[int, Dict]) -> Tuple[int, Dict]:
    """imap_unordered entry: never raises — a failed cell reports its spec
    and the error so the rest of the grid survives."""
    idx, spec = item
    try:
        return idx, _run_cell(spec)
    except Exception as exc:  # noqa: BLE001 — deliberate catch-all
        return idx, {**spec,
                     "error": f"{type(exc).__name__}: {exc}",
                     "traceback": traceback.format_exc(limit=8)}


@dataclasses.dataclass
class ExperimentRunner:
    """Sweeps strategies x scenarios x rates into a tidy result grid."""

    strategies: Optional[Sequence[str]] = None   # None: every registered one
    scenarios: Sequence[str] = tuple(
        k for k in SCENARIO_KINDS if k != "ramp")
    rates: Sequence[float] = (8.0,)
    model: str = "llama-30b"
    hw: str = "L20"
    tp: int = 4
    pp: int = 1
    n_instances: int = 8
    workload: str = "sharegpt"
    duration: float = 60.0
    warmup: Optional[float] = None
    base_seed: int = 0
    n_workers: Optional[int] = None   # None: one per core, capped by cells
    # ---- goodput mode (Fig. 8 frontier) ------------------------------- #
    mode: str = "fixed"               # "fixed" | "goodput"
    target_attainment: float = 0.9
    goodput_lo: float = 0.25          # search bracket (req/s)
    goodput_hi: float = 32.0
    goodput_tol: float = 0.10         # relative rate tolerance
    # append one JSONL row per finished cell (crash/interrupt recovery)
    stream_path: Optional[str] = None

    def __post_init__(self):
        if self.strategies is None:
            from repro.baselines import STRATEGIES
            self.strategies = STRATEGIES
        if self.mode not in ("fixed", "goodput"):
            raise ValueError(f"unknown mode {self.mode!r}; "
                             "expected 'fixed' or 'goodput'")

    def cells(self) -> List[Dict]:
        common = dict(model=self.model, hw=self.hw, tp=self.tp, pp=self.pp,
                      n_instances=self.n_instances, workload=self.workload,
                      duration=self.duration, warmup=self.warmup)
        out = []
        if self.mode == "goodput":
            common.update(mode="goodput",
                          target_attainment=self.target_attainment,
                          goodput_lo=self.goodput_lo,
                          goodput_hi=self.goodput_hi,
                          goodput_tol=self.goodput_tol)
            for strat in self.strategies:
                for scen in self.scenarios:
                    # rate 0.0 = the search's seed sentinel: one seed per
                    # (strategy, scenario), shared by every probe
                    out.append({**common, "strategy": strat,
                                "scenario": scen,
                                "seed": cell_seed(self.base_seed, strat,
                                                  scen, 0.0)})
            return out
        for strat in self.strategies:
            for scen in self.scenarios:
                for rate in self.rates:
                    out.append({**common, "strategy": strat,
                                "scenario": scen, "rate": rate,
                                "seed": cell_seed(self.base_seed, strat,
                                                  scen, rate)})
        return out

    def run(self) -> Dict:
        specs = self.cells()
        workers = self.n_workers
        if workers is None:
            workers = min(len(specs), multiprocessing.cpu_count())
        rows: List[Optional[Dict]] = [None] * len(specs)
        stream = open(self.stream_path, "a") if self.stream_path else None
        try:
            if workers > 1:
                # spawn, not fork: the parent may have imported jax
                # (pytest, notebooks), and forking a multithreaded process
                # can deadlock
                ctx = multiprocessing.get_context("spawn")
                with ctx.Pool(workers) as pool:
                    for idx, row in pool.imap_unordered(
                            _run_cell_safe, list(enumerate(specs))):
                        rows[idx] = row
                        self._stream_row(stream, idx, row)
            else:
                for idx, spec in enumerate(specs):
                    _, row = _run_cell_safe((idx, spec))
                    rows[idx] = row
                    self._stream_row(stream, idx, row)
        finally:
            if stream is not None:
                stream.close()
        meta = dataclasses.asdict(self)
        meta.pop("n_workers")        # parallelism does not affect results
        meta.pop("stream_path")      # neither does streaming
        if self.mode == "fixed":     # keep legacy golden meta stable
            for k in _GOODPUT_FIELDS:
                meta.pop(k)
        meta["strategies"] = list(self.strategies)
        meta["scenarios"] = list(self.scenarios)
        meta["rates"] = list(self.rates)
        results = {"meta": meta, "cells": rows}
        errors = [r for r in rows if r is not None and "error" in r]
        if errors:
            results["errors"] = [
                {k: v for k, v in r.items() if k != "traceback"}
                for r in errors]
        return results

    @staticmethod
    def _stream_row(stream, idx: int, row: Dict) -> None:
        if stream is None:
            return
        stream.write(json.dumps({"cell_index": idx, **row},
                                sort_keys=True) + "\n")
        stream.flush()

    # ------------------------------------------------------------------ #
    @staticmethod
    def grid(results: Dict) -> Dict[str, Dict[str, Dict[float, Dict]]]:
        """Pivot the flat cell list to [strategy][scenario][rate]
        (fixed mode) or [strategy][scenario] (goodput mode)."""
        out: Dict[str, Dict[str, Dict]] = {}
        for cell in results["cells"]:
            by_scen = out.setdefault(cell["strategy"], {})
            if cell.get("mode") == "goodput":
                by_scen[cell["scenario"]] = cell.get("metrics", cell)
            else:
                by_scen.setdefault(cell["scenario"], {})[cell["rate"]] = \
                    cell.get("metrics", cell)
        return out

    @staticmethod
    def save(results: Dict, path) -> None:
        with open(path, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
            f.write("\n")

    @staticmethod
    def load(path) -> Dict:
        with open(path) as f:
            return json.load(f)


# --------------------------------------------------------------------- #
# The canonical regression grid: small enough to run in CI, wide enough
# to pin every strategy x scenario pair.  bench_scenarios --write-golden
# regenerates tests/golden/scenario_grid.json from exactly this spec.
# --------------------------------------------------------------------- #

def regression_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "sarathi", "distserve", "mooncake"),
        scenarios=("poisson", "bursty", "diurnal", "replay"),
        rates=(6.0,),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=20.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)


def goodput_runner(n_workers: Optional[int] = None) -> ExperimentRunner:
    """The canonical goodput-frontier grid (Fig. 8 per traffic shape),
    sized for CI; pinned by tests/golden/goodput_frontier.json.  The
    duration/lo pairing keeps >= ~24 scored requests per probe so a
    single end-of-window straggler can't sink the completion factor."""
    return ExperimentRunner(
        strategies=("ecoserve", "vllm", "mooncake"),
        scenarios=("poisson", "bursty"),
        mode="goodput", target_attainment=0.9,
        goodput_lo=1.0, goodput_hi=24.0, goodput_tol=0.35,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=24.0, warmup=3.0,
        base_seed=42, n_workers=n_workers)
