from repro.simulator.cost_model import (  # noqa: F401
    GPU_L20, GPU_A800, TPU_V5E_SIM, HardwareProfile, InstanceCostModel)
from repro.simulator.workload import WORKLOADS, WorkloadGen  # noqa: F401
from repro.simulator.engine import SimulationEngine          # noqa: F401
from repro.simulator.scenarios import (  # noqa: F401
    SCENARIO_KINDS, Scenario, TraceReplay, make_scenario, write_trace)
from repro.simulator.runner import ExperimentRunner          # noqa: F401
