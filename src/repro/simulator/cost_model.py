"""Analytical (roofline) instance cost model for the cluster simulator.

Step durations are derived from the model config + hardware profile with
per-phase efficiency factors calibrated against the paper's own Table 3
measurements (Llama-30B prefill on an 8x L20 node: 6584.6 tok/s; on 8x
A800: 26189.2 tok/s — see tests/test_cost_model.py for the check).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops: float               # peak bf16 FLOP/s per device
    hbm_bw: float              # bytes/s per device
    hbm_bytes: float           # capacity per device
    intra_node_bw: float       # bytes/s per device for intra-node traffic
    inter_node_bw: float       # bytes/s per NODE (NIC)
    devices_per_node: int
    prefill_eff: float         # achieved fraction of peak in prefill
    decode_bw_eff: float       # achieved fraction of HBM bw in decode
    comm_latency: float = 30e-6   # per collective hop


# L20: 119.5 TF bf16 peak, 864 GB/s GDDR6, PCIe4 x16 (~25 GB/s eff),
# 10 Gb Ethernet per node.  Efficiency calibrated to Table 3.
GPU_L20 = HardwareProfile(
    name="L20", flops=119.5e12, hbm_bw=864e9, hbm_bytes=48e9,
    intra_node_bw=25e9, inter_node_bw=10e9 / 8, devices_per_node=8,
    prefill_eff=0.47, decode_bw_eff=0.75)

# A800: 312 TF bf16, 2039 GB/s HBM2e, NVLink absent in paper's PCIe setup,
# 25 Gb RoCE per node.
GPU_A800 = HardwareProfile(
    name="A800", flops=312e12, hbm_bw=2039e9, hbm_bytes=80e9,
    intra_node_bw=25e9, inter_node_bw=25e9 / 8, devices_per_node=8,
    prefill_eff=0.60, decode_bw_eff=0.75)

# TPU v5e (the build target): ICI intra-pod, slow DCN across pods.
TPU_V5E_SIM = HardwareProfile(
    name="tpu-v5e", flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
    intra_node_bw=50e9, inter_node_bw=25e9 / 8, devices_per_node=256,
    prefill_eff=0.55, decode_bw_eff=0.80)


@dataclasses.dataclass(frozen=True)
class InstanceCostModel:
    """Cost model for ONE serving instance = `tp` x `pp` devices."""
    cfg: ModelConfig
    hw: HardwareProfile
    tp: int = 1
    pp: int = 1
    dtype_bytes: int = 2

    # ------------------------------------------------------------------ #
    @property
    def devices(self) -> int:
        return self.tp * self.pp

    @property
    def param_bytes(self) -> int:
        return self.cfg.param_count() * self.dtype_bytes

    def kv_capacity_tokens(self) -> int:
        """Tokens of KV cache that fit after weights (10% activation slack)."""
        per_tok = self.cfg.kv_bytes_per_token(self.dtype_bytes)
        if per_tok == 0:                       # attention-free: effectively
            return 10_000_000                  # unbounded by KV memory
        free = (self.hw.hbm_bytes * self.devices * 0.9) - self.param_bytes
        return max(0, int(free / per_tok))

    # ------------------------------------------------------------------ #
    def _tp_comm_time(self, tokens: int) -> float:
        """Megatron TP: 2 all-reduce per layer over activations."""
        if self.tp == 1:
            return 0.0
        bytes_ar = tokens * self.cfg.d_model * self.dtype_bytes
        wire = 2.0 * bytes_ar * (self.tp - 1) / self.tp      # ring
        per_layer = wire / self.hw.intra_node_bw + self.hw.comm_latency
        return 2 * self.cfg.num_layers * per_layer

    def _pp_overhead(self, t_stage_total: float, microbatches: int) -> float:
        """Pipeline bubble: (pp-1)/m extra on top of the stage time."""
        if self.pp == 1:
            return 0.0
        return t_stage_total * (self.pp - 1) / max(1, microbatches)

    # ------------------------------------------------------------------ #
    def prefill_time(self, prompt_lens: List[int],
                     kv_prefix_lens: Optional[List[int]] = None) -> float:
        """One prefill batch (PaDG/NoDG: full prompts; Sarathi passes
        chunks with kv_prefix_lens for the re-read of earlier chunks)."""
        if not prompt_lens:
            return 0.0
        n_active = self.cfg.param_count(active_only=True)
        tokens = sum(prompt_lens)
        flops = 2.0 * n_active * tokens
        # attention: 2 matmuls of S^2 * H per head-dim-summed layer
        attn_layers = sum(
            1 for k in self.cfg.block_kinds() if k in ("attn", "local"))
        for i, s in enumerate(prompt_lens):
            ctx = s + (kv_prefix_lens[i] if kv_prefix_lens else 0)
            eff_ctx = min(ctx, self.cfg.sliding_window) if (
                self.cfg.sliding_window) else ctx
            flops += 4.0 * attn_layers * s * eff_ctx * self.cfg.d_model
        t_compute = flops / (self.hw.flops * self.tp * self.hw.prefill_eff)
        # weight + kv-prefix reads
        bytes_moved = self.param_bytes / self.devices * min(
            1.0, tokens / 256.0)   # weight reads amortize over the batch
        if kv_prefix_lens:
            bytes_moved += sum(kv_prefix_lens) * \
                self.cfg.kv_bytes_per_token(self.dtype_bytes) / self.devices
        t_mem = bytes_moved / (self.hw.hbm_bw * self.hw.decode_bw_eff)
        t = max(t_compute, t_mem) / self.pp + self._tp_comm_time(tokens)
        return t + self._pp_overhead(t, microbatches=len(prompt_lens))

    def decode_time(self, batch_size: int, ctx_lens: List[int]) -> float:
        """One decode iteration for `batch_size` sequences.

        PP does NOT cut single-batch decode latency (Fig. 11's premise):
        the pp stages run sequentially for one iteration, so weights/KV
        stream through only a tp-wide memory system."""
        if batch_size == 0:
            return 0.0
        n_active = self.cfg.param_count(active_only=True)
        flops = 2.0 * n_active * batch_size
        t_compute = flops / (self.hw.flops * self.tp * 0.35)
        per_tok = self.cfg.kv_bytes_per_token(self.dtype_bytes)
        eff_ctxs = [min(c, self.cfg.sliding_window) if self.cfg.sliding_window
                    else c for c in ctx_lens]
        kv_bytes = per_tok * sum(eff_ctxs)
        bytes_moved = (self.param_bytes + kv_bytes) / self.tp
        t_mem = bytes_moved / (self.hw.hbm_bw * self.hw.decode_bw_eff)
        t = max(t_compute, t_mem) + self._tp_comm_time(batch_size)
        # pp point-to-point hops (small activations)
        t += (self.pp - 1) * self.hw.comm_latency
        return t

    def hybrid_time(self, chunk_lens: List[int], prefix_lens: List[int],
                    decode_batch: int, decode_ctxs: List[int]) -> float:
        """Sarathi-style fused iteration: decode batch + prefill chunks.
        Compute and memory streams overlap; chunked prefill re-reads the
        KV prefix of earlier chunks (the paper's §2.4.1 criticism)."""
        n_active = self.cfg.param_count(active_only=True)
        flops = 2.0 * n_active * (sum(chunk_lens) + decode_batch)
        attn_layers = sum(
            1 for k in self.cfg.block_kinds() if k in ("attn", "local"))
        for s, p in zip(chunk_lens, prefix_lens):
            flops += 4.0 * attn_layers * s * (s + p) * self.cfg.d_model
        t_compute = flops / (self.hw.flops * self.tp * self.hw.prefill_eff)

        per_tok = self.cfg.kv_bytes_per_token(self.dtype_bytes)
        bytes_moved = self.param_bytes / self.devices
        bytes_moved += per_tok * sum(prefix_lens) / self.devices  # re-read
        eff_ctxs = [min(c, self.cfg.sliding_window) if self.cfg.sliding_window
                    else c for c in decode_ctxs]
        bytes_moved += per_tok * sum(eff_ctxs) / self.devices
        t_mem = bytes_moved * self.pp / (
            self.hw.hbm_bw * self.hw.decode_bw_eff)
        tokens = sum(chunk_lens) + decode_batch
        # hybrid iteration latency is decode-like: pp stages run
        # sequentially (t_compute above is already tp-width)
        t = max(t_compute, t_mem) + self._tp_comm_time(tokens)
        t += (self.pp - 1) * self.hw.comm_latency
        return t

    # ------------------------------------------------------------------ #
    def kv_transfer_bytes(self, prompt_len: int) -> int:
        """KV cache bytes leaving a FuDG prefill instance per request."""
        return prompt_len * self.cfg.kv_bytes_per_token(self.dtype_bytes)

    def predict_prefill(self, prompt_len: int) -> float:
        """Single-request prefill-duration predictor used by Algorithm 2
        (paper: profiled offline over sequence lengths)."""
        return self.prefill_time([prompt_len])
