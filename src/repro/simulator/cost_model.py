"""Analytical (roofline) instance cost model for the cluster simulator.

Step durations are derived from the model config + hardware profile with
per-phase efficiency factors calibrated against the paper's own Table 3
measurements (Llama-30B prefill on an 8x L20 node: 6584.6 tok/s; on 8x
A800: 26189.2 tok/s — see tests/test_cost_model.py for the check).

The model is on the simulator's innermost loop (one ``decode_time`` call
per decode iteration per instance), so all config-derived quantities
(parameter counts, KV bytes/token, attention-layer count, roofline
denominators) are computed once per ``InstanceCostModel`` and memoized in
``_Consts``.  The memoized arithmetic keeps the exact floating-point
operation order of the original formulas — results are bit-identical, so
the golden regression grids do not move.

``decode_time``/``hybrid_time`` additionally accept a precomputed
effective-context *sum* (``ctx_sum``/``decode_ctx_sum``) so hot callers
(``Instance``) can skip building a per-iteration Python list; context
lengths are ints, so the summed fast path is exactly equal to the
per-element path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    flops: float               # peak bf16 FLOP/s per device
    hbm_bw: float              # bytes/s per device
    hbm_bytes: float           # capacity per device
    intra_node_bw: float       # bytes/s per device for intra-node traffic
    inter_node_bw: float       # bytes/s per NODE (NIC)
    devices_per_node: int
    prefill_eff: float         # achieved fraction of peak in prefill
    decode_bw_eff: float       # achieved fraction of HBM bw in decode
    comm_latency: float = 30e-6   # per collective hop


# L20: 119.5 TF bf16 peak, 864 GB/s GDDR6, PCIe4 x16 (~25 GB/s eff),
# 10 Gb Ethernet per node.  Efficiency calibrated to Table 3.
GPU_L20 = HardwareProfile(
    name="L20", flops=119.5e12, hbm_bw=864e9, hbm_bytes=48e9,
    intra_node_bw=25e9, inter_node_bw=10e9 / 8, devices_per_node=8,
    prefill_eff=0.47, decode_bw_eff=0.75)

# A800: 312 TF bf16, 2039 GB/s HBM2e, NVLink absent in paper's PCIe setup,
# 25 Gb RoCE per node.
GPU_A800 = HardwareProfile(
    name="A800", flops=312e12, hbm_bw=2039e9, hbm_bytes=80e9,
    intra_node_bw=25e9, inter_node_bw=25e9 / 8, devices_per_node=8,
    prefill_eff=0.60, decode_bw_eff=0.75)

# TPU v5e (the build target): ICI intra-pod, slow DCN across pods.
TPU_V5E_SIM = HardwareProfile(
    name="tpu-v5e", flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
    intra_node_bw=50e9, inter_node_bw=25e9 / 8, devices_per_node=256,
    prefill_eff=0.55, decode_bw_eff=0.80)


@dataclasses.dataclass(frozen=True)
class _Consts:
    """Per-(cfg, hw, tp, pp) constants hoisted out of the hot path."""
    n_active: int              # active parameters (MoE: top-k experts)
    param_bytes: int
    kv_per_tok: int
    attn_layers: int
    sliding_window: int
    prefill_flops_denom: float   # hw.flops * tp * prefill_eff
    decode_flops_denom: float    # hw.flops * tp * 0.35
    mem_denom: float             # hw.hbm_bw * decode_bw_eff


@dataclasses.dataclass(frozen=True)
class InstanceCostModel:
    """Cost model for ONE serving instance = `tp` x `pp` devices."""
    cfg: ModelConfig
    hw: HardwareProfile
    tp: int = 1
    pp: int = 1
    dtype_bytes: int = 2

    # ------------------------------------------------------------------ #
    @property
    def devices(self) -> int:
        return self.tp * self.pp

    @property
    def _c(self) -> _Consts:
        # memoized via the instance __dict__ (frozen dataclass: direct
        # dict insertion sidesteps the generated __setattr__)
        c = self.__dict__.get("_consts")
        if c is None:
            cfg, hw = self.cfg, self.hw
            c = _Consts(
                n_active=cfg.param_count(active_only=True),
                param_bytes=cfg.param_count() * self.dtype_bytes,
                kv_per_tok=cfg.kv_bytes_per_token(self.dtype_bytes),
                attn_layers=sum(1 for k in cfg.block_kinds()
                                if k in ("attn", "local")),
                sliding_window=cfg.sliding_window,
                prefill_flops_denom=hw.flops * self.tp * hw.prefill_eff,
                decode_flops_denom=hw.flops * self.tp * 0.35,
                mem_denom=hw.hbm_bw * hw.decode_bw_eff,
            )
            self.__dict__["_consts"] = c
        return c

    @property
    def param_bytes(self) -> int:
        return self._c.param_bytes

    @property
    def ctx_clamp(self) -> int:
        """Per-sequence context clamp for decode KV reads (0 = unbounded).
        Callers maintaining an incremental context sum must clamp each
        sequence at this value for ``ctx_sum`` fast paths to stay exact."""
        return self._c.sliding_window

    def kv_capacity_tokens(self) -> int:
        """Tokens of KV cache that fit after weights (10% activation slack)."""
        per_tok = self._c.kv_per_tok
        if per_tok == 0:                       # attention-free: effectively
            return 10_000_000                  # unbounded by KV memory
        free = (self.hw.hbm_bytes * self.devices * 0.9) - self.param_bytes
        return max(0, int(free / per_tok))

    # ------------------------------------------------------------------ #
    def _tp_comm_time(self, tokens: int) -> float:
        """Megatron TP: 2 all-reduce per layer over activations."""
        if self.tp == 1:
            return 0.0
        memo = self.__dict__.setdefault("_comm_memo", {})
        t = memo.get(tokens)
        if t is None:
            bytes_ar = tokens * self.cfg.d_model * self.dtype_bytes
            wire = 2.0 * bytes_ar * (self.tp - 1) / self.tp      # ring
            per_layer = wire / self.hw.intra_node_bw + self.hw.comm_latency
            t = 2 * self.cfg.num_layers * per_layer
            memo[tokens] = t
        return t

    def _pp_overhead(self, t_stage_total: float, microbatches: int) -> float:
        """Pipeline bubble: (pp-1)/m extra on top of the stage time."""
        if self.pp == 1:
            return 0.0
        return t_stage_total * (self.pp - 1) / max(1, microbatches)

    @staticmethod
    def _eff_ctx_sum(ctx_lens: List[int], sliding_window: int) -> int:
        if sliding_window:
            return sum(min(c, sliding_window) for c in ctx_lens)
        return sum(ctx_lens)

    # ------------------------------------------------------------------ #
    def prefill_time(self, prompt_lens: List[int],
                     kv_prefix_lens: Optional[List[int]] = None) -> float:
        """One prefill batch (PaDG/NoDG: full prompts; Sarathi passes
        chunks with kv_prefix_lens for the re-read of earlier chunks)."""
        if not prompt_lens:
            return 0.0
        c = self._c
        tokens = sum(prompt_lens)
        flops = 2.0 * c.n_active * tokens
        # attention: 2 matmuls of S^2 * H per head-dim-summed layer
        for i, s in enumerate(prompt_lens):
            ctx = s + (kv_prefix_lens[i] if kv_prefix_lens else 0)
            eff_ctx = min(ctx, c.sliding_window) if c.sliding_window else ctx
            flops += 4.0 * c.attn_layers * s * eff_ctx * self.cfg.d_model
        t_compute = flops / c.prefill_flops_denom
        # weight + kv-prefix reads
        bytes_moved = c.param_bytes / self.devices * min(
            1.0, tokens / 256.0)   # weight reads amortize over the batch
        if kv_prefix_lens:
            bytes_moved += sum(kv_prefix_lens) * c.kv_per_tok / self.devices
        t_mem = bytes_moved / c.mem_denom
        t = max(t_compute, t_mem) / self.pp + self._tp_comm_time(tokens)
        return t + self._pp_overhead(t, microbatches=len(prompt_lens))

    def decode_time(self, batch_size: int,
                    ctx_lens: Optional[List[int]] = None,
                    *, ctx_sum: Optional[int] = None) -> float:
        """One decode iteration for `batch_size` sequences.

        Accepts either the per-sequence context lengths (``ctx_lens``) or
        their precomputed effective sum (``ctx_sum``, already clamped at
        ``ctx_clamp``); integer context lengths make the two exactly equal.

        PP does NOT cut single-batch decode latency (Fig. 11's premise):
        the pp stages run sequentially for one iteration, so weights/KV
        stream through only a tp-wide memory system."""
        if batch_size == 0:
            return 0.0
        c = self._c
        flops = 2.0 * c.n_active * batch_size
        t_compute = flops / c.decode_flops_denom
        if ctx_sum is None:
            ctx_sum = self._eff_ctx_sum(ctx_lens, c.sliding_window)
        kv_bytes = c.kv_per_tok * ctx_sum
        bytes_moved = (c.param_bytes + kv_bytes) / self.tp
        t_mem = bytes_moved / c.mem_denom
        t = max(t_compute, t_mem) + self._tp_comm_time(batch_size)
        # pp point-to-point hops (small activations)
        t += (self.pp - 1) * self.hw.comm_latency
        return t

    def hybrid_time(self, chunk_lens: List[int], prefix_lens: List[int],
                    decode_batch: int,
                    decode_ctxs: Optional[List[int]] = None,
                    *, decode_ctx_sum: Optional[int] = None) -> float:
        """Sarathi-style fused iteration: decode batch + prefill chunks.
        Compute and memory streams overlap; chunked prefill re-reads the
        KV prefix of earlier chunks (the paper's §2.4.1 criticism).
        ``decode_ctx_sum`` is the clamped-context fast path, as in
        ``decode_time``."""
        c = self._c
        flops = 2.0 * c.n_active * (sum(chunk_lens) + decode_batch)
        for s, p in zip(chunk_lens, prefix_lens):
            flops += 4.0 * c.attn_layers * s * (s + p) * self.cfg.d_model
        t_compute = flops / c.prefill_flops_denom

        if decode_ctx_sum is None:
            decode_ctx_sum = self._eff_ctx_sum(decode_ctxs, c.sliding_window)
        bytes_moved = c.param_bytes / self.devices
        bytes_moved += c.kv_per_tok * sum(prefix_lens) / self.devices
        bytes_moved += c.kv_per_tok * decode_ctx_sum / self.devices
        t_mem = bytes_moved * self.pp / c.mem_denom
        tokens = sum(chunk_lens) + decode_batch
        # hybrid iteration latency is decode-like: pp stages run
        # sequentially (t_compute above is already tp-width)
        t = max(t_compute, t_mem) + self._tp_comm_time(tokens)
        t += (self.pp - 1) * self.hw.comm_latency
        return t

    # ------------------------------------------------------------------ #
    def kv_transfer_bytes(self, prompt_len: int) -> int:
        """KV cache bytes leaving a FuDG prefill instance per request."""
        return prompt_len * self._c.kv_per_tok

    def predict_prefill(self, prompt_len: int) -> float:
        """Single-request prefill-duration predictor used by Algorithm 2
        (paper: profiled offline over sequence lengths).  Memoized per
        prompt length — Algorithm 1 probes every instance's pending queue
        with it at each slot boundary."""
        memo = self.__dict__.setdefault("_prefill_memo", {})
        t = memo.get(prompt_len)
        if t is None:
            t = self.prefill_time([prompt_len])
            memo[prompt_len] = t
        return t


# Serialized field order of ``FittedExecutor`` — module-level (a tuple
# class attribute on a frozen dataclass would become a field).
FITTED_CONSTANT_FIELDS = (
    "prefill_base", "prefill_per_token", "decode_base",
    "decode_per_seq", "decode_per_ctx_token",
    "kv_capacity", "kv_bytes_per_token", "ctx_clamp")


@dataclasses.dataclass(frozen=True)
class FittedExecutor:
    """Linear cost model with *measured* constants (sim-to-real write-back).

    Implements the full ``InstanceCostModel`` surface the scheduling stack
    uses — ``prefill_time``/``decode_time``/``hybrid_time``/
    ``predict_prefill``/``kv_capacity_tokens``/``kv_transfer_bytes``/
    ``ctx_clamp`` — but with flat per-token linear forms whose constants
    come from ``repro.serving.calibration`` least-squares fits of live
    engine step timings, so simulator cells can replay with measured
    throughput instead of roofline estimates.  ``predict_prefill(n)`` is
    arithmetically identical to ``prefill_time([n])`` (no memo needed:
    both are one multiply-add), which the conformance suite relies on.
    """
    prefill_base: float = 0.0
    prefill_per_token: float = 1e-4
    decode_base: float = 0.0
    decode_per_seq: float = 1e-4
    decode_per_ctx_token: float = 0.0
    kv_capacity: int = 10_000_000
    kv_bytes_per_token: int = 0
    ctx_clamp: int = 0

    # ------------------------------------------------------------------ #
    def prefill_time(self, prompt_lens: List[int],
                     kv_prefix_lens: Optional[List[int]] = None) -> float:
        if not prompt_lens:
            return 0.0
        tokens = sum(prompt_lens)
        if kv_prefix_lens:
            tokens += sum(kv_prefix_lens)
        return self.prefill_base + self.prefill_per_token * tokens

    def predict_prefill(self, prompt_len: int) -> float:
        return self.prefill_base + self.prefill_per_token * prompt_len

    def decode_time(self, batch_size: int,
                    ctx_lens: Optional[List[int]] = None,
                    *, ctx_sum: Optional[int] = None) -> float:
        if batch_size == 0:
            return 0.0
        if ctx_sum is None:
            ctx_sum = InstanceCostModel._eff_ctx_sum(
                ctx_lens or [], self.ctx_clamp)
        return (self.decode_base + self.decode_per_seq * batch_size
                + self.decode_per_ctx_token * ctx_sum)

    def hybrid_time(self, chunk_lens: List[int], prefix_lens: List[int],
                    decode_batch: int,
                    decode_ctxs: Optional[List[int]] = None,
                    *, decode_ctx_sum: Optional[int] = None) -> float:
        t = self.prefill_time(chunk_lens, prefix_lens)
        if decode_batch:
            t += self.decode_time(decode_batch, decode_ctxs,
                                  ctx_sum=decode_ctx_sum)
        return t

    # ------------------------------------------------------------------ #
    def kv_capacity_tokens(self) -> int:
        return self.kv_capacity

    def kv_transfer_bytes(self, prompt_len: int) -> int:
        return prompt_len * self.kv_bytes_per_token

    # ------------------------------------------------------------------ #
    def to_json(self) -> dict:
        return {k: getattr(self, k) for k in FITTED_CONSTANT_FIELDS}

    @classmethod
    def from_json(cls, d: dict) -> "FittedExecutor":
        kw = {k: d[k] for k in FITTED_CONSTANT_FIELDS if k in d}
        return cls(**kw)

    @classmethod
    def from_constants(cls, consts: dict,
                       like: Optional[InstanceCostModel] = None
                       ) -> "FittedExecutor":
        """Build from fitted timing constants, inheriting the capacity /
        transfer geometry of an analytic model (``like``) so the fitted
        cell admits exactly as many requests as the analytic one."""
        kw = {k: consts[k] for k in FITTED_CONSTANT_FIELDS if k in consts}
        if like is not None:
            kw.setdefault("kv_capacity", like.kv_capacity_tokens())
            kw.setdefault("kv_bytes_per_token", like._c.kv_per_tok)
            kw.setdefault("ctx_clamp", like.ctx_clamp)
        return cls(**kw)
