"""Discrete-event simulation engine.

Drives any ``ServingSystem`` (PaDG / NoDG / FuDG variants): request
arrivals, instance slot completions, and link transfers share one event
timeline.  Instances execute uninterruptible slots (prefill batch or
decode iteration); systems decide routing and what happens at slot
boundaries.

Arrivals are fed lazily from the (time-sorted) request list instead of
pre-pushing one heap event per request: the heap only ever holds in-flight
completions/transfers, and no per-request closure is allocated.  Ties are
resolved exactly as the old pre-pushed encoding did — an arrival at time t
fires before any completion scheduled at the same t (arrivals used to
carry the lowest sequence numbers), and equal-time arrivals fire in
request-list order (stable sort).  Slot completions are dispatched through
one engine method with an argument tuple stored on the event, not a fresh
closure capturing per-request state.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.instance import Instance
from repro.core.request import Request
from repro.core.system import ServingSystem  # noqa: F401  (re-export: the
# formal protocol moved to repro.core.system; engine callers keep working)
from repro.obs.events import NULL_TRACER, attach_decision_log


class Link:
    """FIFO bandwidth resource (NIC / PCIe); serializes transfers."""

    def __init__(self, name: str, bandwidth: float, latency: float = 1e-3):
        self.name = name
        self.bandwidth = bandwidth
        self.latency = latency
        self.busy_until = 0.0
        self.bytes_moved = 0.0

    def transfer(self, nbytes: float, now: float, factor: float = 1.0,
                 extra_latency: float = 0.0) -> float:
        """Occupy the link for one message; ``factor`` divides the rated
        bandwidth and ``extra_latency`` adds propagation delay (the
        transport's network-degradation path; defaults are the clean
        link, bit-identical to the historic two-argument form)."""
        start = max(now, self.busy_until)
        done = (start + self.latency + extra_latency
                + factor * (nbytes / self.bandwidth))
        self.busy_until = done
        self.bytes_moved += nbytes
        return done


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = dataclasses.field(compare=False)
    args: Tuple = dataclasses.field(compare=False, default=())


class SimulationEngine:
    # Flight-recorder hook (repro.obs): NULL_TRACER keeps the hot path
    # allocation-free — every emission site is guarded by one attribute
    # read.  ``attach_tracer`` swaps in a live Tracer.
    tracer = NULL_TRACER
    _decision_log: Optional[List] = None

    @property
    def decision_log(self) -> Optional[List]:
        """Compat shim for the PR 8 scheduling-decision trace: attaching
        a list here installs it as a tracer mirror, so ``activate``
        appends the historic ("slot", t_start, iid, kind, duration,
        (rids...)) tuples through the event bus.  Shared with
        ``PolicySystemBase.decision_log`` so admission and slot events
        interleave into one totally ordered sequence."""
        return self._decision_log

    @decision_log.setter
    def decision_log(self, log: Optional[List]) -> None:
        attach_decision_log(self, log)

    def __init__(self, system: ServingSystem):
        self.system = system
        self.heap: List[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self._executing: Dict[int, bool] = {}
        self.finished: List[Request] = []
        self.on_tick: Optional[Callable[[float], None]] = None

    # ------------------------------------------------------------------ #
    def push(self, t: float, fn: Callable) -> None:
        heapq.heappush(self.heap, _Event(t, next(self._seq), fn))

    def push_call(self, t: float, fn: Callable, *args) -> None:
        """Schedule ``fn(*args)`` at time ``t`` without a closure."""
        heapq.heappush(self.heap, _Event(t, next(self._seq), fn, args))

    def activate(self, inst: Instance) -> None:
        """Ensure the instance is executing a slot (idempotent)."""
        if not inst.alive:
            return
        if self._executing.get(inst.iid):
            return
        kind, dur, reqs = inst.next_slot(self.now)
        if kind == "idle":
            return
        trc = self.tracer
        if trc.enabled:
            trc.slot(self.now, inst, kind, dur, reqs,
                     len(getattr(self.system, "queue", ())))
        self._executing[inst.iid] = True
        t_end = self.now + dur
        self.push_call(t_end, self._complete_slot, inst, kind, reqs, t_end)

    def _complete_slot(self, inst: Instance, kind: str,
                       reqs: List[Request], t_end: float) -> None:
        self._executing[inst.iid] = False
        if not inst.alive:
            # the instance died mid-slot (repro.faults): the slot's work
            # is lost with its KV — the fault path already re-routed the
            # affected requests, so applying completion here would corrupt
            # their (possibly re-running) state and the dead instance's
            # aggregates
            return
        trc = self.tracer
        if kind == "prefill" and not inst.decode_here:
            # FuDG prefill instance: mark first token, hand off
            inst.handoff_prefilled(reqs, t_end)
            if trc.enabled:
                trc.handoff(t_end, inst.iid, reqs)
            self.system.on_slot_end(inst, "prefill_handoff", reqs,
                                    self.now, self)
        else:
            done = inst.complete_slot(kind, reqs, t_end)
            self.finished.extend(done)
            if trc.enabled and done:
                for r in done:
                    trc.finish(t_end, r.rid)
            self.system.on_slot_end(inst, kind, reqs, self.now, self)
        self.activate(inst)

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request], horizon: float) -> List[Request]:
        # stable sort == (arrival_time, original index): the exact total
        # order the old per-request heap events produced
        arrivals = sorted(requests, key=lambda r: r.arrival_time)
        i, n = 0, len(arrivals)
        heap = self.heap
        while True:
            t_arr = arrivals[i].arrival_time if i < n else None
            if heap and (t_arr is None or heap[0].time < t_arr):
                ev = heapq.heappop(heap)
                if ev.time > horizon:
                    break
                self.now = ev.time
                ev.fn(*ev.args)
            elif t_arr is not None:
                # t_arr <= next event time: arrivals win ties
                if t_arr > horizon:
                    break
                self.now = t_arr
                req = arrivals[i]
                i += 1
                trc = self.tracer
                if trc.enabled:
                    trc.arrive(t_arr, req)
                self.system.submit(req, self.now, self)
            else:
                break
            if self.on_tick:
                self.on_tick(self.now)
        return self.finished
