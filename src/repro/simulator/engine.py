"""Discrete-event simulation engine.

Drives any ``ServingSystem`` (PaDG / NoDG / FuDG variants): request
arrivals, instance slot completions, and link transfers share one event
heap.  Instances execute uninterruptible slots (prefill batch or decode
iteration); systems decide routing and what happens at slot boundaries.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Protocol

from repro.core.instance import Instance
from repro.core.request import Request


class Link:
    """FIFO bandwidth resource (NIC / PCIe); serializes transfers."""

    def __init__(self, name: str, bandwidth: float, latency: float = 1e-3):
        self.name = name
        self.bandwidth = bandwidth
        self.latency = latency
        self.busy_until = 0.0
        self.bytes_moved = 0.0

    def transfer(self, nbytes: float, now: float) -> float:
        start = max(now, self.busy_until)
        done = start + self.latency + nbytes / self.bandwidth
        self.busy_until = done
        self.bytes_moved += nbytes
        return done


class ServingSystem(Protocol):
    instances: List[Instance]

    def submit(self, req: Request, now: float, engine: "SimulationEngine"): ...
    def on_slot_end(self, inst: Instance, kind: str, reqs: List[Request],
                    now: float, engine: "SimulationEngine") -> None: ...


@dataclasses.dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = dataclasses.field(compare=False)


class SimulationEngine:
    def __init__(self, system: ServingSystem):
        self.system = system
        self.heap: List[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self._executing: Dict[int, bool] = {}
        self.finished: List[Request] = []
        self.on_tick: Optional[Callable[[float], None]] = None

    # ------------------------------------------------------------------ #
    def push(self, t: float, fn: Callable) -> None:
        heapq.heappush(self.heap, _Event(t, next(self._seq), fn))

    def activate(self, inst: Instance) -> None:
        """Ensure the instance is executing a slot (idempotent)."""
        if self._executing.get(inst.iid):
            return
        kind, dur, reqs = inst.next_slot(self.now)
        if kind == "idle":
            return
        self._executing[inst.iid] = True
        t_end = self.now + dur

        def complete():
            self._executing[inst.iid] = False
            if kind == "prefill" and not getattr(inst, "decode_here", True):
                # FuDG prefill instance: mark first token, hand off
                for r in reqs:
                    inst.pending.remove(r)
                    r.first_token_time = t_end
                    r.tokens_generated = 1
                self.system.on_slot_end(inst, "prefill_handoff", reqs,
                                        self.now, self)
            else:
                done = inst.complete_slot(kind, reqs, t_end)
                self.finished.extend(done)
                self.system.on_slot_end(inst, kind, reqs, self.now, self)
            self.activate(inst)

        self.push(t_end, complete)

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request], horizon: float) -> List[Request]:
        for req in requests:
            def arrive(r=req):
                self.system.submit(r, self.now, self)
            self.push(req.arrival_time, arrive)

        while self.heap:
            ev = heapq.heappop(self.heap)
            if ev.time > horizon:
                break
            self.now = ev.time
            ev.fn()
            if self.on_tick:
                self.on_tick(self.now)
        return self.finished
