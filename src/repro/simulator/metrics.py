"""Goodput measurement: max request rate sustaining an SLO-attainment
percentile (the paper's Fig. 8 metric)."""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.slo import SLO, attainment, percentile_latencies
from repro.simulator.engine import SimulationEngine
from repro.simulator.workload import WorkloadGen, WorkloadProfile


def as_scenario(workload, rate: float, seed: int):
    """Normalize the workload argument to something with ``generate``.

    Accepts a ``WorkloadProfile`` (wrapped in a Poisson ``WorkloadGen`` at
    ``rate`` — the original behaviour), any scenario object exposing
    ``generate(duration)`` (see ``repro.simulator.scenarios``), or a
    factory callable ``(rate, seed) -> scenario`` for rate sweeps over
    non-stationary shapes.
    """
    if isinstance(workload, WorkloadProfile):
        return WorkloadGen(workload, rate, seed=seed)
    if hasattr(workload, "generate"):
        return workload
    if callable(workload):
        return workload(rate, seed)
    raise TypeError(f"cannot build a scenario from {type(workload)!r}")


def run_once(system_factory: Callable[[], object], workload,
             rate: float, slo: SLO, duration: float = 240.0,
             warmup: float = None, seed: int = 0) -> Dict[str, float]:
    system = system_factory()
    warmup = duration * 0.15 if warmup is None else min(warmup,
                                                        duration * 0.5)
    gen = as_scenario(workload, rate, seed)
    # a prebuilt scenario carries its own rate; report that one so a
    # mismatched ``rate`` argument can't mislabel the result row
    rate = getattr(getattr(gen, "arrivals", None), "rate", rate)
    reqs = gen.generate(duration)
    engine = SimulationEngine(system)
    # allow in-flight work to drain past the arrival window
    engine.run(reqs, horizon=duration * 2.5)
    scored = [r for r in engine.finished if r.arrival_time >= warmup]
    submitted = [r for r in reqs if r.arrival_time >= warmup]
    if not submitted:            # vacuously fine at negligible rates
        return {"rate": rate, "attainment": 1.0, "completion": 1.0,
                "finished": 0.0}
    att = attainment(scored, slo)
    completion = len(scored) / max(1, len(submitted))
    out = {"rate": rate, "attainment": att, "completion": completion,
           "finished": float(len(scored))}
    out.update(percentile_latencies(scored))
    return out


def goodput(system_factory, workload, slo, target_attainment: float,
            lo: float = 0.05, hi: float = 64.0, tol: float = 0.10,
            duration: float = 240.0, warmup: float = None,
            seed: int = 0) -> Dict[str, float]:
    """Binary search for the highest rate with attainment >= target
    (the paper's Fig. 8 metric, per traffic shape).
    Unfinished requests count against attainment via the completion factor.
    ``workload`` is a ``WorkloadProfile`` or a ``(rate, seed) -> scenario``
    factory (a fixed scenario has no rate knob to search over).
    Returns {goodput, attainment_at_goodput, probes, ...}."""
    if not isinstance(workload, WorkloadProfile) and \
            hasattr(workload, "generate"):
        raise TypeError(
            "goodput() searches over request rates, but a fixed scenario "
            "object ignores the probed rate; pass a WorkloadProfile or a "
            "(rate, seed) -> scenario factory instead")
    probes = 0

    def ok(rate: float) -> bool:
        nonlocal probes
        probes += 1
        m = run_once(system_factory, workload, rate, slo,
                     duration=duration, warmup=warmup, seed=seed)
        return m["attainment"] * min(1.0, m["completion"] + 1e-9) \
            >= target_attainment

    if not ok(lo):
        return {"goodput": 0.0, "target": target_attainment,
                "probes": float(probes)}
    # geometric bisection between the bracketing rates
    while hi / lo > 1 + tol:
        mid = (lo * hi) ** 0.5
        if ok(mid):
            lo = mid
        else:
            hi = mid
    final = run_once(system_factory, workload, lo, slo,
                     duration=duration, warmup=warmup, seed=seed + 1)
    return {"goodput": lo, "target": target_attainment,
            "probes": float(probes),
            "attainment": final["attainment"], **{
                k: v for k, v in final.items()
                if k.startswith(("ttft", "tpot"))}}
