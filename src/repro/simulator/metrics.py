"""Goodput measurement: max request rate sustaining an SLO-attainment
percentile (the paper's Fig. 8 metric).

Multi-tenant contract: when ``slo`` is a heterogeneous ``SLOClassSet``,
``run_once`` scores every request against its OWN class budget and
additionally reports the per-class attainment grid plus the
min-over-classes scalar, and ``goodput`` bisects on that minimum — the
frontier is capped by the WORST-served tenant, so a strategy cannot buy
aggregate attainment by starving one class (the "Inference without
Interference" measurement discipline).  Single-class sets are
bit-identical to passing the bare ``SLO``.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.core.slo import (SLO, as_slo_class_set, attainment,
                            attainment_summary, percentile_latencies,
                            request_meets_slo)
from repro.simulator.engine import SimulationEngine
from repro.simulator.workload import WorkloadGen, WorkloadProfile


def as_scenario(workload, rate: float, seed: int):
    """Normalize the workload argument to something with ``generate``.

    Accepts a ``WorkloadProfile`` (wrapped in a Poisson ``WorkloadGen`` at
    ``rate`` — the original behaviour), any scenario object exposing
    ``generate(duration)`` (see ``repro.simulator.scenarios``), or a
    factory callable ``(rate, seed) -> scenario`` for rate sweeps over
    non-stationary shapes.
    """
    if isinstance(workload, WorkloadProfile):
        return WorkloadGen(workload, rate, seed=seed)
    if hasattr(workload, "generate"):
        return workload
    if callable(workload):
        return workload(rate, seed)
    raise TypeError(f"cannot build a scenario from {type(workload)!r}")


def phase_edges(duration: float, warmup: float, phases: int):
    """Boundaries of the per-phase attainment windows: ``phases`` equal
    slices of the scored span [warmup, duration).  The one definition
    shared by ``run_once`` and consumers that map other per-phase data
    (controller trajectories, offline-optimal sweeps) onto the same
    windows."""
    return [warmup + (duration - warmup) * i / phases
            for i in range(phases + 1)]


def run_once(system_factory: Callable[[], object], workload,
             rate: float, slo, duration: float = 240.0,
             warmup: float = None, seed: int = 0,
             control=None, phases=None, faults=None,
             trace=None) -> Dict[str, float]:
    """One simulation at a fixed rate.  ``slo`` is a bare ``SLO`` or an
    ``SLOClassSet``; a heterogeneous set adds ``attainment_by_class``
    (per-class grid) and ``attainment_min`` (worst class) to the row.

    ``control`` installs the closed-loop autoscaler (``repro.control``):
    a controller spec string (``"band"``, ``"threshold"``,
    ``"band:max=8,delay=2"``) or a ``ScalingController`` instance; the
    row then carries the recorded ``timeline`` (scale events + instance
    trajectory).  ``phases`` splits the scored window into attainment
    phases — an int for equal windows over [warmup, duration) or an
    explicit boundary sequence — adding ``attainment_by_phase`` (each
    phase scored over requests *arriving* in it, unfinished ones
    counting as misses, so post-shift dips are visible) and the
    min-over-phases scalar ``attainment_phase_min``.

    ``faults`` injects a seeded fault schedule (``repro.faults``): a spec
    string (``"crash:t=14;spot:mtbf=20,notice=2"``), a named interruption
    trace (``"itrace:gentle"``, ``repro.simulator.scenarios``), or a
    prebuilt ``FaultSchedule``; the row then carries the injector's
    ``faults`` summary (applied events + failure-policy stats).  Faulted
    requests that never finish count as misses exactly like any other
    unfinished request.

    ``trace`` attaches the flight recorder (``repro.obs``): ``True``
    captures in memory, a ``Tracer`` instance is attached as-is, and a
    path string/``PathLike`` additionally writes the events as JSONL at
    the end of the run.  Tracing is observation-only — it never touches
    the event timeline — and the captured events are reported under
    ``out["trace"]`` (count + path), a key the runner excludes from
    golden rows so the axis stays seed-neutral."""
    system = system_factory()
    warmup = duration * 0.15 if warmup is None else min(warmup,
                                                        duration * 0.5)
    classes = as_slo_class_set(slo)
    harness = None
    gen = as_scenario(workload, rate, seed)
    # a prebuilt scenario carries its own rate; report that one so a
    # mismatched ``rate`` argument can't mislabel the result row
    scen_rate = getattr(getattr(gen, "arrivals", None), "rate", None)
    if scen_rate is None:
        scen_rate = getattr(gen, "rate", None)  # MixedScenario/WorkloadGen
    if scen_rate is not None:
        rate = scen_rate
    reqs = gen.generate(duration)
    engine = SimulationEngine(system)
    tracer = None
    trace_path = None
    if trace is not None and trace is not False:
        # lazy for the same reason as control/faults: untraced cells
        # stay as cheap as before the obs layer existed
        from repro.obs.events import Tracer, attach_tracer
        if isinstance(trace, Tracer):
            tracer = trace
        else:
            tracer = Tracer()
            if trace is not True:          # str / PathLike destination
                trace_path = trace
        attach_tracer(tracer, engine=engine, system=system)
    if control is not None:
        if hasattr(system, "pools"):
            # a fleet cell: capacity decisions are budget-constrained
            # rebalancing across the member pools, not single-pool
            # scaling (control spec "rebalance[:k=v,...]")
            from repro.fleet import FleetRebalanceHarness
            harness = FleetRebalanceHarness(system, engine,
                                            control).attach()
        else:
            # imported lazily: repro.control depends only on repro.core,
            # but static cells must not pay (or require) the import
            from repro.control import ControlLoopHarness, make_controller
            harness = ControlLoopHarness(
                system, engine, make_controller(control)).attach()
    injector = None
    if faults:
        # lazy for the same reason: fault-free cells stay import-free
        from repro.faults import FaultInjector, make_fault_schedule
        if hasattr(faults, "events"):          # prebuilt FaultSchedule
            schedule = faults
        else:
            spec_str = str(faults)
            if spec_str.startswith("itrace:"):
                from repro.simulator.scenarios import INTERRUPTION_TRACES
                spec_str = INTERRUPTION_TRACES[spec_str[len("itrace:"):]]
            schedule = make_fault_schedule(spec_str, seed=seed,
                                           duration=duration)
        injector = FaultInjector(schedule, system).attach(engine)
    # allow in-flight work to drain past the arrival window
    engine.run(reqs, horizon=duration * 2.5)
    scored = [r for r in engine.finished if r.arrival_time >= warmup]
    submitted = [r for r in reqs if r.arrival_time >= warmup]
    if not submitted:            # vacuously fine at negligible rates
        return {"rate": rate, "attainment": 1.0, "completion": 1.0,
                "finished": 0.0}
    if classes.is_single:
        att = attainment(scored, classes.default_slo)
        per_class = None
    else:
        att, per_class = attainment_summary(scored, classes)
        # the min ranges over classes that SUBMITTED post-warmup traffic:
        # a class that drew no arrivals is vacuously fine (matching the
        # single-class "not submitted" branch above), not starved — else
        # low-rate goodput probes would report 0.0 on empty classes.  A
        # class with submitted-but-unfinished requests still scores 0.0.
        known = set(classes.names)
        active = {r.slo_class if r.slo_class in known else classes.default
                  for r in submitted}
        att_min = min(per_class[c] for c in active)
    completion = len(scored) / max(1, len(submitted))
    out = {"rate": rate, "attainment": att, "completion": completion,
           "finished": float(len(scored))}
    if per_class is not None:
        out["attainment_by_class"] = per_class
        out["attainment_min"] = att_min
    if phases:
        edges = (phase_edges(duration, warmup, phases)
                 if isinstance(phases, int) else [float(b) for b in phases])
        met = {id(r) for r in scored
               if request_meets_slo(r, classes.for_request(r))}
        by_phase = []
        for lo, hi in zip(edges, edges[1:]):
            sub = [r for r in submitted if lo <= r.arrival_time < hi]
            # an empty phase is vacuously fine (same contract as the
            # zero-submission branch above)
            by_phase.append(
                sum(1 for r in sub if id(r) in met) / len(sub)
                if sub else 1.0)
        out["attainment_by_phase"] = by_phase
        out["attainment_phase_min"] = min(by_phase) if by_phase else 1.0
    if hasattr(system, "pool_of_rid"):
        # fleet cell (repro.fleet): score each pool over the requests
        # routed to it — submitted-but-unfinished requests count against
        # their pool, and the min ranges over pools that received
        # post-warmup traffic (an idle pool is vacuously fine, matching
        # the class-grid contract above)
        met = {id(r) for r in scored
               if request_meets_slo(r, classes.for_request(r))}
        by_pool: Dict[str, float] = {}
        active_pools = []
        for k, name in enumerate(system.pool_names):
            sub = [r for r in submitted
                   if system.pool_of_rid.get(r.rid) == k]
            by_pool[name] = (sum(1 for r in sub if id(r) in met) /
                             len(sub)) if sub else 1.0
            if sub:
                active_pools.append(name)
        out["attainment_by_pool"] = by_pool
        out["attainment_pool_min"] = (
            min(by_pool[n] for n in active_pools) if active_pools else 1.0)
        out["fleet"] = system.fleet_summary()
    if harness is not None:
        out["timeline"] = harness.timeline.summary()
    if injector is not None:
        out["faults"] = injector.summary()
    out.update(percentile_latencies(scored))
    if tracer is not None:
        # JSON-safe digest only: callers that want the events pass their
        # own Tracer (trace=<Tracer>) and keep the reference
        out["trace"] = {"events": len(tracer.events)}
        if trace_path is not None:
            from repro.obs.export import write_jsonl
            write_jsonl(tracer, trace_path)
            out["trace"]["path"] = str(trace_path)
    return out


def goodput(system_factory, workload, slo, target_attainment: float,
            lo: float = 0.05, hi: float = 64.0, tol: float = 0.10,
            duration: float = 240.0, warmup: float = None,
            seed: int = 0) -> Dict[str, float]:
    """Binary search for the highest rate with attainment >= target
    (the paper's Fig. 8 metric, per traffic shape).
    Unfinished requests count against attainment via the completion factor.
    ``workload`` is a ``WorkloadProfile`` or a ``(rate, seed) -> scenario``
    factory (a fixed scenario has no rate knob to search over).

    Under a heterogeneous ``SLOClassSet`` the search criterion is the
    MIN-over-classes attainment: every class must meet the target at the
    reported rate, so one starved tenant caps the frontier.
    Returns {goodput, attainment_at_goodput, probes, ...}."""
    if not isinstance(workload, WorkloadProfile) and \
            hasattr(workload, "generate"):
        raise TypeError(
            "goodput() searches over request rates, but a fixed scenario "
            "object ignores the probed rate; pass a WorkloadProfile or a "
            "(rate, seed) -> scenario factory instead")
    probes = 0

    def ok(rate: float) -> bool:
        nonlocal probes
        probes += 1
        m = run_once(system_factory, workload, rate, slo,
                     duration=duration, warmup=warmup, seed=seed)
        # multi-class rows carry attainment_min; single-class rows reduce
        # to the scalar attainment (bit-identical legacy criterion)
        score = m.get("attainment_min", m["attainment"])
        return score * min(1.0, m["completion"] + 1e-9) \
            >= target_attainment

    if not ok(lo):
        return {"goodput": 0.0, "target": target_attainment,
                "probes": float(probes)}
    # geometric bisection between the bracketing rates
    while hi / lo > 1 + tol:
        mid = (lo * hi) ** 0.5
        if ok(mid):
            lo = mid
        else:
            hi = mid
    final = run_once(system_factory, workload, lo, slo,
                     duration=duration, warmup=warmup, seed=seed + 1)
    out = {"goodput": lo, "target": target_attainment,
           "probes": float(probes),
           "attainment": final["attainment"], **{
               k: v for k, v in final.items()
               if k.startswith(("ttft", "tpot"))}}
    for k in ("attainment_by_class", "attainment_min"):
        if k in final:
            out[k] = final[k]
    return out
