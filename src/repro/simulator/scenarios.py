"""Composable trace-driven arrival scenarios.

The stationary-Poisson generator in ``workload.py`` exercises the paper's
goodput claim under exactly one traffic shape; DistServe and DynaServe
both evaluate under bursty and shifting load because disaggregation
trade-offs invert there.  A ``Scenario`` pairs an ``ArrivalProcess``
(stationary Poisson, MMPP-style bursty, diurnal sinusoid, linear ramp)
with a ``WorkloadProfile``'s length distributions; everything draws from
one ``np.random.default_rng`` stream so a (scenario, seed, duration)
triple is bit-exactly reproducible.

``MixedScenario`` composes N tenant streams — each an
``(arrival_process, profile, slo_class)`` triple — into one seeded,
merge-sorted arrival sequence for multi-tenant SLO experiments (see
``repro.core.slo.SLOClassSet``).

Any generated workload can be frozen to a JSONL trace (one
``{"arrival_time", "prompt_len", "output_len"[, "slo_class"]}`` record
per line) with ``write_trace`` and replayed with ``TraceReplay`` — JSON
round-trips Python floats exactly, so replay reproduces the original
``Request`` stream bit-for-bit, ``slo_class`` tags included (untagged
legacy traces load as the default class).
"""
from __future__ import annotations

import dataclasses
import json
import math
import zlib
from typing import Callable, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.request import Request
from repro.core.slo import DEFAULT_SLO_CLASS
from repro.simulator.workload import (WORKLOADS, WorkloadProfile,
                                      poisson_arrival_times)

# --------------------------------------------------------------------- #
# arrival processes
# --------------------------------------------------------------------- #


def _thinned_times(rng: np.random.Generator, duration: float, peak: float,
                   rate_fn: Callable[[float], float]) -> np.ndarray:
    """Non-homogeneous Poisson process via Lewis-Shedler thinning."""
    out: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= duration:
            break
        if rng.random() * peak <= rate_fn(t):
            out.append(t)
    return np.asarray(out, dtype=float)


class ArrivalProcess:
    """Seeded arrival-time sampler; ``rate`` is the time-averaged rate."""

    rate: float

    def sample(self, rng: np.random.Generator,
               duration: float) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Stationary Poisson at ``rate`` req/s (the seed repo's only shape)."""
    rate: float

    def sample(self, rng, duration):
        return poisson_arrival_times(rng, self.rate, duration)


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """MMPP-style bursty arrivals: exponential low/high phases alternate;
    the high-phase rate is ``burst`` x the low-phase rate, with phase
    durations chosen so the time-averaged rate stays ``rate``."""
    rate: float
    burst: float = 4.0        # high-phase rate multiplier over low phase
    phase_low: float = 12.0   # mean seconds spent in the low phase
    phase_high: float = 3.0   # mean seconds spent in the high phase

    def sample(self, rng, duration):
        r_low = self.rate * (self.phase_low + self.phase_high) / (
            self.phase_low + self.burst * self.phase_high)
        r_high = self.burst * r_low
        pieces: List[np.ndarray] = []
        t, high = 0.0, False
        while t < duration:
            mean_len = self.phase_high if high else self.phase_low
            length = rng.exponential(mean_len)
            end = min(t + length, duration)
            r = r_high if high else r_low
            n = rng.poisson(r * (end - t))
            if n:
                pieces.append(t + np.sort(rng.random(n)) * (end - t))
            t += length
            high = not high
        if not pieces:
            return np.empty(0)
        return np.concatenate(pieces)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal rate modulation: rate(t) = rate * (1 + A sin(2pi t/T))."""
    rate: float
    amplitude: float = 0.6    # in (0, 1]: peak = rate * (1 + amplitude)
    period: float = 120.0     # seconds per day-cycle (compressed)
    phase: float = 0.0

    def sample(self, rng, duration):
        peak = self.rate * (1.0 + self.amplitude)

        def rate_fn(t: float) -> float:
            return self.rate * (1.0 + self.amplitude * math.sin(
                2.0 * math.pi * (t + self.phase) / self.period))

        return _thinned_times(rng, duration, peak, rate_fn)


@dataclasses.dataclass(frozen=True)
class PhasedArrivals(ArrivalProcess):
    """Piecewise-stationary Poisson over equal windows: the horizon is
    split into ``len(weights)`` phases and phase k runs at
    ``rate * weights[k] / mean(weights)`` — so the time-averaged rate
    stays ``rate`` while the *mix* of a multi-tenant scenario shifts at
    phase boundaries (tenant A weighted ``(4, 1)`` against tenant B's
    ``(1, 4)`` trades places mid-run at the same total load).  Windows
    are sampled in order from the one RNG stream, exactly like the
    bursty process samples its phases."""
    rate: float
    weights: Tuple[float, ...] = (1.0,)

    def __post_init__(self):
        if not self.weights or any(w < 0 for w in self.weights) \
                or sum(self.weights) <= 0:
            raise ValueError(f"shift weights must be non-negative with a "
                             f"positive sum, got {self.weights}")

    def sample(self, rng, duration):
        mean_w = sum(self.weights) / len(self.weights)
        k = len(self.weights)
        pieces: List[np.ndarray] = []
        for i, w in enumerate(self.weights):
            t0 = duration * i / k
            t1 = duration * (i + 1) / k
            n = rng.poisson(self.rate * (w / mean_w) * (t1 - t0))
            if n:
                pieces.append(t0 + np.sort(rng.random(n)) * (t1 - t0))
        if not pieces:
            return np.empty(0)
        return np.concatenate(pieces)


@dataclasses.dataclass(frozen=True)
class RampArrivals(ArrivalProcess):
    """Linear ramp from ``lo_frac*rate`` to ``hi_frac*rate`` over the
    horizon; defaults keep the time-averaged rate at ``rate``."""
    rate: float
    lo_frac: float = 0.25
    hi_frac: float = 1.75

    def sample(self, rng, duration):
        lo = self.lo_frac * self.rate
        hi = self.hi_frac * self.rate
        peak = max(lo, hi)

        def rate_fn(t: float) -> float:
            return lo + (hi - lo) * (t / duration)

        return _thinned_times(rng, duration, peak, rate_fn)


# --------------------------------------------------------------------- #
# scenarios
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A reproducible workload: arrival process x length distributions."""
    name: str
    profile: WorkloadProfile
    arrivals: ArrivalProcess
    seed: int = 0

    def generate(self, duration: float) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        times = self.arrivals.sample(rng, duration)
        n = len(times)
        ins = self.profile.input_dist.sample(rng, n)
        outs = self.profile.output_dist.sample(rng, n)
        return [
            Request(rid=i, arrival_time=float(times[i]),
                    prompt_len=int(ins[i]), output_len=int(outs[i]))
            for i in range(n)
        ]


# --------------------------------------------------------------------- #
# multi-tenant mixes
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant stream inside a ``MixedScenario``: its SLO-class tag,
    length distributions, and arrival process (carrying that tenant's
    share of the total rate).  ``model`` optionally tags every request
    of the stream with the model the tenant asks for (``repro.fleet``
    routes on it); None keeps requests untagged."""
    slo_class: str
    profile: WorkloadProfile
    arrivals: ArrivalProcess
    model: Optional[str] = None


def _tenant_seed(seed: int, slo_class: str) -> int:
    """Per-tenant RNG seed derived from the tenant's IDENTITY (class tag),
    not its position — permuting the tenant tuple cannot move any
    tenant's stream.  Same CRC32 mixing discipline as the runner's
    ``cell_seed`` (never Python's salted ``hash``)."""
    return (zlib.crc32(slo_class.encode()) ^ (seed * 2654435761)) \
        & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class MixedScenario:
    """N tenant streams composed into one seeded arrival sequence.

    Each tenant draws from its own ``default_rng`` stream (seeded by
    tenant identity) exactly the way ``Scenario.generate`` draws — times,
    then input lengths, then output lengths — and the per-tenant
    sequences are merged into one time-sorted stream (stable: equal-time
    arrivals resolve by class name, then within-tenant order).  With a
    SINGLE tenant the stream seeds directly from ``seed``, so the request
    sequence is bit-identical to the equivalent ``Scenario`` (only the
    ``slo_class`` tag differs) — single-tenant sweeps reproduce the
    legacy golden grids exactly.
    """
    name: str
    tenants: Tuple[TenantSpec, ...]
    seed: int = 0

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("MixedScenario needs at least one tenant")
        names = [t.slo_class for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant slo_class in {names}")

    @property
    def rate(self) -> float:
        """Total time-averaged request rate across tenants."""
        return sum(t.arrivals.rate for t in self.tenants)

    @property
    def slo_classes(self) -> Tuple[str, ...]:
        return tuple(sorted(t.slo_class for t in self.tenants))

    def generate(self, duration: float) -> List[Request]:
        single = len(self.tenants) == 1
        merged: List[Tuple[float, int, int, str, Optional[str]]] = []
        for t in sorted(self.tenants, key=lambda t: t.slo_class):
            # per-tenant seeds key on the CLASS TAG only — adding or
            # changing another field (e.g. the fleet model tag) must not
            # move any tenant's draws
            tseed = self.seed if single else \
                _tenant_seed(self.seed, t.slo_class)
            rng = np.random.default_rng(tseed)
            times = t.arrivals.sample(rng, duration)
            n = len(times)
            ins = t.profile.input_dist.sample(rng, n)
            outs = t.profile.output_dist.sample(rng, n)
            merged.extend(
                (float(times[i]), int(ins[i]), int(outs[i]), t.slo_class,
                 t.model)
                for i in range(n))
        # stable sort of class-ordered streams == deterministic k-way
        # merge; rids are assigned in merged arrival order
        merged.sort(key=lambda rec: rec[0])
        return [
            Request(rid=i, arrival_time=at, prompt_len=p, output_len=o,
                    slo_class=c, model=m)
            for i, (at, p, o, c, m) in enumerate(merged)
        ]


def _norm_tenant_entry(entry) -> Tuple[str, Optional[float],
                                       Optional[str], Optional[str]]:
    """``"alpaca"`` | ``("alpaca", 0.7)`` | ``("alpaca", 0.7, "bursty")``
    | ``("alpaca", 0.7, "bursty", "llama-30b")``
    -> (workload name, share or None, arrival shape or None,
    model tag or None)."""
    if isinstance(entry, str):
        return entry, None, None, None
    seq = tuple(entry)
    if not seq or not isinstance(seq[0], str):
        raise TypeError(f"tenant entry {entry!r}: expected a workload "
                        "name or (name, share[, shape[, model]])")
    share = float(seq[1]) if len(seq) > 1 and seq[1] is not None else None
    shape = seq[2] if len(seq) > 2 and seq[2] else None
    model = seq[3] if len(seq) > 3 and seq[3] else None
    return seq[0], share, shape, model


def make_mixed_scenario(kind: str, tenant_workloads: Sequence,
                        rate: float, seed: int = 0,
                        shares: Optional[Sequence[float]] = None,
                        **kw) -> MixedScenario:
    """Compose one tenant per Table 4 workload name: each tenant's
    ``slo_class`` IS the workload name (so ``DATASET_SLOS`` supplies the
    per-class budgets) and its lengths come from that workload's profile.

    Entries are workload names (equal share of ``rate``, the cell's
    ``kind`` as arrival shape) or ``(name, share[, shape[, model]])``
    tuples pinning that tenant's fraction of the total rate and,
    optionally, its own arrival shape and fleet model tag — e.g. bursty
    alpaca over diurnal longbench:
    ``(("alpaca", 0.7, "bursty"), ("longbench", 0.3, "diurnal"))``, or a
    shifting two-model fleet mix:
    ``(("sharegpt", 0.5, "shift:4,1", "llama-30b"),
    ("longbench", None, "shift:1,4", "qwen1.5-32b"))``.
    Entries without an explicit share split the unclaimed remainder
    equally.  Per-tenant RNG streams are seeded by tenant *identity*
    either way, so adding a share/shape/model to one tenant never moves
    another tenant's draws."""
    entries = [_norm_tenant_entry(e) for e in tenant_workloads]
    if shares is not None:
        if len(shares) != len(entries):
            raise ValueError("one share per tenant workload")
        entries = [(n, float(s), sh, m)
                   for (n, _, sh, m), s in zip(entries, shares)]
    claimed = sum(s for _, s, _, _ in entries if s is not None)
    if claimed > 1.0 + 1e-9:
        raise ValueError(f"tenant shares sum to {claimed} > 1")
    unspec = sum(1 for _, s, _, _ in entries if s is None)
    if not unspec and abs(claimed - 1.0) > 1e-9:
        # all-explicit shares must cover the rate: a silent shortfall
        # would label result rows with an offered load nobody simulated
        raise ValueError(f"explicit tenant shares sum to {claimed}, "
                         "not 1; leave one share None to absorb the "
                         "remainder")
    default_share = (1.0 - claimed) / unspec if unspec else 0.0
    tenants = []
    for name, share, shape, model in entries:
        share = default_share if share is None else share
        scen = make_scenario(shape or kind, name, rate * share,
                             seed=seed, **kw)
        if not isinstance(scen, Scenario):
            raise TypeError(f"kind {shape or kind!r} does not parameterize "
                            "by rate and cannot form a tenant stream")
        tenants.append(TenantSpec(slo_class=name, profile=scen.profile,
                                  arrivals=scen.arrivals, model=model))
    names = [n for n, _, _, _ in entries]
    return MixedScenario(name=f"{kind}+{'+'.join(names)}",
                         tenants=tuple(tenants), seed=seed)


# --------------------------------------------------------------------- #
# JSONL traces
# --------------------------------------------------------------------- #

# (arrival_time, prompt_len, output_len, slo_class, model-or-None)
TraceRecord = Tuple[float, int, int, str, Optional[str]]


def trace_lines(reqs: Iterable[Request]) -> List[str]:
    """One JSONL record per request.  The ``slo_class`` and ``model``
    keys are written only for tagged requests, so single-tenant,
    untagged traces stay byte-identical to the legacy three-key
    format."""
    out: List[str] = []
    for r in reqs:
        d = {"arrival_time": r.arrival_time,
             "prompt_len": r.prompt_len,
             "output_len": r.output_len}
        if r.slo_class != DEFAULT_SLO_CLASS:
            d["slo_class"] = r.slo_class
        if r.model is not None:
            d["model"] = r.model
        out.append(json.dumps(d))
    return out


def write_trace(reqs: Iterable[Request], path) -> None:
    """Freeze any generated workload to a JSONL trace file."""
    with open(path, "w") as f:
        for line in trace_lines(reqs):
            f.write(line + "\n")


def _parse_trace(lines: Iterable[str]) -> Tuple[TraceRecord, ...]:
    records: List[TraceRecord] = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        model = d.get("model")
        records.append((float(d["arrival_time"]), int(d["prompt_len"]),
                        int(d["output_len"]),
                        # untagged legacy JSONL loads as the default class
                        str(d.get("slo_class", DEFAULT_SLO_CLASS)),
                        None if model is None else str(model)))
    return tuple(records)


@dataclasses.dataclass(frozen=True)
class TraceReplay:
    """Replays a frozen trace; arrivals past ``duration`` are dropped so
    a long trace can drive a short experiment.

    ``loop=True`` tiles the trace instead: when the experiment window
    outlives the trace span, the record sequence repeats end-to-end
    (each pass offset by span + one mean inter-arrival gap, so the
    time-averaged rate carries across the seam) until ``duration`` is
    covered — a short rate-normalized excerpt can then drive a long
    cell without most of the window being silent.
    """
    name: str
    records: Tuple[TraceRecord, ...]
    loop: bool = False

    @property
    def rate(self) -> float:
        """Time-averaged arrival rate over the trace span (0.0 for
        traces too short to define one); lets ``run_once`` label result
        rows with the rate actually replayed."""
        if len(self.records) < 2:
            return 0.0
        span = self.records[-1][0] - self.records[0][0]
        return (len(self.records) - 1) / span if span > 0 else 0.0

    def generate(self, duration: float = None) -> List[Request]:
        reqs: List[Request] = []
        tiled = (self.loop and duration is not None
                 and len(self.records) >= 2 and self.rate > 0)
        passes = 1
        stride = 0.0
        if tiled:
            span = self.records[-1][0] - self.records[0][0]
            stride = span + 1.0 / self.rate     # seam gap = mean gap
            passes = max(1, math.ceil(duration / stride))
        rid = 0
        for k in range(passes):
            off = k * stride
            for t, plen, olen, cls, model in self.records:
                t = t + off
                if duration is not None and t >= duration:
                    continue
                reqs.append(Request(rid=rid, arrival_time=t,
                                    prompt_len=plen, output_len=olen,
                                    slo_class=cls, model=model))
                rid += 1
        return reqs

    @staticmethod
    def from_requests(name: str, reqs: Sequence[Request]) -> "TraceReplay":
        return TraceReplay(name, _parse_trace(trace_lines(reqs)))

    @staticmethod
    def from_jsonl(path, name: str = None) -> "TraceReplay":
        with open(path) as f:
            records = _parse_trace(f)
        return TraceReplay(name or f"replay:{path}", records)


@dataclasses.dataclass(frozen=True)
class RoundTripReplay:
    """Generates a base scenario, freezes it through the JSONL codec, and
    replays the frozen form — the runner's default trace-replay cell, so
    every sweep exercises the serialize -> replay path end to end."""
    base: Scenario
    name: str = "replay"

    def generate(self, duration: float) -> List[Request]:
        frozen = trace_lines(self.base.generate(duration))
        return TraceReplay(self.name, _parse_trace(frozen)).generate(duration)


# --------------------------------------------------------------------- #
# factory
# --------------------------------------------------------------------- #

SCENARIO_KINDS = ("poisson", "bursty", "diurnal", "ramp", "replay")

# Named interruption traces: fault-spec strings (``repro.faults``
# grammar) reachable as ``faults="itrace:<name>"`` in ``run_once`` and
# grid cells, so benchmarks pin a fault shape by name the way scenarios
# pin an arrival shape.  "gentle" is one crash plus one spot preemption
# at fixed times (the CI smoke shape); "stormy" layers stochastic spot
# churn, crashes, and a straggler on top — the spec's mtbf clauses draw
# their event times from the schedule's own seeded RNG, so every cell
# seed gets a distinct but reproducible storm.
INTERRUPTION_TRACES = {
    "gentle": "crash:t=14;preempt:t=26,notice=2",
    "stormy": "spot:mtbf=16,notice=2;crash:mtbf=30;slow:t=10,factor=2,dur=8",
}


def make_scenario(kind: str, profile: Union[str, WorkloadProfile],
                  rate: float, seed: int = 0, **kw):
    """Build a scenario by kind at a time-averaged ``rate``.

    ``kind='replay'`` replays ``kw['trace']`` (a JSONL path) if given,
    else round-trips a Poisson workload through the trace codec.
    ``kind='trace:<fixture>'`` (``"trace:azure"``, ``"trace:burstgpt"``)
    replays a converted real-trace excerpt (``repro.traces``)
    rate-normalized to ``rate`` — the replay is frozen data, so
    ``profile`` and ``seed`` do not perturb it (lengths come from the
    trace; the rate knob is a pure time dilation), but grids can still
    sweep rates over real traffic shapes.
    ``kind='shift:<w0>,<w1>[,...]'`` runs piecewise-stationary Poisson
    phases weighted by the listed factors (``PhasedArrivals``; the
    time-averaged rate stays ``rate``) — per-tenant shift shapes are how
    a fleet cell's traffic mix moves between models mid-run.
    """
    if kind.startswith("trace:"):
        if kw:
            raise TypeError(f"trace kinds take no extra options, got {kw}")
        # lazy: repro.traces imports this module for the replay codec
        from repro.traces import fixture_replay
        return fixture_replay(kind[len("trace:"):], rate=rate, loop=True)
    if isinstance(profile, str):
        profile = WORKLOADS[profile]
    if kind.startswith("shift:"):
        if kw:
            raise TypeError(f"shift kinds take no extra options, got {kw}")
        weights = tuple(float(x) for x in kind[len("shift:"):].split(","))
        return Scenario(kind, profile, PhasedArrivals(rate, weights), seed)
    if kind == "poisson":
        if kw:
            raise TypeError(f"poisson takes no extra options, got {kw}")
        return Scenario(kind, profile, PoissonArrivals(rate), seed)
    if kind == "bursty":
        return Scenario(kind, profile, BurstyArrivals(rate, **kw), seed)
    if kind == "diurnal":
        return Scenario(kind, profile, DiurnalArrivals(rate, **kw), seed)
    if kind == "ramp":
        return Scenario(kind, profile, RampArrivals(rate, **kw), seed)
    if kind == "replay":
        trace = kw.pop("trace", None)
        if kw:
            raise TypeError(f"replay takes only 'trace', got {kw}")
        if trace is not None:
            return TraceReplay.from_jsonl(trace)
        base = Scenario("replay-base", profile, PoissonArrivals(rate), seed)
        return RoundTripReplay(base)
    raise KeyError(f"unknown scenario kind {kind!r}; "
                   f"expected one of {SCENARIO_KINDS}")
