"""Workload generators matching the paper's Table 4 length statistics.

Input/output lengths are lognormal fits to (mean, median); LongBench's
inputs have mean < median so they use a clipped normal.  Inputs truncate
at 4096 as in the paper.  Arrivals are Poisson at a fixed request rate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List

import numpy as np

from repro.core.request import Request


def poisson_arrival_times(rng: np.random.Generator, rate: float,
                          duration: float) -> np.ndarray:
    """Homogeneous Poisson arrival times on [0, duration).

    Draws exponential gaps in chunks until the cumulative time crosses
    ``duration`` — a single pre-sized draw silently truncates arrivals at
    long horizons whenever the sampled gaps run short.
    """
    chunk = int(rate * duration * 1.5) + 16
    gaps = rng.exponential(1.0 / rate, size=chunk)
    times = np.cumsum(gaps)
    while times[-1] < duration:
        gaps = rng.exponential(1.0 / rate, size=chunk)
        times = np.concatenate([times, times[-1] + np.cumsum(gaps)])
    return times[times < duration]


def _lognormal_params(mean: float, median: float):
    mu = math.log(median)
    sigma2 = 2.0 * math.log(mean / median)
    return mu, math.sqrt(max(sigma2, 1e-4))


@dataclasses.dataclass(frozen=True)
class LengthDist:
    mean: float
    median: float
    max_len: int

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.mean <= self.median:   # longbench inputs
            x = rng.normal(self.mean, 0.15 * self.mean, size=n)
        else:
            mu, sigma = _lognormal_params(self.mean, self.median)
            x = rng.lognormal(mu, sigma, size=n)
        return np.clip(x, 1, self.max_len).astype(int)


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    input_dist: LengthDist
    output_dist: LengthDist


# Table 4 statistics
WORKLOADS = {
    "alpaca": WorkloadProfile(
        "alpaca",
        LengthDist(mean=20.63, median=17.0, max_len=4096),
        LengthDist(mean=163.80, median=119.0, max_len=2048)),
    "sharegpt": WorkloadProfile(
        "sharegpt",
        LengthDist(mean=343.76, median=148.0, max_len=4096),
        LengthDist(mean=237.20, median=152.0, max_len=2048)),
    "longbench": WorkloadProfile(
        "longbench",
        LengthDist(mean=2686.89, median=2736.5, max_len=4096),
        LengthDist(mean=101.78, median=19.0, max_len=2048)),
}


class WorkloadGen:
    def __init__(self, profile: WorkloadProfile, rate: float,
                 seed: int = 0):
        self.profile = profile
        self.rate = rate
        self.rng = np.random.default_rng(seed)

    def generate(self, duration: float) -> List[Request]:
        """Poisson arrivals over [0, duration)."""
        times = poisson_arrival_times(self.rng, self.rate, duration)
        n = len(times)
        ins = self.profile.input_dist.sample(self.rng, n)
        outs = self.profile.output_dist.sample(self.rng, n)
        return [
            Request(rid=i, arrival_time=float(times[i]),
                    prompt_len=int(ins[i]), output_len=int(outs[i]))
            for i in range(n)
        ]
