from repro.data.pipeline import (  # noqa: F401
    ByteTokenizer, synthetic_corpus, TokenDataset)
