"""LM data pipeline: byte-level tokenizer, synthetic corpus generator,
packed next-token batches (used by train_4k and the training example)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np


class ByteTokenizer:
    """Byte tokenizer with BOS=0 / EOS=1 (ids shifted by 2)."""
    bos = 0
    eos = 1

    def __init__(self, vocab_size: int = 258):
        self.vocab_size = max(vocab_size, 258)

    def encode(self, text: str) -> List[int]:
        return [self.bos] + [b + 2 for b in text.encode("utf-8")] + [self.eos]

    def decode(self, ids: List[int]) -> str:
        return bytes(i - 2 for i in ids
                     if i >= 2 and i - 2 < 256).decode("utf-8", "replace")


def synthetic_corpus(n_docs: int = 256, seed: int = 0) -> List[str]:
    """Deterministic pseudo-text with learnable structure (repeated
    patterns + arithmetic snippets) so a 100M model's loss visibly drops."""
    rng = np.random.default_rng(seed)
    words = ["the", "model", "serves", "tokens", "prefill", "decode",
             "cache", "batch", "goodput", "latency", "macro", "instance",
             "tensor", "pipeline", "schedule", "roll", "activate"]
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(30, 120))
        seq = rng.choice(words, size=n)
        a, b = rng.integers(1, 50, 2)
        docs.append(" ".join(seq) + f" {a}+{b}={a + b}.")
    return docs


@dataclasses.dataclass
class TokenDataset:
    """Packs tokenized documents into fixed-length next-token batches."""
    tokens: np.ndarray          # 1-D stream

    @staticmethod
    def from_texts(texts: List[str],
                   tok: ByteTokenizer = ByteTokenizer()) -> "TokenDataset":
        stream: List[int] = []
        for t in texts:
            stream.extend(tok.encode(t))
        return TokenDataset(np.asarray(stream, np.int32))

    def batches(self, batch_size: int, seq_len: int,
                seed: int = 0) -> Iterator[dict]:
        rng = np.random.default_rng(seed)
        n = len(self.tokens) - seq_len - 1
        while True:
            starts = rng.integers(0, n, batch_size)
            toks = np.stack([self.tokens[s:s + seq_len] for s in starts])
            labs = np.stack(
                [self.tokens[s + 1:s + seq_len + 1] for s in starts])
            yield {"tokens": toks, "labels": labs}
