"""Flight-recorder persistence: JSONL codec + Chrome-trace/Perfetto export.

``SCHEMA`` is the single source of truth for the positional fields of
every event tuple the ``Tracer`` emits (``repro.obs.events``).  The JSONL
codec writes one named-field object per event (first line = a meta
header carrying the schema version and the tracer's ``meta`` dict), and
``read_jsonl`` rebuilds the exact tuples — the round trip is lossless
for every JSON-representable payload, which all emission sites keep to.

``chrome_trace`` renders the events in the Chrome Trace Event JSON
format Perfetto loads directly (https://ui.perfetto.dev -> open trace):
slot spans become complete ("X") events on one track per instance,
request/instance/fault/control/transport events become instants ("i"),
and the per-instance state samples become counter ("C") tracks (KV
occupancy, queue depth, decode batch utilization, prefill backlog).
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

from repro.obs.events import slot_rids

SCHEMA_VERSION = 1

# etype -> positional field names AFTER the (etype, t) prefix; must match
# the append sites in repro.obs.events.Tracer exactly.
SCHEMA: Dict[str, Tuple[str, ...]] = {
    "arrive":    ("rid", "slo_class", "model"),
    "admit":     ("rid", "iid"),
    "enqueue":   ("rid",),
    "drain":     ("rid", "iid"),
    "finish":    ("rid",),
    "fail":      ("rid", "reason"),
    "requeue":   ("rid",),
    "migrate":   ("rid", "src", "dst"),
    "handoff":   ("iid", "rids"),
    "slot":      ("iid", "kind", "dur", "rids", "kv_used", "kv_cap",
                  "n_pending", "pending_tokens", "n_decoding", "queue_len",
                  "max_decode_batch"),
    "instance":  ("iid", "what"),
    "fault":     ("kind", "iid"),
    "control":   ("what", "value"),
    "transport": ("what", "kind", "src", "dst"),
    "op":        ("what", "work", "extra", "dt"),
}

# fields decoded back to tuples (JSON has no tuple type)
_TUPLE_FIELDS = frozenset(["rids"])


def _events_of(tracer_or_events) -> List[tuple]:
    ev = getattr(tracer_or_events, "events", tracer_or_events)
    return list(ev)


def to_dicts(tracer_or_events) -> List[dict]:
    """Named-field view of the event list (the JSONL body shape)."""
    rows = []
    for ev in _events_of(tracer_or_events):
        etype, t = ev[0], ev[1]
        fields = SCHEMA.get(etype)
        if fields is None:                       # forward compat: keep raw
            rows.append({"e": etype, "t": t, "args": list(ev[2:])})
            continue
        row = {"e": etype, "t": t}
        for name, val in zip(fields, ev[2:]):
            # rids may be a live request batch (hot-path economy, see
            # events.Tracer.slot) — normalize to ids here
            row[name] = (list(slot_rids(val)) if name in _TUPLE_FIELDS
                         else val)
        rows.append(row)
    return rows


def write_jsonl(tracer_or_events, path) -> int:
    """Write the trace as JSONL (meta header + one object per event).
    Returns the number of events written."""
    import os
    meta = dict(getattr(tracer_or_events, "meta", {}) or {})
    rows = to_dicts(tracer_or_events)
    parent = os.path.dirname(str(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as fh:
        fh.write(json.dumps({"schema": SCHEMA_VERSION, "meta": meta,
                             "events": len(rows)}, sort_keys=True) + "\n")
        for row in rows:
            fh.write(json.dumps(row, sort_keys=True) + "\n")
    return len(rows)


def read_jsonl(path) -> Tuple[List[tuple], dict]:
    """Rebuild ``(events, meta)`` from a JSONL trace file — the inverse
    of ``write_jsonl`` (tuples restored, header consumed)."""
    events: List[tuple] = []
    meta: dict = {}
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if i == 0 and "schema" in row and "e" not in row:
                meta = dict(row.get("meta", {}))
                continue
            etype = row["e"]
            fields = SCHEMA.get(etype)
            if fields is None:
                events.append((etype, row["t"], *row.get("args", ())))
                continue
            vals = []
            for name in fields:
                v = row.get(name)
                if name in _TUPLE_FIELDS and isinstance(v, list):
                    v = tuple(v)
                vals.append(v)
            events.append((etype, row["t"], *vals))
    return events, meta


# --------------------------------------------------------------------- #
# Chrome-trace / Perfetto export
# --------------------------------------------------------------------- #
_US = 1e6          # trace-event timestamps are microseconds
_PID_SIM = 1       # one process row: the simulated pool
_CTRL_TID = 10_000  # control-plane instants live on their own track


def _us(t: float) -> float:
    return round(max(t, 0.0) * _US, 3)


def chrome_trace(tracer_or_events, meta: dict = None) -> dict:
    """Render the events as a Chrome Trace Event JSON object
    (``{"traceEvents": [...]}``) loadable by Perfetto and
    ``chrome://tracing``.  One thread track per instance carrying its
    slot spans + counters; instants for lifecycle/fault/control events.
    """
    events = _events_of(tracer_or_events)
    if meta is None:
        meta = dict(getattr(tracer_or_events, "meta", {}) or {})
    out: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": _PID_SIM,
        "args": {"name": meta.get("name", "repro sim pool")}}]
    seen_tids = set()

    def tid_of(iid) -> int:
        tid = int(iid) if iid is not None else _CTRL_TID
        if tid not in seen_tids:
            seen_tids.add(tid)
            out.append({"name": "thread_name", "ph": "M", "pid": _PID_SIM,
                        "tid": tid,
                        "args": {"name": ("control" if tid == _CTRL_TID
                                          else f"instance {tid}")}})
        return tid

    def instant(name: str, t: float, tid: int, args: dict) -> None:
        out.append({"name": name, "ph": "i", "s": "t", "pid": _PID_SIM,
                    "tid": tid, "ts": _us(t), "args": args})

    for ev in events:
        etype, t = ev[0], ev[1]
        if etype == "slot":
            (iid, kind, dur, rids, kv_used, kv_cap, n_pending,
             pending_tokens, n_decoding, queue_len, max_batch) = ev[2:]
            rids = slot_rids(rids)
            tid = tid_of(iid)
            out.append({
                "name": kind, "ph": "X", "pid": _PID_SIM, "tid": tid,
                "ts": _us(t), "dur": round(dur * _US, 3),
                "args": {"rids": list(rids), "batch": len(rids),
                         "kv_used": kv_used, "queue_len": queue_len}})
            util = (n_decoding / max_batch) if max_batch else 0.0
            for cname, val in (("kv_occupancy",
                                kv_used / kv_cap if kv_cap else 0.0),
                               ("queue_depth", queue_len),
                               ("decode_batch_util", util),
                               ("prefill_backlog_tokens", pending_tokens)):
                out.append({"name": f"{cname} (inst {iid})", "ph": "C",
                            "pid": _PID_SIM, "tid": tid, "ts": _us(t),
                            "args": {cname: round(float(val), 6)}})
        elif etype == "instance":
            iid, what = ev[2:]
            instant(f"instance:{what}", t, tid_of(iid), {"iid": iid})
        elif etype == "fault":
            kind, iid = ev[2:]
            instant(f"fault:{kind}", t,
                    tid_of(iid) if iid is not None else _CTRL_TID,
                    {"iid": iid})
        elif etype == "control":
            what, value = ev[2:]
            instant(f"control:{what}", t, tid_of(None),
                    {"value": value if isinstance(
                        value, (int, float, str, bool, type(None)))
                        else str(value)})
        elif etype == "transport":
            what, kind, src, dst = ev[2:]
            instant(f"transport:{what}", t, tid_of(None),
                    {"kind": kind, "src": src, "dst": dst})
        elif etype in ("fail", "migrate"):
            instant(f"request:{etype}", t, tid_of(None),
                    {SCHEMA[etype][0]: ev[2]})
        # arrive/admit/enqueue/drain/finish/handoff/op stay out of the
        # rendered trace (per-request volume would swamp the UI); they
        # remain in the JSONL for the attribution tooling.
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome_trace(tracer_or_events, path, meta: dict = None) -> int:
    """Write the Perfetto-loadable JSON; returns the traceEvents count."""
    import os
    doc = chrome_trace(tracer_or_events, meta=meta)
    parent = os.path.dirname(str(path))
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh)
        fh.write("\n")
    return len(doc["traceEvents"])
