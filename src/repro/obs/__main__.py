"""Flight-recorder CLI: ``python -m repro.obs <cmd> <trace.jsonl>``.

    summarize    print the whole-trace digest (event counts, attribution
                 totals + exactness check, TPOT jitter, interference)
    attribution  print the per-request TTFT attribution table
    export       convert a JSONL trace to Chrome-trace/Perfetto JSON
                 (``--perfetto`` / ``-o out.json``)

All commands read the JSONL format ``repro.obs.export.write_jsonl``
produces (``run_once(trace=path)``, ``bench_trace --smoke``).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs.export import read_jsonl, write_chrome_trace
from repro.obs.metrics import attribution, summarize


def _cmd_summarize(args) -> int:
    events, meta = read_jsonl(args.trace)
    digest = summarize(events)
    if meta:
        digest["meta"] = meta
    json.dump(digest, sys.stdout, indent=2, sort_keys=True)
    sys.stdout.write("\n")
    return 0 if digest["attribution"]["exact"] else 1


def _cmd_attribution(args) -> int:
    events, _ = read_jsonl(args.trace)
    attr = attribution(events)
    rows = attr["rows"][: args.limit] if args.limit else attr["rows"]
    if args.json:
        json.dump({"rows": rows, "totals": attr["totals"],
                   "unattributed": attr["unattributed"]},
                  sys.stdout, indent=2, sort_keys=True)
        sys.stdout.write("\n")
        return 0
    cols = ("rid", "arrival", "queue_wait", "prefill_wait",
            "prefill_service", "transfer", "ttft")
    print("  ".join(f"{c:>15}" for c in cols))
    for r in rows:
        print("  ".join(
            f"{r[c]:>15}" if c == "rid" else f"{r[c]:>15.6f}"
            for c in cols))
    tot = attr["totals"]
    # per-row exactness is the contract (repro.obs.metrics docstring)
    exact = all(
        r["queue_wait"] + r["prefill_wait"] + r["prefill_service"]
        + r["transfer"] == r["ttft"] for r in attr["rows"])
    print(f"-- {tot['n']} attributed, {attr['unattributed']} unattributed;"
          f" ttft_total={tot['ttft']:.9f} per-row exact={exact}")
    return 0 if exact else 1


def _cmd_export(args) -> int:
    events, meta = read_jsonl(args.trace)
    out = args.out or (str(args.trace).rsplit(".jsonl", 1)[0]
                       + ".perfetto.json")
    n = write_chrome_trace(events, out, meta=meta)
    print(f"wrote {n} trace events -> {out}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Flight-recorder trace tooling")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="whole-trace digest as JSON")
    p.add_argument("trace")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("attribution", help="per-request TTFT attribution")
    p.add_argument("trace")
    p.add_argument("--limit", type=int, default=0,
                   help="print at most N rows (0 = all)")
    p.add_argument("--json", action="store_true",
                   help="emit JSON instead of the table")
    p.set_defaults(fn=_cmd_attribution)

    p = sub.add_parser("export",
                       help="convert to Chrome-trace/Perfetto JSON")
    p.add_argument("trace")
    p.add_argument("--perfetto", action="store_true",
                   help="Perfetto-loadable Chrome-trace JSON (the only "
                        "format; flag kept explicit for readability)")
    p.add_argument("-o", "--out", default=None)
    p.set_defaults(fn=_cmd_export)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
