"""The flight-recorder event bus: typed sim-time events, zero overhead
when off.

One ``Tracer`` rides the whole stack: the engine emits slot spans (with
the per-instance state sampled at the slot boundary), ``PolicySystemBase``
emits the request lifecycle (arrive / admit / enqueue / drain / finish /
fail / requeue / migrate), the macro scheduler emits rolling-activation
rotations and mitosis split/merge, the transport emits per-message fates,
the fault injector and control loop emit their domain events, and the
real-path ``CalibrationRecorder`` emits per-op timings.  Everything is a
plain tuple ``(etype, t, ...)`` appended to ``tracer.events`` — no
classes, no dict churn on the hot path; the positional field names live
in ``repro.obs.export.SCHEMA``.

The default is ``NULL_TRACER`` (``enabled = False``): every emission site
guards with one attribute read (``trc = self.tracer; if trc.enabled:``),
the same contract as the pre-existing ``decision_log: None`` pattern —
which this layer subsumes: attaching a list to
``engine.decision_log`` / ``system.decision_log`` installs a
mirror-only tracer that appends the exact legacy
``("slot"|"admit"|"queue"|"drain", ...)`` tuples, so the sim-to-real
conformance suite observes a bit-identical totally ordered sequence.

This module is deliberately import-free of the rest of ``repro`` so the
engine/system/transport hot paths can import it without cycles.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple


class NullTracer:
    """The off switch: one shared instance, ``enabled`` False, and inert
    emission methods (never called on guarded hot paths; the methods
    exist so unguarded cold paths cannot crash)."""

    enabled = False
    events: Tuple = ()
    clock: Optional[Callable[[], float]] = None

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        return self._noop

    @staticmethod
    def _noop(*args: Any, **kw: Any) -> None:
        return None

    def now(self) -> float:
        return -1.0


NULL_TRACER = NullTracer()


class Tracer:
    """Collects typed events with sim-time timestamps and stable ids.

    ``mirror`` (optional) is a legacy ``decision_log`` list: the four
    decision kinds are additionally appended to it in their historical
    tuple shapes.  ``record=False`` makes a mirror-only tracer (the
    ``decision_log`` compat shim) that never accumulates ``events``.
    ``clock`` supplies timestamps for control-plane emissions that have
    no sim time in scope (mitosis split/merge); ``run_once`` wires it to
    the engine clock, bare construction stamps ``-1.0``.
    """

    enabled = True

    __slots__ = ("events", "_mirror", "_record", "clock", "meta")

    def __init__(self, mirror: Optional[list] = None, record: bool = True,
                 clock: Optional[Callable[[], float]] = None):
        self.events: List[tuple] = []
        self._mirror = mirror
        self._record = record
        self.clock = clock
        self.meta: dict = {}

    def now(self) -> float:
        """Clock fallback for emissions without a timestamp in scope."""
        return self.clock() if self.clock is not None else -1.0

    # ---------------- request lifecycle -------------------------------- #
    def arrive(self, t: float, req) -> None:
        if self._record:
            self.events.append(("arrive", t, req.rid, req.slo_class,
                                req.model))

    def admit(self, t: float, rid: int, iid: int) -> None:
        if self._mirror is not None:
            self._mirror.append(("admit", t, rid, iid))
        if self._record:
            self.events.append(("admit", t, rid, iid))

    def enqueue(self, t: float, rid: int) -> None:
        if self._mirror is not None:
            self._mirror.append(("queue", t, rid))
        if self._record:
            self.events.append(("enqueue", t, rid))

    def drain(self, t: float, rid: int, iid: int) -> None:
        if self._mirror is not None:
            self._mirror.append(("drain", t, rid, iid))
        if self._record:
            self.events.append(("drain", t, rid, iid))

    def finish(self, t: float, rid: int) -> None:
        if self._record:
            self.events.append(("finish", t, rid))

    def fail(self, t: float, rid: int, reason: str) -> None:
        if self._record:
            self.events.append(("fail", t, rid, reason))

    def requeue(self, t: float, rid: int) -> None:
        if self._record:
            self.events.append(("requeue", t, rid))

    def migrate(self, t: float, rid: int, src: int, dst: int) -> None:
        if self._record:
            self.events.append(("migrate", t, rid, src, dst))

    def handoff(self, t: float, iid: int, reqs) -> None:
        if self._record:
            self.events.append(("handoff", t, iid,
                                tuple(r.rid for r in reqs)))

    # ---------------- slot spans (per-instance state sample) ----------- #
    def slot(self, t: float, inst, kind: str, dur: float, reqs,
             queue_len: int) -> None:
        # the busiest emission (one per slot), most of the
        # tracing-overhead budget benchmarks/bench_simspeed.py gates.
        # The hot path stores the live request batch and defers rid
        # extraction to analysis time (``slot_rids``): the engine's slot
        # batches are fresh slices that are never mutated after the
        # slot is scheduled, and rids are immutable, so the deferred
        # view is identical — without an O(batch) tuple build per slot.
        m = self._mirror
        if m is not None:
            # the exact legacy decision_log tuple, at the exact legacy
            # program point (the caller emits before scheduling the slot)
            rids = tuple([r.rid for r in reqs])
            m.append(("slot", t, inst.iid, kind, dur, rids))
            reqs = rids
        if self._record:
            # _pending_tokens/_decode_kv_sum are Instance's O(1) running
            # aggregates (kv_tokens_used() == their sum); read directly
            # to skip property/method dispatch on the hot path
            pending_tokens = inst._pending_tokens
            self.events.append((
                "slot", t, inst.iid, kind, dur, reqs,
                inst._decode_kv_sum + pending_tokens,
                inst.kv_capacity_tokens,
                len(inst.pending), pending_tokens,
                len(inst.decoding), queue_len, inst.max_decode_batch))

    # ---------------- instance / fault / control / transport ----------- #
    def instance(self, t: float, iid: int, what: str) -> None:
        if self._record:
            self.events.append(("instance", t, iid, what))

    def fault(self, t: float, kind: str, iid) -> None:
        if self._record:
            self.events.append(("fault", t, kind, iid))

    def control(self, t: float, what: str, value) -> None:
        if self._record:
            self.events.append(("control", t, what, value))

    def transport(self, t: float, what: str, kind: str, src: int,
                  dst: int) -> None:
        if self._record:
            self.events.append(("transport", t, what, kind, src, dst))

    # ---------------- real-path op samples (calibration bus) ----------- #
    def op(self, t: float, what: str, work: int, extra: int,
           dt: float) -> None:
        if self._record:
            self.events.append(("op", t, what, work, extra, dt))


def slot_rids(field) -> Tuple[int, ...]:
    """Normalize a slot/handoff event's request field to a rid tuple.
    Live tracers store the request batch itself (hot-path economy, see
    ``Tracer.slot``); mirror-attached tracers and JSONL round trips
    store int tuples already."""
    if field and not isinstance(field[0], int):
        return tuple([r.rid for r in field])
    return tuple(field)


# --------------------------------------------------------------------- #
# attachment helpers
# --------------------------------------------------------------------- #
def attach_decision_log(obj, log: Optional[list]) -> None:
    """The ``decision_log`` compat shim body: property setters on
    ``SimulationEngine`` / ``PolicySystemBase`` delegate here.

    Attaching a list installs it as the mirror of the object's tracer —
    minting a mirror-only tracer when tracing is off, so the legacy
    contract (None default = allocation-free hot path) survives.
    Detaching (``log = None``) removes the mirror and drops a shim-only
    tracer back to ``NULL_TRACER``."""
    obj._decision_log = log
    trc = getattr(obj, "tracer", NULL_TRACER)
    if log is not None:
        if trc.enabled:
            trc._mirror = log
        else:
            obj.tracer = Tracer(mirror=log, record=False)
    elif trc.enabled:
        trc._mirror = None
        if not trc._record:
            obj.tracer = NULL_TRACER


def attach_tracer(tracer: Tracer, engine=None, system=None) -> Tracer:
    """Thread one tracer through a live (engine, system) pair: the
    engine (slot spans + clock), the system (request lifecycle), its
    transport, its macro scheduler and macros (rotate/split/merge), and
    — for composite fleet systems — every member pool the same way.
    Purely attribute assignment: attaching is observation-only and never
    perturbs the event timeline."""
    if engine is not None:
        engine.tracer = tracer
        if tracer.clock is None:
            tracer.clock = lambda: engine.now
        # keep a previously attached decision_log mirrored through the
        # replacement tracer (run_once tracing + conformance recording)
        if getattr(engine, "_decision_log", None) is not None:
            tracer._mirror = engine._decision_log

    def _wire(sys_obj) -> None:
        sys_obj.tracer = tracer
        tr = getattr(sys_obj, "transport", None)
        if tr is not None:
            tr.tracer = tracer
        sched = getattr(sys_obj, "sched", None)
        if sched is not None:
            sched.tracer = tracer
            for m in getattr(sched, "macros", ()):
                m.tracer = tracer

    if system is not None:
        _wire(system)
        for pool in getattr(system, "pools", ()) or ():
            _wire(pool)
    return tracer
