"""repro.obs — the unified flight recorder.

``events`` is the zero-overhead-when-off bus (safe to import from hot
paths); ``metrics`` derives per-instance time-series, TTFT attribution,
and the Fig. 2 interference score from a captured event list;
``export`` renders JSONL and Chrome-trace/Perfetto JSON.  Only the
events layer is re-exported here so importing ``repro.obs`` stays as
cheap as the hot paths that depend on it.
"""
from repro.obs.events import (NULL_TRACER, NullTracer, Tracer,
                              attach_decision_log, attach_tracer)

__all__ = ["NULL_TRACER", "NullTracer", "Tracer", "attach_decision_log",
           "attach_tracer"]
