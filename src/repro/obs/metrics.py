"""Flight-recorder analysis: per-instance time series, per-request TTFT
attribution, TPOT jitter, and the decode-interference score.

Everything here consumes the raw event tuples (``repro.obs.events``,
field names in ``repro.obs.export.SCHEMA``) — the analyses run equally
on a live ``Tracer.events`` list or on events re-read from a JSONL
trace, and on sim or served (replay) runs, because both paths emit the
same bus.

TTFT attribution contract
-------------------------
For a request prefilled in a whole ``prefill`` slot (the PaDG default;
chunked-hybrid prefills are counted as ``unattributed``):

    ttft = queue_wait + prefill_wait + prefill_service + transfer

with ``queue_wait = t_admit - t_arrive`` (arrival to the *last*
admission: direct admit or queue drain), ``prefill_wait =
t_slot - t_admit`` (admitted but waiting for the prefill batch to
start), ``prefill_service = dur`` (the slot span; the sim stamps the
first token at slot end), and ``transfer = 0.0`` in simulation (FuDG KV
handoff happens *after* the first-token stamp; real-path transfers
would land here).  The decomposition telescopes, so the components sum
to the measured TTFT *exactly* — bit-equal, not approximately — which
``tests/golden/trace_attribution.json`` pins.

Interference score
------------------
The paper's Fig. 2 observation: co-locating prefill with decode
stretches decode steps.  Per instance we walk the slot chain in time
order; for each decode/hybrid slot that extends a *contiguous* chain
(no idle gap) after a previous decode, the stretch is
``(t_end - prev_decode_end) / dur`` — 1.0 when decode steps run
back-to-back, > 1.0 when prefill slots were interleaved between them.
The score is the mean stretch minus 1.0 (0.0 = perfect isolation).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.obs.events import slot_rids

_EPS = 1e-9
_DECODE_KINDS = ("decode", "hybrid")


def _events_of(tracer_or_events) -> List[tuple]:
    return list(getattr(tracer_or_events, "events", tracer_or_events))


def _percentile(xs: List[float], p: float) -> float:
    """Nearest-rank percentile without numpy (keeps this module
    import-light for the CLI)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
    return float(s[k])


# --------------------------------------------------------------------- #
# per-instance time series
# --------------------------------------------------------------------- #
def instance_series(tracer_or_events) -> Dict[int, Dict[str, list]]:
    """Per-instance time series sampled at slot boundaries: parallel
    lists keyed ``t, kind, dur, batch, kv_occupancy, queue_depth,
    decode_batch_util, prefill_backlog_tokens``."""
    out: Dict[int, Dict[str, list]] = {}
    for ev in _events_of(tracer_or_events):
        if ev[0] != "slot":
            continue
        (_, t, iid, kind, dur, rids, kv_used, kv_cap, n_pending,
         pending_tokens, n_decoding, queue_len, max_batch) = ev
        s = out.setdefault(iid, defaultdict(list))
        s["t"].append(t)
        s["kind"].append(kind)
        s["dur"].append(dur)
        s["batch"].append(len(rids))
        s["kv_occupancy"].append(kv_used / kv_cap if kv_cap else 0.0)
        s["queue_depth"].append(queue_len)
        s["decode_batch_util"].append(
            n_decoding / max_batch if max_batch else 0.0)
        s["prefill_backlog_tokens"].append(pending_tokens)
    return {iid: dict(s) for iid, s in out.items()}


# --------------------------------------------------------------------- #
# TTFT attribution + TPOT jitter
# --------------------------------------------------------------------- #
def attribution(tracer_or_events) -> Dict[str, object]:
    """Per-request TTFT attribution rows + aggregate digest.

    Returns ``{"rows": [...], "unattributed": int, "totals": {...}}``;
    each row carries ``rid, arrival, admit, slot_start, queue_wait,
    prefill_wait, prefill_service, transfer, ttft`` with the exactness
    invariant ``queue_wait + prefill_wait + prefill_service + transfer
    == ttft`` (see the module docstring).  Requests prefilled via
    chunked-hybrid slots or with an incomplete lifecycle count as
    ``unattributed``."""
    events = _events_of(tracer_or_events)
    arrive: Dict[int, float] = {}
    admits: Dict[int, List[float]] = defaultdict(list)
    last_prefill: Dict[int, Tuple[float, float]] = {}  # rid -> (t, dur)

    for ev in events:
        etype = ev[0]
        if etype == "arrive":
            arrive[ev[2]] = ev[1]
        elif etype in ("admit", "drain"):
            admits[ev[2]].append(ev[1])
        elif etype == "slot":
            _, t, _iid, kind, dur, rids = ev[:6]
            if kind == "prefill":
                for rid in slot_rids(rids):
                    last_prefill[rid] = (t, dur)
            # NB: a hybrid slot's rids are its decode batch; the chunked
            # prefills riding it never appear in a whole prefill slot
            # and therefore count as unattributed
        elif etype == "requeue":
            # resubmitted after a fault: earlier prefill evidence is
            # stale, the post-requeue lifecycle decides
            last_prefill.pop(ev[2], None)

    rows = []
    unattributed = 0
    for rid, t_arr in sorted(arrive.items()):
        hit = last_prefill.get(rid)
        if hit is None:
            # never whole-slot prefilled (still queued at horizon, or
            # chunked-hybrid prefill)
            unattributed += 1
            continue
        t_slot, dur = hit
        adm = [a for a in admits.get(rid, ()) if a <= t_slot + _EPS]
        if not adm:
            unattributed += 1
            continue
        t_adm = adm[-1]
        queue_wait = t_adm - t_arr
        prefill_wait = t_slot - t_adm
        transfer = 0.0
        ttft = queue_wait + prefill_wait + dur + transfer
        rows.append({
            "rid": rid, "arrival": t_arr, "admit": t_adm,
            "slot_start": t_slot, "queue_wait": queue_wait,
            "prefill_wait": prefill_wait, "prefill_service": dur,
            "transfer": transfer, "ttft": ttft})

    def _tot(key: str) -> float:
        return sum(r[key] for r in rows)

    totals = {k: _tot(k) for k in ("queue_wait", "prefill_wait",
                                   "prefill_service", "transfer", "ttft")}
    totals["n"] = len(rows)
    return {"rows": rows, "unattributed": unattributed, "totals": totals}


def tpot_jitter(tracer_or_events) -> Dict[str, object]:
    """Per-token TPOT jitter from decode-slot spans.

    A request's token timeline is its prefill completion followed by the
    ends of every decode/hybrid slot it rode; per-request we report the
    mean inter-token gap and the jitter ``p99_gap - p50_gap``, then
    aggregate p50/p99 over requests."""
    events = _events_of(tracer_or_events)
    first_token: Dict[int, float] = {}
    decode_ends: Dict[int, List[float]] = defaultdict(list)
    for ev in events:
        if ev[0] != "slot":
            continue
        _, t, _iid, kind, dur, rids = ev[:6]
        if kind == "prefill":
            for rid in slot_rids(rids):
                first_token[rid] = t + dur
        elif kind in _DECODE_KINDS:
            for rid in slot_rids(rids):
                decode_ends[rid].append(t + dur)
    per_req = []
    for rid, ft in first_token.items():
        ends = decode_ends.get(rid)
        if not ends:
            continue
        times = [ft] + sorted(ends)
        gaps = [b - a for a, b in zip(times, times[1:])]
        per_req.append({
            "rid": rid, "n_tokens": len(gaps),
            "tpot_mean": sum(gaps) / len(gaps),
            "tpot_jitter": _percentile(gaps, 99) - _percentile(gaps, 50)})
    return {
        "n": len(per_req),
        "tpot_mean_p50": _percentile([r["tpot_mean"] for r in per_req], 50),
        "tpot_jitter_p50": _percentile(
            [r["tpot_jitter"] for r in per_req], 50),
        "tpot_jitter_p99": _percentile(
            [r["tpot_jitter"] for r in per_req], 99),
        "per_request": per_req}


# --------------------------------------------------------------------- #
# interference score (paper Fig. 2)
# --------------------------------------------------------------------- #
def interference(tracer_or_events) -> Dict[str, float]:
    """Decode-step stretch on contiguous slot chains (module docstring).
    Returns ``{score, p50, p99, max, n}`` where score = mean stretch
    - 1.0 (0.0 = decode never waited behind prefill)."""
    per_inst: Dict[int, List[Tuple[float, str, float]]] = defaultdict(list)
    for ev in _events_of(tracer_or_events):
        if ev[0] == "slot":
            per_inst[ev[2]].append((ev[1], ev[3], ev[4]))
    stretches: List[float] = []
    for slots in per_inst.values():
        slots.sort(key=lambda s: s[0])
        prev_end: Optional[float] = None
        prev_decode_end: Optional[float] = None
        for t, kind, dur in slots:
            if prev_end is not None and t - prev_end > _EPS:
                prev_decode_end = None    # idle gap breaks the chain
            if kind in _DECODE_KINDS and dur > 0:
                if prev_decode_end is not None:
                    stretches.append((t + dur - prev_decode_end) / dur)
                prev_decode_end = t + dur
            prev_end = t + dur
    if not stretches:
        return {"score": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0, "n": 0}
    return {
        "score": sum(stretches) / len(stretches) - 1.0,
        "p50": _percentile(stretches, 50),
        "p99": _percentile(stretches, 99),
        "max": max(stretches),
        "n": len(stretches)}


# --------------------------------------------------------------------- #
# run digest (the CLI `summarize` payload)
# --------------------------------------------------------------------- #
def summarize(tracer_or_events) -> Dict[str, object]:
    """Whole-trace digest: event counts by type, time span, instance
    count, attribution totals (+ the exactness check), TPOT jitter
    aggregates, and the interference score."""
    events = _events_of(tracer_or_events)
    counts: Dict[str, int] = defaultdict(int)
    t_lo, t_hi = float("inf"), float("-inf")
    iids = set()
    for ev in events:
        counts[ev[0]] += 1
        if ev[1] >= 0:
            t_lo = min(t_lo, ev[1])
            t_hi = max(t_hi, ev[1])
        if ev[0] == "slot":
            iids.add(ev[2])
    attr = attribution(events)
    tot = attr["totals"]
    # the exactness contract is PER ROW (module docstring): each row's
    # components sum bit-equal to its ttft.  (Cross-row totals are not
    # compared — summing per-component then adding rounds differently
    # than summing per-row ttfts.)
    exact = all(
        r["queue_wait"] + r["prefill_wait"] + r["prefill_service"]
        + r["transfer"] == r["ttft"] for r in attr["rows"])
    jit = tpot_jitter(events)
    return {
        "events": len(events),
        "by_type": dict(sorted(counts.items())),
        "t_span": [t_lo, t_hi] if events and t_lo <= t_hi else [0.0, 0.0],
        "instances": len(iids),
        "attribution": {
            "n": tot["n"], "unattributed": attr["unattributed"],
            "ttft_total": tot["ttft"],
            "exact": exact},
        "tpot": {k: jit[k] for k in ("n", "tpot_mean_p50",
                                     "tpot_jitter_p50", "tpot_jitter_p99")},
        "interference": interference(events)}
