"""Minimal pure-JAX AdamW (tree-based, no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    m: Any                # like params, f32
    v: Any                # like params, f32


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads: Any, state: AdamWState,
               params: Any) -> Tuple[Any, AdamWState]:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
        step = state.step + 1
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - self.lr * delta
            return p_new.astype(p.dtype), m_new, v_new

        # three passes (XLA CSEs the duplicated math under jit); avoids
        # tuple-leaf transposition clashing with tuple-structured params
        p_new = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[0],
                             grads, state.m, state.v, params)
        m_new = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[1],
                             grads, state.m, state.v, params)
        v_new = jax.tree.map(lambda g, m, v, p: upd(g, m, v, p)[2],
                             grads, state.m, state.v, params)
        return p_new, AdamWState(step=step, m=m_new, v=v_new)
