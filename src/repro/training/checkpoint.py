"""Checkpointing: numpy-npz based, pytree-structure preserving."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _base(path: str) -> str:
    return path[:-4] if path.endswith(".npz") else path


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    base = _base(path)
    os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(base + ".npz", **arrays)
    with open(base + ".meta.json", "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef)}, f)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    base = _base(path)
    data = np.load(base + ".npz")
    leaves, treedef = _flatten(like)
    new_leaves = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {leaf.shape}")
        new_leaves.append(arr)
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), meta["step"]
