"""Training loop (single-host or mesh-distributed via the same step
builders the dry-run uses)."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import init_params, make_loss_fn
from repro.models.layers import MeshInfo
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamW


def train(
    cfg: ModelConfig,
    batches: Iterator[Dict],
    *,
    steps: int = 200,
    optimizer: AdamW = AdamW(lr=1e-3),
    mi: MeshInfo = MeshInfo(),
    dtype=jnp.float32,
    seed: int = 0,
    log_every: int = 10,
    checkpoint_path: Optional[str] = None,
    log_fn: Callable[[str], None] = print,
):
    params = init_params(jax.random.key(seed), cfg, dtype)
    opt_state = optimizer.init(params)
    loss_fn = make_loss_fn(cfg, mi)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    losses = []
    t0 = time.perf_counter()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            dt = time.perf_counter() - t0
            log_fn(f"step {step:5d}  loss {losses[-1]:.4f}  "
                   f"({dt / (step + 1):.3f}s/step)")
    if checkpoint_path:
        save_checkpoint(checkpoint_path, params, step=steps)
    return params, losses
