from repro.training.optimizer import AdamW, AdamWState  # noqa: F401
