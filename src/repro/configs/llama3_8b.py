"""Llama-3-8B — dense GQA decoder with a 128k vocabulary.

[arXiv:2407.21783] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    citation="arXiv:2407.21783 (The Llama 3 Herd of Models)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128_256,
    block_pattern=(ATTN,),
    rope="full",
    rope_theta=500_000.0,
)
