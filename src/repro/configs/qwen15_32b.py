"""Qwen1.5-32B — dense MHA-like decoder (kv=40) with QKV bias.

[hf:Qwen/Qwen1.5 family card] 64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    citation="hf:Qwen/Qwen1.5-0.5B (family card)",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    block_pattern=(ATTN,),
    qkv_bias=True,
    rope="full",
    rope_theta=1_000_000.0,
)
