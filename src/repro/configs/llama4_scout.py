"""Llama-4 Scout (17B active, 16 experts) — MoE top-1, chunked attention.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16e top-1, early fusion.  Scout's model card
uses chunked (local) attention on most layers, enabling 500k+ contexts —
we model every block as sliding-window 8192, which keeps long_500k
sub-quadratic.
"""
from repro.configs.base import ModelConfig, LOCAL_ATTN

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=(LOCAL_ATTN,),
    sliding_window=8192,
    num_experts=16,
    top_k=1,
    rope="full",
)
