"""Qwen2-72B — the paper's largest evaluation model.

[arXiv:2407.10671] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    citation="arXiv:2407.10671 (Qwen2)",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    block_pattern=(ATTN,),
    qkv_bias=True,
    rope="full",
    rope_theta=1_000_000.0,
)
