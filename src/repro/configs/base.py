"""Model configuration system.

A ``ModelConfig`` fully describes one architecture from the assigned pool
(or one of the paper's own evaluation models).  Families share one
composable transformer implementation in ``repro.models``; the config
selects the block pattern, attention flavour, MoE settings, etc.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds usable in ``block_pattern`` (repeated cyclically over layers).
ATTN = "attn"          # global causal attention (bidirectional if encoder)
LOCAL_ATTN = "local"   # sliding-window causal attention
RGLRU = "rglru"        # RG-LRU recurrent block (Griffin / RecurrentGemma)
RWKV6 = "rwkv6"        # RWKV-6 "Finch" time-mix block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    citation: str                # source paper / model card
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # --- block structure -------------------------------------------------
    block_pattern: Tuple[str, ...] = (ATTN,)
    sliding_window: int = 0      # window for LOCAL_ATTN blocks
    is_encoder: bool = False     # bidirectional, no decode phase (hubert)

    # --- attention flavour ------------------------------------------------
    qk_norm: bool = False        # qwen3: RMSNorm on q and k heads
    qkv_bias: bool = False       # qwen1.5 / qwen2-vl
    rope: str = "full"           # full | half (chatglm 2d) | mrope | none
    rope_theta: float = 10_000.0

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- modality frontend stub --------------------------------------------
    modality: str = "text"       # text | audio | vision
    frontend_dim: int = 0        # embedding dim produced by the stub frontend
    num_patches: int = 0         # vlm: patches provided per sample

    # --- norms / misc -------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_soft_cap: float = 0.0  # recurrentgemma uses 30.0

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ #
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return all(b in (RGLRU, RWKV6) for b in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if no block attends to unbounded context (long_500k eligible)."""
        return all(
            b in (RGLRU, RWKV6) or (b == LOCAL_ATTN and self.sliding_window > 0)
            for b in self.block_pattern
        )

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length num_layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    # --- parameter counting (for roofline MODEL_FLOPS = 6 N D) ----------- #
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.block_kinds():
            if kind in (ATTN, LOCAL_ATTN):
                attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
            elif kind == RGLRU:
                # w_x, w_gate, w_out, w_in_gate, w_rec_gate (+conv, small)
                attn = 5 * d * d
            elif kind == RWKV6:
                # r,k,v,g,o projections + decay lora
                attn = 5 * d * d + 2 * d * 64
            else:  # pragma: no cover
                raise ValueError(kind)
            if kind == RWKV6:
                ffn = 2 * d * self.d_ff          # squared-relu channel mix
            elif self.is_moe:
                n_eff = self.top_k if active_only else self.num_experts
                ffn = n_eff * 3 * d * self.d_ff + d * self.num_experts
            else:
                ffn = 3 * d * self.d_ff          # gated (SwiGLU-style) MLP
            total += attn + ffn
        return total

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache (or recurrent-state amortized) bytes per token of context."""
        per_layer = 0
        for kind in self.block_kinds():
            if kind == ATTN:
                per_layer += 2 * self.num_kv_heads * self.head_dim * dtype_bytes
            elif kind == LOCAL_ATTN:
                per_layer += 2 * self.num_kv_heads * self.head_dim * dtype_bytes
            # recurrent blocks hold O(1) state -> 0 per token
        return per_layer
