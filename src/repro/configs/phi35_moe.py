"""Phi-3.5-MoE (42B total, 6.6B active) — 16 experts, top-2 routing.

[hf:microsoft/Phi-3.5-MoE-instruct] 32L d_model=4096 32H (GQA kv=8)
d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    block_pattern=(ATTN,),
    num_experts=16,
    top_k=2,
    rope="full",
)
