"""CodeLlama2-34B — the paper's GQA evaluation model.

[arXiv:2308.12950] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=32016.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="codellama2-34b",
    family="dense",
    citation="arXiv:2308.12950 (Code Llama)",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=32_016,
    block_pattern=(ATTN,),
    rope="full",
    rope_theta=1_000_000.0,
)
