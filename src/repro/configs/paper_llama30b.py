"""Llama-30B — the paper's MHA evaluation model (Table 3 / Fig. 8).

[arXiv:2302.13971] 60L d_model=6656 52H (MHA) d_ff=17920 vocab=32000.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llama-30b",
    family="dense",
    citation="arXiv:2302.13971 (LLaMA)",
    num_layers=60,
    d_model=6656,
    num_heads=52,
    num_kv_heads=52,
    d_ff=17920,
    vocab_size=32_000,
    block_pattern=(ATTN,),
    rope="full",
)
