"""HuBERT X-Large — encoder-only audio transformer (wav2vec2-style arch).

[arXiv:2106.07447] 48L d_model=1280 16H (MHA, kv=16) d_ff=5120 vocab=504.
The conv feature-extractor frontend is a STUB per the brief: input_specs()
provides precomputed frame embeddings (B, S, frontend_dim).
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    citation="arXiv:2106.07447 (HuBERT)",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    block_pattern=(ATTN,),
    is_encoder=True,
    rope="none",          # hubert uses conv positional embedding; stubbed
    modality="audio",
    frontend_dim=512,
)
