"""ChatGLM3-6B — dense GQA decoder with rotary applied to half the head dim.

[arXiv:2406.12793] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    citation="arXiv:2406.12793 (ChatGLM)",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65_024,
    block_pattern=(ATTN,),
    qkv_bias=True,        # chatglm uses bias on qkv only
    rope="half",          # 2d rope: rotary on first half of head_dim
)
