"""RWKV-6 "Finch" 3B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536.  Time-mix block
keeps a per-head (head_dim x head_dim) state; decode is O(1) in context.
"""
from repro.configs.base import ModelConfig, RWKV6

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    citation="arXiv:2404.05892 (RWKV-6 Finch)",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # wkv heads (head_dim 64); attention-free
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    block_pattern=(RWKV6,),
    rope="none",
)
