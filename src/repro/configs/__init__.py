"""Architecture registry.

``get_config(arch_id)`` returns the full assigned configuration;
``get_smoke_config(arch_id)`` returns a reduced variant of the same family
(<=2 layers, d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama3-8b": "llama3_8b",
    "llama3-8b-sw": "llama3_8b_sw",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "qwen3-4b": "qwen3_4b",
    "hubert-xlarge": "hubert_xlarge",
    "llama4-scout-17b-a16e": "llama4_scout",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen1.5-32b": "qwen15_32b",
    "chatglm3-6b": "chatglm3_6b",
    "rwkv6-3b": "rwkv6_3b",
    # the paper's own evaluation models
    "llama-30b": "paper_llama30b",
    "codellama2-34b": "paper_codellama34b",
    "qwen2-72b": "paper_qwen2_72b",
}

# The ten assigned architectures (llama3-8b-sw is a documented extra
# variant used only for long_500k; paper models are for the benchmarks).
ASSIGNED: List[str] = [
    "recurrentgemma-2b",
    "llama3-8b",
    "phi3.5-moe-42b-a6.6b",
    "qwen3-4b",
    "hubert-xlarge",
    "llama4-scout-17b-a16e",
    "qwen2-vl-2b",
    "qwen1.5-32b",
    "chatglm3-6b",
    "rwkv6-3b",
]

_cache: Dict[str, ModelConfig] = {}


def available_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _cache:
        if arch_id not in _MODULES:
            raise KeyError(
                f"unknown arch {arch_id!r}; available: {sorted(_MODULES)}")
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
        _cache[arch_id] = mod.CONFIG
    return _cache[arch_id]


def get_smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    cfg = get_config(arch_id)
    pattern = cfg.block_pattern
    n_layers = max(2, len(pattern))  # keep at least one full pattern cycle
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = max(1, min(cfg.num_kv_heads, heads)) if heads else 0
    d_model = 256
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if heads else 0,
        d_ff=512,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
        num_patches=min(cfg.num_patches, 16) if cfg.num_patches else 0,
    )
    return dataclasses.replace(cfg, **updates)
