"""Llama-3-8B sliding-window VARIANT (beyond-assignment, long_500k only).

Identical to llama3-8b but every block uses a 8192-token sliding window so
the 524k-context decode shape is sub-quadratic.  This is the documented
extra variant from DESIGN.md; the faithful ``llama3-8b`` config is
unchanged.
"""
import dataclasses

from repro.configs.base import LOCAL_ATTN
from repro.configs.llama3_8b import CONFIG as _BASE

CONFIG = dataclasses.replace(
    _BASE,
    name="llama3-8b-sw",
    block_pattern=(LOCAL_ATTN,),
    sliding_window=8192,
)
