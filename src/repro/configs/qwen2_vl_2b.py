"""Qwen2-VL-2B — VLM language backbone with M-RoPE.

[arXiv:2409.12191] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
The ViT frontend is a STUB per the brief: input_specs() provides patch
embeddings (B, num_patches, frontend_dim) + (t, h, w) positions; M-RoPE
splits the rotary dims into three position components.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    citation="arXiv:2409.12191 (Qwen2-VL)",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    block_pattern=(ATTN,),
    qkv_bias=True,
    rope="mrope",
    rope_theta=1_000_000.0,
    modality="vision",
    frontend_dim=1152,     # SigLIP-style patch embedding dim
    num_patches=1024,
)
