"""RecurrentGemma-2B — Griffin hybrid: (RG-LRU, RG-LRU, local-attn) blocks.

[arXiv:2402.19427] 26L d_model=2560 10H (GQA kv=1, head_dim=256 in the
paper; we keep d_model/num_heads=256) d_ff=7680 vocab=256000, local
attention window 2048, logit soft cap 30.
"""
from repro.configs.base import ModelConfig, RGLRU, LOCAL_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427 (RecurrentGemma / Griffin)",
    num_layers=26,          # 26 blocks; pattern cycles (rglru, rglru, local)
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    sliding_window=2048,
    rope="full",
    logit_soft_cap=30.0,
    tie_embeddings=True,
)
