"""Qwen3-4B — dense GQA decoder with per-head q/k RMSNorm.

[hf:Qwen/Qwen3-8B family] 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, qk_norm.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B (Qwen3 family card)",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    block_pattern=(ATTN,),
    qk_norm=True,
    rope="full",
    rope_theta=1_000_000.0,
)
