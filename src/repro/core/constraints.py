"""Algorithm 2: Constraint Checking (verbatim from the paper).

Given an instance's status and an incoming request, verify that admitting
the request violates neither the TTFT SLO (constraint 1), the TPOT SLO of
the decodes already running there (constraint 2), nor the KV-cache memory
capacity (constraint 3).

Multi-tenant note: ``slo`` is the budget the INCOMING request is checked
against — under an ``SLOClassSet`` the router passes the request's own
class SLO here, and ``status.saved_tpots`` already accrues each running
decode's slack against that decode's own class TPOT (see
``Instance.status``), so constraint 2 stays per-tenant consistent.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.instance import InstanceStatus
from repro.core.request import Request
from repro.core.slo import SLO


def check_constraints(
    status: InstanceStatus,
    req: Request,
    slo: SLO,
    predict_prefill: Callable[[int], float],
    now: float,
    *,
    expected_kv_tokens: Optional[int] = None,
    conservative: bool = False,
) -> bool:
    # ---- Constraint 1: TTFT ------------------------------------------- #
    # pending prefills admitted since the phase switch, plus the new one
    t_total = sum(predict_prefill(n) for n in status.pending_prefill_lens)
    t_total += predict_prefill(req.prompt_len)
    # requests queue behind the prefills already pending on this instance;
    # the elapsed wait of the new request also counts against its TTFT
    already_waited = max(0.0, now - req.arrival_time)
    if t_total + already_waited > slo.ttft:
        return False

    # ---- Constraint 2: TPOT ------------------------------------------- #
    # inserting t_total of prefill work delays every running decode by
    # t_total; each decode has accumulated `saved_tpot` slack (line 15)
    if status.saved_tpots:
        if conservative:   # EcoServe++: protect the youngest decode too
            if min(status.saved_tpots) < t_total:
                return False
        else:              # paper Algorithm 2 line 16: mean
            mean_saved = sum(status.saved_tpots) / len(status.saved_tpots)
            if mean_saved < t_total:
                return False
    # 2b: the request's own decode joins the batch — the projected decode
    # iteration time must stay within the TPOT SLO ("prioritizing the
    # maintenance of satisfactory TPOT", §3.4).  The budget is the
    # tighter of the incoming request's class TPOT and the strictest
    # budget among decodes already running (``decode_tpot_floor``): a
    # lax-class admission must not slow the shared decode batch past a
    # tight-class tenant's SLO.  Single-class mode: floor == slo.tpot.
    if status.decode_iter_time_plus_one > min(slo.tpot,
                                              status.decode_tpot_floor):
        return False

    # ---- Constraint 3: KV cache capacity ------------------------------ #
    want = expected_kv_tokens if expected_kv_tokens is not None else (
        req.prompt_len * 2)   # prompt + headroom for generation
    if want > status.kv_tokens_free:
        return False
    return True
