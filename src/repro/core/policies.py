"""Pluggable serving policies: queue disciplines, admission, routing.

The paper frames NoDG/FuDG/PaDG as points in one design space — what
differs between the strategies is *policy* (how requests are queued,
admitted, and routed), not machinery.  This module factors those three
decisions into small strategy objects that ``PolicySystemBase``
(``repro.core.system``) composes:

* ``QueueDiscipline`` — the order in which the system-level waiting
  queue is retried at slot boundaries (FIFO; SLO-priority via earliest
  per-class TTFT deadline; shortest-prompt-first).
* ``AdmissionPolicy`` — whether a request may enter an instance *now*
  (immediate; slack-guarded through constraint-checked routing;
  timeout-forced, the paper's "continuous stream" fallback;
  kv-guard, the slack-guarded NoDG variant holding KV headroom for each
  request's full footprint; backpressure, which defers to the queue
  once the target instance has a full prefill slot of backlog).
* ``RoutingPolicy`` — which instance an admission attempt targets
  (least-KV-loaded replica; round-robin; macro-instance rolling
  activation, Algorithm 1; FuDG prefill/decode partitioning).

Every policy is constructible from a declarative string spec
(``"timeout-forced:4"``) so ``StrategySpec`` (``repro.baselines``) can
name compositions like ``"vllm+priority"`` without code.  ``describe()``
round-trips back to that string, keeping result rows self-documenting.

Policies hold no per-request state of their own (round-robin's cursor is
the one deliberate exception); everything they need is read off the
``system`` passed to each call, so one policy object can be shared by
construction code paths without aliasing hazards.
"""
from __future__ import annotations

import heapq
import itertools
from typing import TYPE_CHECKING, Deque, List, Optional, Union

from repro.core.request import Request

if TYPE_CHECKING:
    from repro.core.instance import Instance
    from repro.core.slo import SLOClassSet


def _fmt(x: float) -> str:
    return f"{x:g}"


# --------------------------------------------------------------------- #
# queue disciplines
# --------------------------------------------------------------------- #


class QueueDiscipline:
    """Orders the system-level waiting queue for a drain pass.

    ``order`` returns the retry order over a snapshot of the queue,
    truncated to ``limit`` entries (the drain loop's try budget: a full
    sort of an overload backlog would put O(n log n) back on the
    per-slot-boundary hot path the PR 2 work flattened —
    ``heapq.nsmallest`` keeps it O(n log limit)).  The base system owns
    the actual membership; failed and untried requests keep their
    arrival order in the underlying deque.
    """

    name = "queue"

    def order(self, queue: Deque[Request], now: float,
              slo_set: Optional["SLOClassSet"],
              limit: Optional[int] = None) -> List[Request]:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


def _truncated(queue: Deque[Request], limit: Optional[int]
               ) -> List[Request]:
    if limit is None or len(queue) <= limit:
        return list(queue)
    return list(itertools.islice(queue, limit))


class FIFODiscipline(QueueDiscipline):
    """Arrival order — bit-identical to the pre-policy deque loop (which
    also never looked past its try budget)."""

    name = "fifo"

    def order(self, queue, now, slo_set, limit=None):
        return _truncated(queue, limit)


class SLOPriorityDiscipline(QueueDiscipline):
    """Earliest-deadline-first over per-class TTFT budgets: a queued
    request's deadline is ``arrival + its own class's TTFT``, so
    tight-TTFT tenants (alpaca, 1 s) jump ahead of lax ones (longbench,
    15 s) until the lax request has genuinely aged into urgency.  With a
    single class (or no SLO attached) this degrades to FIFO order."""

    name = "slo-priority"

    def order(self, queue, now, slo_set, limit=None):
        if slo_set is None:
            return _truncated(queue, limit)

        def deadline(r: Request):
            return (r.arrival_time + slo_set.for_request(r).ttft,
                    r.arrival_time, r.rid)

        if limit is not None:
            return heapq.nsmallest(limit, queue, key=deadline)
        return sorted(queue, key=deadline)


class ShortestPromptDiscipline(QueueDiscipline):
    """Shortest-prompt-first (SJF on prefill work): minimizes mean TTFT
    at the cost of long-prompt fairness — the classic counterpoint to
    EDF for serving queues."""

    name = "shortest-prompt"

    def order(self, queue, now, slo_set, limit=None):
        key = (lambda r: (r.prompt_len, r.arrival_time, r.rid))
        if limit is not None:
            return heapq.nsmallest(limit, queue, key=key)
        return sorted(queue, key=key)


# --------------------------------------------------------------------- #
# routing policies
# --------------------------------------------------------------------- #


class RoutingPolicy:
    """Chooses the instance an admission attempt targets.

    Two entry points: ``select`` picks a candidate *without* admitting
    (used by guard-style admission policies that want to inspect it);
    ``place`` performs the full constraint-checked admission attempt and
    returns the admitted instance or None.  The default ``place`` is
    select-then-admit; macro routing overrides it because Algorithm 1
    fuses the constraint check with admission.
    """

    name = "routing"

    def select(self, system, req: Request,
               now: float) -> Optional["Instance"]:
        raise NotImplementedError

    def place(self, system, req: Request,
              now: float) -> Optional["Instance"]:
        inst = self.select(system, req, now)
        if inst is None:
            return None
        inst.admit(req, now)
        return inst

    def place_forced(self, system, req: Request, now: float) -> "Instance":
        """Admission of last resort (SLO already lost): must admit."""
        inst = self.place(system, req, now)
        if inst is None:
            raise RuntimeError(f"{self.name} routing could not force-admit")
        return inst

    # ---- scaling hooks ------------------------------------------------ #
    def add_instance(self, system, inst: "Instance") -> None:
        """Make a freshly created instance routable (the base system has
        already appended it to ``system.instances``)."""

    def remove_instance(self, system) -> Optional["Instance"]:
        """Pick an instance to retire and stop routing to it; its
        in-flight work stays on it until drained."""
        if not system.instances:
            return None
        return min(system.instances, key=lambda i: i.kv_tokens_used())

    def discard_instance(self, system, inst: "Instance") -> None:
        """Stop routing to a *specific* instance (fault teardown: the
        fault picked the victim, not the retirement heuristic).  The
        base system has already dropped it from ``system.instances``;
        policies with their own membership structures override this."""

    def describe(self) -> str:
        return self.name


def _reachable(system, instances, now):
    """Transport-filtered candidate pool: the same list object on the
    clean plane (zero cost), the reachable subset under network faults.
    Guarded so bare test stand-ins without a transport still work."""
    tr = getattr(system, "transport", None)
    if tr is None or tr.network is None:
        return instances
    return tr.filter_reachable(instances, now)


class LeastKVRouting(RoutingPolicy):
    """vLLM-style: the replica with the fewest outstanding KV tokens."""

    name = "least-kv"

    def select(self, system, req, now):
        pool = _reachable(system, system.instances, now)
        if not pool:
            return None
        return min(pool, key=lambda i: i.kv_tokens_used())


class RoundRobinRouting(RoutingPolicy):
    """Cyclic placement; the cursor is the policy's only state."""

    name = "round-robin"

    def __init__(self):
        self._cursor = 0

    def select(self, system, req, now):
        if not system.instances:
            return None
        inst = system.instances[self._cursor % len(system.instances)]
        self._cursor += 1
        return inst


class MacroLeastUtilizedRouting(RoutingPolicy):
    """EcoServe inter-instance routing: macro instances in ascending
    utilization order, each running Algorithm 1 (sticky rolling
    activation + Algorithm 2 constraint check) via ``MacroInstance.
    route``; forced admission lands on the emptiest instance of the
    least-utilized macro.  Requires the system to expose ``sched``
    (an ``OverallScheduler``)."""

    name = "macro-least-utilized"

    def select(self, system, req, now):
        raise TypeError("macro routing fuses constraint-check and "
                        "admission (Algorithm 1); use place()")

    def place(self, system, req, now):
        for m in sorted(system.sched.macros,
                        key=lambda m: m.utilization(now)):
            inst = m.route(req, now)
            if inst is not None:
                return inst
        return None

    def place_forced(self, system, req, now):
        return system.sched.macros[0].route_forced(req, now)

    def add_instance(self, system, inst):
        system.sched.add_instance(inst)

    def remove_instance(self, system):
        return system.sched.remove_instance()

    def discard_instance(self, system, inst):
        system.sched.discard_instance(inst)


class PrefillPartitionedRouting(RoutingPolicy):
    """FuDG: new requests go to the least-backlogged *prefill* instance;
    decode instances only receive work through the KV hand-off path.
    Requires the system to expose ``prefill_insts``/``decode_insts``."""

    name = "prefill-least-pending"

    def select(self, system, req, now):
        pool = _reachable(system, system.prefill_insts, now)
        if not pool:
            return None
        return min(pool, key=lambda i: i.pending_tokens)

    def add_instance(self, system, inst):
        # decode is the paper's FuDG bottleneck under MHA KV traffic
        system.decode_insts.append(inst)

    def remove_instance(self, system):
        if len(system.decode_insts) <= 1:
            return None
        inst = min(system.decode_insts, key=lambda i: i.kv_tokens_used())
        system.decode_insts.remove(inst)
        return inst

    def discard_instance(self, system, inst):
        # a fault may take either kind — even the last decoder (that IS
        # the FuDG cliff the degradation bench measures)
        if inst in system.prefill_insts:
            system.prefill_insts.remove(inst)
        if inst in system.decode_insts:
            system.decode_insts.remove(inst)


# --------------------------------------------------------------------- #
# admission policies
# --------------------------------------------------------------------- #


class AdmissionPolicy:
    """Decides whether a request enters an instance *now* (returning the
    admitted instance) or stays in the system queue (returning None)."""

    name = "admission"

    def try_admit(self, system, req: Request,
                  now: float) -> Optional["Instance"]:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class ImmediateAdmission(AdmissionPolicy):
    """Admit on arrival wherever routing points (NoDG/FuDG baselines:
    the queue stays empty and all waiting happens inside instances)."""

    name = "immediate"

    def try_admit(self, system, req, now):
        return system.routing.place(system, req, now)


class SlackGuardedAdmission(AdmissionPolicy):
    """Admit only where constraint-checked routing accepts (Algorithm 2
    through ``MacroInstance.route``); otherwise queue — with no forced
    fallback, an unserviceable request waits forever."""

    name = "slack-guarded"

    def try_admit(self, system, req, now):
        return system.routing.place(system, req, now)


class TimeoutForcedAdmission(SlackGuardedAdmission):
    """The paper's continuous-stream admission: slack-guarded, but once a
    request has waited past ``timeout_factor`` x its OWN class's TTFT
    budget the SLO is unreachable anyway — force-admit so it still
    completes (counted as a violation)."""

    name = "timeout-forced"

    def __init__(self, timeout_factor: float = 4.0):
        self.timeout_factor = timeout_factor

    def try_admit(self, system, req, now):
        inst = system.routing.place(system, req, now)
        if inst is not None:
            return inst
        ttft = system.slo_set.for_request(req).ttft
        if now - req.arrival_time > self.timeout_factor * ttft:
            return system.routing.place_forced(system, req, now)
        return None

    def describe(self):
        return f"{self.name}:{_fmt(self.timeout_factor)}"


class KVGuardAdmission(AdmissionPolicy):
    """Slack-guarded NoDG admission: route normally, but admit only when
    the target instance has KV headroom for the request's *whole*
    footprint (prompt + maximum output tokens) inside
    ``headroom_fraction`` x capacity — otherwise the request waits in
    the system queue.  The NoDG counterpart of EcoServe's Algorithm 2
    guard: instead of slack over predicted slot times, a replica
    guards the one resource whose exhaustion it cannot schedule around
    (KV memory), deferring work rather than overcommitting."""

    name = "kv-guard"

    def __init__(self, headroom_fraction: float = 0.9):
        self.headroom_fraction = headroom_fraction

    def try_admit(self, system, req, now):
        inst = system.routing.select(system, req, now)
        if inst is None:
            return None
        footprint = req.prompt_len + req.output_len
        budget = self.headroom_fraction * inst.kv_capacity_tokens
        if inst.kv_tokens_used() + footprint <= budget:
            inst.admit(req, now)
            return inst
        return None

    def describe(self):
        return f"{self.name}:{_fmt(self.headroom_fraction)}"


class BackpressureAdmission(AdmissionPolicy):
    """Defer to the system queue once the routed instance already holds
    ``max_backlog_fraction`` x its ``max_prefill_tokens`` of pending
    prefill work.  On its own this only bounds per-instance backlog; its
    point is composition with a non-FIFO ``QueueDiscipline`` — work that
    would have sat in an instance's arrival-ordered pending list waits
    in the *system* queue instead, where the discipline can reorder it
    (e.g. ``"vllm+priority"``: EDF over per-class TTFT deadlines)."""

    name = "backpressure"

    def __init__(self, max_backlog_fraction: float = 0.125):
        self.max_backlog_fraction = max_backlog_fraction

    def try_admit(self, system, req, now):
        inst = system.routing.select(system, req, now)
        if inst is None:
            return None
        budget = self.max_backlog_fraction * inst.max_prefill_tokens
        if inst.pending_tokens <= budget:
            inst.admit(req, now)
            return inst
        return None

    def describe(self):
        return f"{self.name}:{_fmt(self.max_backlog_fraction)}"


# --------------------------------------------------------------------- #
# declarative construction
# --------------------------------------------------------------------- #

QUEUE_DISCIPLINES = {
    FIFODiscipline.name: FIFODiscipline,
    SLOPriorityDiscipline.name: SLOPriorityDiscipline,
    ShortestPromptDiscipline.name: ShortestPromptDiscipline,
}

ADMISSION_POLICIES = {
    ImmediateAdmission.name: ImmediateAdmission,
    SlackGuardedAdmission.name: SlackGuardedAdmission,
    TimeoutForcedAdmission.name: TimeoutForcedAdmission,
    KVGuardAdmission.name: KVGuardAdmission,
    BackpressureAdmission.name: BackpressureAdmission,
}

ROUTING_POLICIES = {
    LeastKVRouting.name: LeastKVRouting,
    RoundRobinRouting.name: RoundRobinRouting,
    MacroLeastUtilizedRouting.name: MacroLeastUtilizedRouting,
    PrefillPartitionedRouting.name: PrefillPartitionedRouting,
}


def _make(registry, spec, base_cls, kind: str):
    if isinstance(spec, base_cls):
        return spec
    if isinstance(spec, str):
        name, _, arg = spec.partition(":")
        if name not in registry:
            raise KeyError(f"unknown {kind} policy {name!r}; expected one "
                           f"of {tuple(registry)}")
        cls = registry[name]
        return cls(float(arg)) if arg else cls()
    raise TypeError(f"cannot build a {kind} policy from {spec!r}")


def make_queue_discipline(
        spec: Union[str, QueueDiscipline]) -> QueueDiscipline:
    """``"fifo"`` / ``"slo-priority"`` / ``"shortest-prompt"`` or an
    instance (passed through)."""
    return _make(QUEUE_DISCIPLINES, spec, QueueDiscipline, "queue")


def make_admission(spec: Union[str, AdmissionPolicy]) -> AdmissionPolicy:
    """``"immediate"`` / ``"slack-guarded"`` / ``"timeout-forced[:F]"`` /
    ``"kv-guard[:F]"`` / ``"backpressure[:F]"`` (``:F`` is the policy's
    float parameter) or an instance (passed through)."""
    return _make(ADMISSION_POLICIES, spec, AdmissionPolicy, "admission")


def make_routing(spec: Union[str, RoutingPolicy]) -> RoutingPolicy:
    """``"least-kv"`` / ``"round-robin"`` / ``"macro-least-utilized"`` /
    ``"prefill-least-pending"`` or an instance (passed through)."""
    return _make(ROUTING_POLICIES, spec, RoutingPolicy, "routing")
