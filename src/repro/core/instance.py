"""Instance: one model replica with temporal prefill/decode disaggregation.

This is the paper's *instance scheduler* (Fig. 5 step 5).  The instance is
execution-backend agnostic: durations come from an ``ExecutorModel``
(analytical cost model in the simulator; measured wall-clock in the
real-exec engine).  Scheduling policy (PaDG intra-instance rule):

  * prefills are prioritized — whenever admitted prefills are pending,
    the next slot is a prefill batch;
  * otherwise run one decode iteration over the running batch;
  * each slot is an uninterruptible unit of work (phase switches happen
    only at slot boundaries, which is what makes the disaggregation
    *temporal*).

Hot-path accounting is incremental: the instance maintains running
aggregates (pending prefill tokens, decode KV/context sums) that are
updated in O(1) on every admit/complete/hand-off instead of re-summing
``self.pending``/``self.decoding`` at each slot boundary.  All membership
changes MUST therefore go through the mutator methods below
(``admit``/``remove_pending``/``add_decoding``/``remove_decoding``/
``sync_tokens``/``handoff_prefilled``) — never mutate the lists directly.
Every mutator bumps ``_version``, which invalidates the status cache and
the cached next-prefill-batch plan.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Protocol, Tuple

from repro.core.request import Request, RequestState

if TYPE_CHECKING:
    from repro.core.slo import SLOClassSet


class ExecutorModel(Protocol):
    def prefill_time(self, prompt_lens: List[int]) -> float: ...
    def decode_time(self, batch_size: int, ctx_lens: List[int]) -> float: ...
    # optional fast path (see InstanceCostModel): an integer `ctx_clamp`
    # attribute plus `decode_time(n, ctx_sum=...)` /
    # `hybrid_time(..., decode_ctx_sum=...)` keyword forms that take the
    # precomputed clamped-context sum instead of a per-sequence list
    # optional (EcoServe-CP): fused decode+chunk iteration
    # def hybrid_time(self, chunk_lens, prefix_lens, batch, ctxs): ...


@dataclasses.dataclass
class InstanceStatus:
    """What the instance periodically reports to its macro-instance
    scheduler (decode progress, memory, phase)."""
    iid: int
    phase: str                       # prefill | decode | idle
    pending_prefill_lens: List[int]
    pending_prefill_tokens: int
    num_decoding: int
    saved_tpots: List[float]
    kv_tokens_used: int
    kv_tokens_capacity: int
    last_switch_time: float
    # projected decode iteration time if one more request joins the batch
    # (guards TPOT against unbounded decode-batch growth)
    decode_iter_time_plus_one: float = 0.0
    # tightest TPOT budget among the decodes already running here (the
    # scalar instance SLO in single-class mode): admission must not slow
    # the shared decode batch past the strictest running tenant's budget
    decode_tpot_floor: float = float("inf")

    @property
    def kv_tokens_free(self) -> int:
        return self.kv_tokens_capacity - self.kv_tokens_used


class Instance:
    """Simulation-state instance; also the scheduling brain reused by the
    real-exec engine (which overrides the executor with measured times)."""

    # FuDG prefill-only instances override this (see baselines)
    decode_here = True
    # cleared by the fault layer (repro.faults) on crash / preemption
    # deadline; the engine discards in-flight slots of dead instances and
    # never activates them again
    alive = True

    def __init__(self, iid: int, executor: ExecutorModel,
                 kv_capacity_tokens: int,
                 max_prefill_tokens: int = 16_384,
                 max_decode_batch: int = 256,
                 max_prefill_batch: Optional[int] = None,
                 slo_tpot: Optional[float] = None,
                 slo_ttft: Optional[float] = None,
                 conservative_slack: bool = False,
                 chunked_fallback: int = 0,
                 slo_classes: Optional["SLOClassSet"] = None):
        self.iid = iid
        self.executor = executor
        self.kv_capacity_tokens = kv_capacity_tokens
        self.max_prefill_tokens = max_prefill_tokens
        self.max_decode_batch = max_decode_batch
        # Slot-coupled prefill cap (real-exec engines): each prefilled
        # request lands in one of ``max_prefill_batch`` physical decode
        # slots, so a prefill batch may take at most
        # ``max_prefill_batch - len(decoding)`` requests.  None (default)
        # keeps the simulator's token-bounded-only plan, bit-identically.
        self.max_prefill_batch = max_prefill_batch
        # PaDG intra-instance rule (§3.1): with a TPOT SLO known, the
        # instance keeps decoding until its decodes have accumulated
        # enough slack to absorb the pending prefill slot.  None disables
        # the guard (NoDG baselines are strictly prefill-prioritized).
        self.slo_tpot = slo_tpot
        self.slo_ttft = slo_ttft
        # Multi-tenant SLO classes: when a heterogeneous class set is
        # attached, the slack guard and status report score every request
        # against ITS OWN class budget.  A single-class (or absent) set
        # keeps the scalar slo_tpot/slo_ttft code paths, bit-identically.
        self.slo_classes = slo_classes
        self._multi_slo = (slo_classes is not None
                           and not slo_classes.is_single)
        self.conservative_slack = conservative_slack  # EcoServe++ (min slack)
        # EcoServe-CP (beyond-paper): when decode slack is too thin for a
        # full prefill slot, ride `chunked_fallback` prefill tokens along
        # with the decode iteration (Sarathi-style chunk INSIDE PaDG) so
        # TTFT progresses without stalling decodes.  0 disables.
        self.chunked_fallback = chunked_fallback
        self._chunk_progress: dict = {}
        self._current_chunks: List = []

        self.pending: List[Request] = []      # admitted, waiting for prefill
        self.decoding: List[Request] = []
        self.phase = "idle"
        self.last_switch_time = 0.0
        self.busy_until = 0.0
        self._finished: List[Request] = []

        # ---- incremental aggregates (see module docstring) ------------- #
        # executors exposing ctx_clamp support the summed decode fast path
        self._ctx_clamp = int(getattr(executor, "ctx_clamp", 0) or 0)
        self._fast_ctx_sum = hasattr(executor, "ctx_clamp")
        self._pending_tokens = 0       # sum of prompt_len over pending
        self._decode_kv_sum = 0        # sum of r.kv_tokens() over decoding
        self._decode_eff_sum = 0       # same, clamped at _ctx_clamp
        self._version = 0              # bumped on any mutation
        self._status_cache = None      # ((now, slo, version), status)
        self._prefill_plan_cache = None  # (version, (batch, lens, dur, old))
        self._starve_deadline_cache = None  # (version, deadline) multi-SLO

    # ----------------------------------------------------------------- #
    # mutators: the ONLY legal way to change pending/decoding membership
    # ----------------------------------------------------------------- #
    def _touch(self) -> None:
        self._version += 1

    def _eff(self, kv: int) -> int:
        return min(kv, self._ctx_clamp) if self._ctx_clamp else kv

    def admit(self, req: Request, now: float) -> None:
        req.state = RequestState.PENDING
        req.admitted_time = now
        req.instance_id = self.iid
        self.pending.append(req)
        self._pending_tokens += req.prompt_len
        self._touch()

    def remove_pending(self, req: Request) -> None:
        self.pending.remove(req)
        self._pending_tokens -= req.prompt_len
        self._touch()

    def add_decoding(self, req: Request) -> None:
        kv = req.kv_tokens()
        self.decoding.append(req)
        self._decode_kv_sum += kv
        self._decode_eff_sum += self._eff(kv)
        self._touch()

    def remove_decoding(self, req: Request) -> None:
        kv = req.kv_tokens()
        self.decoding.remove(req)
        self._decode_kv_sum -= kv
        self._decode_eff_sum -= self._eff(kv)
        self._touch()

    def _gen_token(self, req: Request) -> None:
        """One decode token for a request currently in ``decoding``."""
        req.tokens_generated += 1
        self._decode_kv_sum += 1
        if not self._ctx_clamp or req.kv_tokens() <= self._ctx_clamp:
            self._decode_eff_sum += 1

    def sync_tokens(self, req: Request, tokens_generated: int) -> None:
        """Externally set ``req.tokens_generated`` (req must be in
        ``decoding``), keeping the running aggregates consistent — used by
        the real-exec server whose engine advances counts out-of-band."""
        old_kv = req.kv_tokens()
        req.tokens_generated = tokens_generated
        new_kv = req.kv_tokens()
        if new_kv != old_kv:
            self._decode_kv_sum += new_kv - old_kv
            self._decode_eff_sum += self._eff(new_kv) - self._eff(old_kv)
            self._touch()

    def handoff_prefilled(self, reqs: List[Request], t_end: float) -> None:
        """FuDG prefill-only instance: mark first token and release the
        batch for transfer to a decode instance."""
        for r in reqs:
            self.remove_pending(r)
            r.first_token_time = t_end
            r.tokens_generated = 1

    def set_executor(self, executor: ExecutorModel) -> None:
        """Swap the executor in place (straggler-slowdown wrapper,
        repro.faults), re-deriving the fast-path markers and invalidating
        every duration cache.  The incremental aggregates are
        executor-independent, so membership state carries over."""
        self.executor = executor
        new_clamp = int(getattr(executor, "ctx_clamp", 0) or 0)
        if new_clamp != self._ctx_clamp:
            # the clamped decode-context sum depends on the clamp value
            self._ctx_clamp = new_clamp
            self._decode_eff_sum = sum(
                self._eff(r.kv_tokens()) for r in self.decoding)
        self._fast_ctx_sum = hasattr(executor, "ctx_clamp")
        self._touch()

    def kv_tokens_used(self) -> int:
        return self._decode_kv_sum + self._pending_tokens

    @property
    def pending_tokens(self) -> int:
        """Total prompt tokens awaiting prefill (O(1))."""
        return self._pending_tokens

    def audit_aggregates(self) -> dict:
        """(incremental, recomputed-from-scratch) pairs — test hook for
        the accounting invariants."""
        eff = (lambda kv: min(kv, self._ctx_clamp)) if self._ctx_clamp \
            else (lambda kv: kv)
        return {
            "pending_tokens": (
                self._pending_tokens,
                sum(r.prompt_len for r in self.pending)),
            "decode_kv_sum": (
                self._decode_kv_sum,
                sum(r.kv_tokens() for r in self.decoding)),
            "decode_eff_sum": (
                self._decode_eff_sum,
                sum(eff(r.kv_tokens()) for r in self.decoding)),
        }

    # ----------------------------------------------------------------- #
    def status(self, now: float, slo_tpot: float) -> InstanceStatus:
        # memoized per (now, slo, version): Algorithm 1 probes every
        # instance for every queued request at each slot boundary, and
        # every mutator bumps _version — stale entries are impossible.
        # In multi-SLO mode _status ignores the scalar slo_tpot (each
        # decode uses its own class budget), so the key normalizes it —
        # interleaved-class dispatch must not thrash the one-entry cache
        key = (now, None if self._multi_slo else slo_tpot, self._version)
        cached = self._status_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        st = self._status(now, slo_tpot)
        self._status_cache = (key, st)
        return st

    def _status(self, now: float, slo_tpot: float) -> InstanceStatus:
        n_next = min(len(self.decoding) + 1, self.max_decode_batch)
        if self._fast_ctx_sum and n_next - 1 == len(self.decoding):
            dit = self.executor.decode_time(
                n_next, ctx_sum=self._decode_eff_sum + self._eff(512))
        else:
            ctxs = [r.kv_tokens() for r in self.decoding][: n_next - 1]
            dit = self.executor.decode_time(n_next, ctxs + [512])
        if self._multi_slo:
            # each decode's slack accrues against its OWN class's TPOT
            classes = self.slo_classes
            tpots = [classes.for_request(r).tpot for r in self.decoding]
            saved = [r.saved_tpot(now, t)
                     for r, t in zip(self.decoding, tpots)]
            floor = min(tpots) if tpots else float("inf")
        else:
            saved = [r.saved_tpot(now, slo_tpot) for r in self.decoding]
            floor = slo_tpot if slo_tpot is not None else float("inf")
        return InstanceStatus(
            iid=self.iid,
            phase=self.phase,
            pending_prefill_lens=[r.prompt_len for r in self.pending],
            pending_prefill_tokens=self._pending_tokens,
            num_decoding=len(self.decoding),
            saved_tpots=saved,
            kv_tokens_used=self.kv_tokens_used(),
            kv_tokens_capacity=self.kv_capacity_tokens,
            last_switch_time=self.last_switch_time,
            decode_iter_time_plus_one=dit,
            decode_tpot_floor=floor,
        )

    # ----------------------------------------------------------------- #
    def _decode_iter_time(self, batch: List[Request]) -> float:
        """Duration of one decode iteration over ``batch``: the O(1)
        ctx-sum fast path when the executor supports it and the batch is
        the whole decode set, else the per-request list path."""
        if self._fast_ctx_sum and len(batch) == len(self.decoding):
            return self.executor.decode_time(
                len(batch), ctx_sum=self._decode_eff_sum)
        return self.executor.decode_time(
            len(batch), [r.kv_tokens() for r in batch])

    def _hybrid_iter_time(self, chunk_lens: List[int],
                          prefix_lens: List[int],
                          batch: List[Request]) -> float:
        """Duration of one fused decode+chunk iteration (same fast-path
        rule as ``_decode_iter_time``)."""
        if self._fast_ctx_sum and len(batch) == len(self.decoding):
            return self.executor.hybrid_time(
                chunk_lens, prefix_lens, len(batch),
                decode_ctx_sum=self._decode_eff_sum)
        return self.executor.hybrid_time(
            chunk_lens, prefix_lens, len(batch),
            [r.kv_tokens() for r in batch])

    # ----------------------------------------------------------------- #
    def _prefill_plan(self) -> Tuple[List[Request], List[int], float, float]:
        """The actual next prefill batch (respecting max_prefill_tokens
        and chunk progress), its duration, and the oldest pending arrival
        — computed once per mutation and reused by both the slack guard
        and ``next_slot``."""
        cached = self._prefill_plan_cache
        if cached is not None and cached[0] == self._version:
            return cached[1]
        batch: List[Request] = []
        lens: List[int] = []
        tokens = 0
        # physical decode slots still free (None = unconstrained; the
        # plan may then legitimately be empty when every slot is decoding)
        limit = None if self.max_prefill_batch is None else max(
            0, self.max_prefill_batch - len(self.decoding))
        for r in self.pending:
            if limit is not None and len(batch) >= limit:
                break
            remaining = r.prompt_len - self._chunk_progress.get(r.rid, 0)
            if batch and tokens + remaining > self.max_prefill_tokens:
                break
            batch.append(r)
            lens.append(remaining)
            tokens += remaining
        dur = self.executor.prefill_time(lens) if lens else 0.0
        oldest = min(r.arrival_time for r in self.pending) \
            if self.pending else 0.0
        plan = (batch, lens, dur, oldest)
        self._prefill_plan_cache = (self._version, plan)
        return plan

    def next_slot(self, now: float) -> Tuple[str, float, List[Request]]:
        """Decide and 'execute' the next slot starting at ``now``.

        Returns (kind, duration, affected requests).  kind == "idle" means
        nothing to do.  The caller (event engine) applies completion at
        now + duration via ``complete_slot``.
        """
        if self.pending and self._slack_allows_prefill(now):
            batch, _, dur, _ = self._prefill_plan()
            # an empty plan (every physical slot busy decoding under
            # ``max_prefill_batch``) falls through to a decode iteration
            if batch:
                if self.phase != "prefill":
                    self.phase = "prefill"
                    self.last_switch_time = now
                return "prefill", dur, batch
        if self.decoding:
            batch = self.decoding[: self.max_decode_batch]
            if self.pending and self.chunked_fallback:
                # EcoServe-CP: hybrid iteration (decode + prefill chunk)
                chunks = []
                budget = self.chunked_fallback
                for r in self.pending:
                    if budget <= 0:
                        break
                    done = self._chunk_progress.get(r.rid, 0)
                    take = min(budget, r.prompt_len - done)
                    if take > 0:
                        chunks.append((r, take, done))
                        budget -= take
                dur = self._hybrid_iter_time(
                    [c[1] for c in chunks], [c[2] for c in chunks], batch)
                self._current_chunks = chunks
                self.phase = "hybrid"
                return "hybrid", dur, batch
            dur = self._decode_iter_time(batch)
            if self.phase != "decode":
                self.phase = "decode"
                self.last_switch_time = now
            return "decode", dur, batch
        self.phase = "idle"
        return "idle", 0.0, []

    def _slack_allows_prefill(self, now: float) -> bool:
        """§3.1: execute decodes until enough TPOT slack has accumulated to
        absorb the pending prefill slot without violating running decodes.
        Costs the *actual* next prefill batch (what ``next_slot`` would
        run), cached until the pending set changes."""
        if self.slo_tpot is None or not self.decoding:
            return True
        if self._multi_slo:
            return self._slack_allows_prefill_per_class(now)
        _, _, dur, oldest = self._prefill_plan()
        # anti-starvation: a pending prefill nearing its TTFT budget wins
        if self.slo_ttft is not None:
            if now - oldest + dur > 0.6 * self.slo_ttft:
                return True
        saved = [r.saved_tpot(now, self.slo_tpot) for r in self.decoding]
        slack = min(saved) if self.conservative_slack else (
            sum(saved) / len(saved))
        return slack >= dur

    def _starvation_deadline(self) -> float:
        """Earliest anti-starvation deadline over the pending set:
        min(arrival + 0.6 * own-class TTFT).  Depends only on pending
        membership, so it is cached per mutation version like the
        prefill plan — the per-class guard stays O(1) per probe instead
        of rescanning the queue at every slot decision."""
        cached = self._starve_deadline_cache
        if cached is not None and cached[0] == self._version:
            return cached[1]
        classes = self.slo_classes
        deadline = min(
            (r.arrival_time + 0.6 * classes.for_request(r).ttft
             for r in self.pending), default=float("inf"))
        self._starve_deadline_cache = (self._version, deadline)
        return deadline

    def _slack_allows_prefill_per_class(self, now: float) -> bool:
        """Multi-tenant form of the guard: the anti-starvation check uses
        each pending request's OWN TTFT budget (a tight-class prefill can
        force the switch while a lax-class one keeps waiting), and decode
        slack accrues against each decode's OWN TPOT budget."""
        classes = self.slo_classes
        _, _, dur, _ = self._prefill_plan()
        # some pending prefill past 60% of its own TTFT budget wins
        if now + dur > self._starvation_deadline():
            return True
        saved = [r.saved_tpot(now, classes.for_request(r).tpot)
                 for r in self.decoding]
        slack = min(saved) if self.conservative_slack else (
            sum(saved) / len(saved))
        return slack >= dur

    def complete_slot(self, kind: str, reqs: List[Request],
                      t_end: float) -> List[Request]:
        """Apply slot completion; returns requests finished in this slot."""
        finished: List[Request] = []
        if kind == "prefill":
            for r in reqs:
                self.remove_pending(r)
                self._chunk_progress.pop(r.rid, None)
                r.first_token_time = t_end
                r.tokens_generated = 1
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = t_end
                    finished.append(r)
                else:
                    r.state = RequestState.DECODING
                    self.add_decoding(r)
        elif kind in ("decode", "hybrid"):
            for r in reqs:
                self._gen_token(r)
                if r.tokens_generated == 2:
                    r.second_token_time = t_end
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = t_end
                    self.remove_decoding(r)
                    finished.append(r)
            self._touch()   # decode token counts changed
            if kind == "hybrid":
                for r, take, done in self._current_chunks:
                    new_done = done + take
                    self._chunk_progress[r.rid] = new_done
                    self._touch()   # chunk progress feeds _prefill_plan
                    if new_done >= r.prompt_len:
                        self.remove_pending(r)
                        del self._chunk_progress[r.rid]
                        r.first_token_time = t_end
                        r.tokens_generated = 1
                        if r.tokens_generated >= r.output_len:
                            r.state = RequestState.FINISHED
                            r.finish_time = t_end
                            finished.append(r)
                        else:
                            r.state = RequestState.DECODING
                            self.add_decoding(r)
                self._current_chunks = []
        self._finished.extend(finished)
        return finished

    # ----------------------------------------------------------------- #
    @property
    def busy(self) -> bool:
        return bool(self.pending or self.decoding)
