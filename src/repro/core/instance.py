"""Instance: one model replica with temporal prefill/decode disaggregation.

This is the paper's *instance scheduler* (Fig. 5 step 5).  The instance is
execution-backend agnostic: durations come from an ``ExecutorModel``
(analytical cost model in the simulator; measured wall-clock in the
real-exec engine).  Scheduling policy (PaDG intra-instance rule):

  * prefills are prioritized — whenever admitted prefills are pending,
    the next slot is a prefill batch;
  * otherwise run one decode iteration over the running batch;
  * each slot is an uninterruptible unit of work (phase switches happen
    only at slot boundaries, which is what makes the disaggregation
    *temporal*).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Protocol, Tuple

from repro.core.request import Request, RequestState


class ExecutorModel(Protocol):
    def prefill_time(self, prompt_lens: List[int]) -> float: ...
    def decode_time(self, batch_size: int, ctx_lens: List[int]) -> float: ...
    # optional (EcoServe-CP): fused decode+chunk iteration
    # def hybrid_time(self, chunk_lens, prefix_lens, batch, ctxs): ...


@dataclasses.dataclass
class InstanceStatus:
    """What the instance periodically reports to its macro-instance
    scheduler (decode progress, memory, phase)."""
    iid: int
    phase: str                       # prefill | decode | idle
    pending_prefill_lens: List[int]
    pending_prefill_tokens: int
    num_decoding: int
    saved_tpots: List[float]
    kv_tokens_used: int
    kv_tokens_capacity: int
    last_switch_time: float
    # projected decode iteration time if one more request joins the batch
    # (guards TPOT against unbounded decode-batch growth)
    decode_iter_time_plus_one: float = 0.0

    @property
    def kv_tokens_free(self) -> int:
        return self.kv_tokens_capacity - self.kv_tokens_used


class Instance:
    """Simulation-state instance; also the scheduling brain reused by the
    real-exec engine (which overrides the executor with measured times)."""

    def __init__(self, iid: int, executor: ExecutorModel,
                 kv_capacity_tokens: int,
                 max_prefill_tokens: int = 16_384,
                 max_decode_batch: int = 256,
                 slo_tpot: Optional[float] = None,
                 slo_ttft: Optional[float] = None,
                 conservative_slack: bool = False,
                 chunked_fallback: int = 0):
        self.iid = iid
        self.executor = executor
        self.kv_capacity_tokens = kv_capacity_tokens
        self.max_prefill_tokens = max_prefill_tokens
        self.max_decode_batch = max_decode_batch
        # PaDG intra-instance rule (§3.1): with a TPOT SLO known, the
        # instance keeps decoding until its decodes have accumulated
        # enough slack to absorb the pending prefill slot.  None disables
        # the guard (NoDG baselines are strictly prefill-prioritized).
        self.slo_tpot = slo_tpot
        self.slo_ttft = slo_ttft
        self.conservative_slack = conservative_slack  # EcoServe++ (min slack)
        # EcoServe-CP (beyond-paper): when decode slack is too thin for a
        # full prefill slot, ride `chunked_fallback` prefill tokens along
        # with the decode iteration (Sarathi-style chunk INSIDE PaDG) so
        # TTFT progresses without stalling decodes.  0 disables.
        self.chunked_fallback = chunked_fallback
        self._chunk_progress: dict = {}
        self._current_chunks: List = []

        self.pending: List[Request] = []      # admitted, waiting for prefill
        self.decoding: List[Request] = []
        self.phase = "idle"
        self.last_switch_time = 0.0
        self.busy_until = 0.0
        self._finished: List[Request] = []

    # ----------------------------------------------------------------- #
    def admit(self, req: Request, now: float) -> None:
        req.state = RequestState.PENDING
        req.admitted_time = now
        req.instance_id = self.iid
        self.pending.append(req)

    def kv_tokens_used(self) -> int:
        used = sum(r.kv_tokens() for r in self.decoding)
        used += sum(r.prompt_len for r in self.pending)
        return used

    def status(self, now: float, slo_tpot: float) -> InstanceStatus:
        # memoized per (now, slo): Algorithm 1 probes every instance for
        # every queued request at each slot boundary
        cached = getattr(self, "_status_cache", None)
        if cached is not None and cached[0] == (now, slo_tpot,
                                                len(self.pending),
                                                len(self.decoding)):
            return cached[1]
        st = self._status(now, slo_tpot)
        self._status_cache = ((now, slo_tpot, len(self.pending),
                               len(self.decoding)), st)
        return st

    def _status(self, now: float, slo_tpot: float) -> InstanceStatus:
        n_next = min(len(self.decoding) + 1, self.max_decode_batch)
        ctxs = [r.kv_tokens() for r in self.decoding][: n_next - 1]
        return InstanceStatus(
            iid=self.iid,
            phase=self.phase,
            pending_prefill_lens=[r.prompt_len for r in self.pending],
            pending_prefill_tokens=sum(r.prompt_len for r in self.pending),
            num_decoding=len(self.decoding),
            saved_tpots=[r.saved_tpot(now, slo_tpot) for r in self.decoding],
            kv_tokens_used=self.kv_tokens_used(),
            kv_tokens_capacity=self.kv_capacity_tokens,
            last_switch_time=self.last_switch_time,
            decode_iter_time_plus_one=self.executor.decode_time(
                n_next, ctxs + [512]),
        )

    # ----------------------------------------------------------------- #
    def next_slot(self, now: float) -> Tuple[str, float, List[Request]]:
        """Decide and 'execute' the next slot starting at ``now``.

        Returns (kind, duration, affected requests).  kind == "idle" means
        nothing to do.  The caller (event engine) applies completion at
        now + duration via ``complete_slot``.
        """
        if self.pending and self._slack_allows_prefill(now):
            batch: List[Request] = []
            tokens = 0
            for r in self.pending:
                remaining = r.prompt_len - self._chunk_progress.get(r.rid, 0)
                if batch and tokens + remaining > self.max_prefill_tokens:
                    break
                batch.append(r)
                tokens += remaining
            dur = self.executor.prefill_time(
                [r.prompt_len - self._chunk_progress.get(r.rid, 0)
                 for r in batch])
            if self.phase != "prefill":
                self.phase = "prefill"
                self.last_switch_time = now
            return "prefill", dur, batch
        if self.decoding:
            batch = self.decoding[: self.max_decode_batch]
            if self.pending and self.chunked_fallback:
                # EcoServe-CP: hybrid iteration (decode + prefill chunk)
                chunks = []
                budget = self.chunked_fallback
                for r in self.pending:
                    if budget <= 0:
                        break
                    done = self._chunk_progress.get(r.rid, 0)
                    take = min(budget, r.prompt_len - done)
                    if take > 0:
                        chunks.append((r, take, done))
                        budget -= take
                dur = self.executor.hybrid_time(
                    [c[1] for c in chunks], [c[2] for c in chunks],
                    len(batch), [r.kv_tokens() for r in batch])
                self._current_chunks = chunks
                self.phase = "hybrid"
                return "hybrid", dur, batch
            dur = self.executor.decode_time(
                len(batch), [r.kv_tokens() for r in batch])
            if self.phase != "decode":
                self.phase = "decode"
                self.last_switch_time = now
            return "decode", dur, batch
        self.phase = "idle"
        return "idle", 0.0, []

    def _slack_allows_prefill(self, now: float) -> bool:
        """§3.1: execute decodes until enough TPOT slack has accumulated to
        absorb the pending prefill slot without violating running decodes."""
        if self.slo_tpot is None or not self.decoding:
            return True
        dur = self.executor.prefill_time([r.prompt_len for r in self.pending])
        # anti-starvation: a pending prefill nearing its TTFT budget wins
        if self.slo_ttft is not None:
            oldest = min(r.arrival_time for r in self.pending)
            if now - oldest + dur > 0.6 * self.slo_ttft:
                return True
        saved = [r.saved_tpot(now, self.slo_tpot) for r in self.decoding]
        slack = min(saved) if self.conservative_slack else (
            sum(saved) / len(saved))
        return slack >= dur

    def complete_slot(self, kind: str, reqs: List[Request],
                      t_end: float) -> List[Request]:
        """Apply slot completion; returns requests finished in this slot."""
        finished: List[Request] = []
        if kind == "prefill":
            for r in reqs:
                self.pending.remove(r)
                self._chunk_progress.pop(r.rid, None)
                r.first_token_time = t_end
                r.tokens_generated = 1
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = t_end
                    finished.append(r)
                else:
                    r.state = RequestState.DECODING
                    self.decoding.append(r)
        elif kind in ("decode", "hybrid"):
            for r in reqs:
                r.tokens_generated += 1
                if r.tokens_generated == 2:
                    r.second_token_time = t_end
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = t_end
                    self.decoding.remove(r)
                    finished.append(r)
            if kind == "hybrid":
                for r, take, done in self._current_chunks:
                    new_done = done + take
                    self._chunk_progress[r.rid] = new_done
                    if new_done >= r.prompt_len:
                        self.pending.remove(r)
                        del self._chunk_progress[r.rid]
                        r.first_token_time = t_end
                        r.tokens_generated = 1
                        if r.tokens_generated >= r.output_len:
                            r.state = RequestState.FINISHED
                            r.finish_time = t_end
                            finished.append(r)
                        else:
                            r.state = RequestState.DECODING
                            self.decoding.append(r)
                self._current_chunks = []
        self._finished.extend(finished)
        return finished

    # ----------------------------------------------------------------- #
    @property
    def busy(self) -> bool:
        return bool(self.pending or self.decoding)
