"""Macro instance: rolling activation + Algorithm 1 (inter-instance routing).

A macro instance is EcoServe's basic serving unit: N instances whose
prefill phases are staggered in time.  The scheduler routes each incoming
request *stickily* to the most recently used instance; when that instance
fails the constraint check, it cycles to the next one — this cyclic
hand-off IS the rolling activation (the paper's Fig. 5 step 2).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Union

from repro.core.constraints import check_constraints
from repro.core.instance import Instance
from repro.core.request import Request
from repro.core.slo import SLO, SLOClassSet, as_slo_class_set
from repro.obs.events import NULL_TRACER


class MacroInstance:
    # flight-recorder hook: rolling-activation rotations are the paper's
    # Fig. 5 step 2 — worth a timeline event each
    tracer = NULL_TRACER

    def __init__(self, mid: int, instances: List[Instance],
                 slo: Union[SLO, SLOClassSet],
                 predict_prefill: Callable[[int], float],
                 conservative: bool = False,
                 reachable: Optional[Callable[[int, float], bool]] = None):
        self.mid = mid
        self.instances: List[Instance] = list(instances)
        # scheduler-side health predicate (iid, now) -> bool; None means
        # an ideal coordination plane.  Under network faults the rolling
        # activation fails over past unreachable instances instead of
        # handing work to a black-holed one.
        self.reachable = reachable
        # accept a bare SLO (legacy single-tenant callers) or a class set;
        # routing always resolves the REQUEST's class (Algorithm 1 becomes
        # SLO-aware: constraints check against the request's own budgets)
        self.slo_set = as_slo_class_set(slo)
        self.slo = self.slo_set.default_slo
        self.predict_prefill = predict_prefill
        self.conservative = conservative       # EcoServe++ admission
        self._active_idx = 0      # sticky pointer (Algorithm 1 line 2)
        self.rejected = 0

    # ------------------------------------------------------------------ #
    def route(self, req: Request, now: float) -> Optional[Instance]:
        """Algorithm 1: try the instance that admitted the previous request;
        on constraint failure check the next instance, cyclically.  Returns
        the chosen instance (request admitted) or None if no instance can
        satisfy the constraints right now."""
        n = len(self.instances)
        if n == 0:
            return None
        slo = self.slo_set.for_request(req)
        for k in range(n):
            idx = (self._active_idx + k) % n
            inst = self.instances[idx]
            if (self.reachable is not None
                    and not self.reachable(inst.iid, now)):
                # fail over: the cycle skips the unreachable instance
                continue
            status = inst.status(now, slo.tpot)
            if check_constraints(status, req, slo,
                                 self.predict_prefill, now,
                                 conservative=self.conservative):
                if idx != self._active_idx:
                    trc = self.tracer
                    if trc.enabled:
                        trc.instance(now, inst.iid, "rotate")
                self._active_idx = idx
                inst.admit(req, now)
                return inst
        return None

    def route_forced(self, req: Request, now: float) -> Instance:
        """Admission of last resort (SLO already lost): pick the instance
        with the most free KV memory so the request still completes.
        Prefers reachable instances; with every one unreachable it still
        admits somewhere (the request would otherwise be dropped)."""
        pool = self.instances
        if self.reachable is not None:
            ok = [i for i in pool if self.reachable(i.iid, now)]
            if ok:
                pool = ok
        inst = max(pool,
                   key=lambda i: i.kv_capacity_tokens - i.kv_tokens_used())
        self.rejected += 1
        inst.admit(req, now)
        idx = self.instances.index(inst)
        if idx != self._active_idx:
            trc = self.tracer
            if trc.enabled:
                trc.instance(now, inst.iid, "rotate")
        self._active_idx = idx
        return inst

    # ------------------------------------------------------------------ #
    def add_instance(self, inst: Instance) -> None:
        self.instances.append(inst)

    def remove_instance(self) -> Optional[Instance]:
        """Remove (and return) the emptiest instance for migration/scaling;
        its in-flight requests stay on it until drained — the caller keeps
        stepping it but routes no new work (paper: migration is triggered
        during the decode phase and never interrupts execution)."""
        if not self.instances:
            return None
        inst = min(self.instances, key=lambda i: i.kv_tokens_used())
        self.instances.remove(inst)
        self._active_idx = 0 if not self.instances else (
            self._active_idx % len(self.instances))
        return inst

    def remove_specific(self, inst: Instance) -> bool:
        """Remove a named instance (fault teardown picks the victim, not
        the emptiest-first heuristic); returns False if absent."""
        if inst not in self.instances:
            return False
        self.instances.remove(inst)
        self._active_idx = 0 if not self.instances else (
            self._active_idx % len(self.instances))
        return True

    @property
    def size(self) -> int:
        return len(self.instances)

    def utilization(self, now: float) -> float:
        if not self.instances:
            return 0.0
        busy = sum(1 for i in self.instances if i.busy)
        return busy / len(self.instances)
