"""EcoServe: the PaDG serving system (paper's full stack over the engine).

Combines: temporal disaggregation (Instance), rolling activation +
Algorithm 1 (MacroInstance), Algorithm 2 (constraints), mitosis scaling
(OverallScheduler).  Expressed as a ``PolicySystemBase`` composition:
macro-least-utilized routing (Algorithm 1 over macro instances),
timeout-forced admission (the paper's "continuous stream" rule:
slack-guarded, force-admitted once a request has overstayed its own
class's TTFT budget), and a FIFO drain of the macro-level queue at every
slot boundary.  Swap the queue discipline to get e.g.
``"ecoserve+priority"`` without touching this file.
"""
from __future__ import annotations

from repro.core.instance import Instance
from repro.core.mitosis import OverallScheduler, register_instance
from repro.core.policies import TimeoutForcedAdmission
from repro.core.system import PolicySystemBase
from repro.simulator.cost_model import InstanceCostModel


class EcoServeSystem(PolicySystemBase):
    base_name = "ecoserve"
    default_queue = "fifo"
    default_admission = "timeout-forced:4"
    default_routing = "macro-least-utilized"

    def __init__(self, cost: InstanceCostModel, n_instances: int, slo,
                 n_lower: int = 4, n_upper: int = 16,
                 queue_timeout_factor: float = 4.0,
                 plus_plus: bool = False,
                 chunked_fallback: int = 0,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, instance_kwargs=None, iid_base: int = 0):
        """``slo`` is a bare ``SLO`` or a multi-tenant ``SLOClassSet``;
        with a class set, admission/routing/slack all run against each
        request's own class budgets (single-class sets are bit-identical
        to the scalar path).

        ``plus_plus`` enables the beyond-paper EcoServe++ admission:
        min-slack (instead of mean-slack) in Constraint 2 and in the
        intra-instance switch guard — protects young decodes.

        ``chunked_fallback`` > 0 enables EcoServe-CP (beyond-paper):
        when slack is too thin for a full prefill slot, that many prefill
        tokens ride along with each decode iteration."""
        self.plus_plus = plus_plus
        self.chunked_fallback = chunked_fallback
        self.n_lower = n_lower
        self.n_upper = n_upper
        self.queue_timeout_factor = queue_timeout_factor
        # extra Instance(...) kwargs (e.g. max_decode_batch /
        # max_prefill_batch for engine-backed conformance runs); must be
        # set before super().__init__ because _build() runs inside it
        self.instance_kwargs = dict(instance_kwargs or {})
        if admission is None:
            admission = TimeoutForcedAdmission(queue_timeout_factor)
        super().__init__(cost, n_instances, slo,
                         queue_discipline=queue_discipline,
                         admission=admission, routing=routing,
                         failure=failure, iid_base=iid_base)

    def _build(self, n_instances: int) -> None:
        self.sched = OverallScheduler(
            self.slo_set, self.cost.predict_prefill, n_lower=self.n_lower,
            n_upper=self.n_upper, conservative=self.plus_plus,
            reachable=self.transport.instance_reachable)
        for i in range(n_instances):
            inst = self._make_instance(self.iid_base + i)
            self.instances.append(inst)
            self.sched.add_instance(inst)

    def _make_instance(self, iid: int) -> Instance:
        inst = Instance(
            iid, self.cost, kv_capacity_tokens=self.cost.kv_capacity_tokens(),
            slo_tpot=self.slo.tpot, slo_ttft=self.slo.ttft,
            conservative_slack=self.plus_plus,
            chunked_fallback=self.chunked_fallback,
            slo_classes=self.slo_set, **self.instance_kwargs)
        register_instance(inst)
        return inst
