"""EcoServe: the PaDG serving system (paper's full stack over the engine).

Combines: temporal disaggregation (Instance), rolling activation +
Algorithm 1 (MacroInstance), Algorithm 2 (constraints), mitosis scaling
(OverallScheduler).  Unadmitted requests wait in a macro-level queue and
are retried at every slot boundary — the paper's "continuous stream"
admission.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

from repro.core.instance import Instance
from repro.core.macro import MacroInstance
from repro.core.mitosis import OverallScheduler, register_instance
from repro.core.request import Request
from repro.core.slo import SLO, as_slo_class_set
from repro.simulator.cost_model import InstanceCostModel
from repro.simulator.engine import SimulationEngine


class EcoServeSystem:
    def __init__(self, cost: InstanceCostModel, n_instances: int, slo,
                 n_lower: int = 4, n_upper: int = 16,
                 queue_timeout_factor: float = 4.0,
                 plus_plus: bool = False,
                 chunked_fallback: int = 0):
        """``slo`` is a bare ``SLO`` or a multi-tenant ``SLOClassSet``;
        with a class set, admission/routing/slack all run against each
        request's own class budgets (single-class sets are bit-identical
        to the scalar path).

        ``plus_plus`` enables the beyond-paper EcoServe++ admission:
        min-slack (instead of mean-slack) in Constraint 2 and in the
        intra-instance switch guard — protects young decodes.

        ``chunked_fallback`` > 0 enables EcoServe-CP (beyond-paper):
        when slack is too thin for a full prefill slot, that many prefill
        tokens ride along with each decode iteration."""
        self.cost = cost
        self.slo_set = as_slo_class_set(slo)
        self.slo: SLO = self.slo_set.default_slo
        self.plus_plus = plus_plus
        self.chunked_fallback = chunked_fallback
        self.sched = OverallScheduler(
            self.slo_set, cost.predict_prefill, n_lower=n_lower,
            n_upper=n_upper, conservative=plus_plus)
        self.instances: List[Instance] = []
        for i in range(n_instances):
            inst = self._make_instance(i)
            self.instances.append(inst)
            self.sched.add_instance(inst)
        self.queue: Deque[Request] = deque()
        self.queue_timeout_factor = queue_timeout_factor
        self._next_iid = n_instances

    def _make_instance(self, iid: int) -> Instance:
        inst = Instance(
            iid, self.cost, kv_capacity_tokens=self.cost.kv_capacity_tokens(),
            slo_tpot=self.slo.tpot, slo_ttft=self.slo.ttft,
            conservative_slack=self.plus_plus,
            chunked_fallback=self.chunked_fallback,
            slo_classes=self.slo_set)
        register_instance(inst)
        return inst

    # ---------------- engine hooks ------------------------------------- #
    def submit(self, req: Request, now: float,
               engine: SimulationEngine) -> None:
        inst = self._try_admit(req, now)
        if inst is not None:
            engine.activate(inst)
        else:
            self.queue.append(req)

    def on_slot_end(self, inst, kind, reqs, now, engine) -> None:
        # retry queued admissions: instance states just changed
        self._drain_queue(now, engine)

    # ---------------- admission ----------------------------------------- #
    def _try_admit(self, req: Request, now: float) -> Optional[Instance]:
        for m in sorted(self.sched.macros,
                        key=lambda m: m.utilization(now)):
            inst = m.route(req, now)
            if inst is not None:
                return inst
        # SLO unreachable for this request: admit anyway once it has
        # waited too long against ITS OWN class's TTFT budget (completes,
        # counted as violation)
        ttft = self.slo_set.for_request(req).ttft
        if now - req.arrival_time > self.queue_timeout_factor * ttft:
            return self.sched.macros[0].route_forced(req, now)
        return None

    def _drain_queue(self, now: float, engine: SimulationEngine,
                     max_tries: int = 64) -> None:
        """Retry queued admissions FIFO; bounded per call so an overload
        backlog cannot make every slot boundary O(queue)."""
        tries = 0
        fails = 0
        still: Deque[Request] = deque()
        while self.queue and tries < max_tries and fails < 4:
            req = self.queue.popleft()
            tries += 1
            inst = self._try_admit(req, now)
            if inst is not None:
                engine.activate(inst)
                fails = 0
            else:
                still.append(req)
                fails += 1
        still.extend(self.queue)
        self.queue = still

    # ---------------- mitosis hooks (dynamic scaling bench) ------------- #
    def scale_up(self, engine: SimulationEngine) -> Instance:
        inst = self._make_instance(self._next_iid)
        self._next_iid += 1
        self.instances.append(inst)
        self.sched.add_instance(inst)
        return inst

    def scale_down(self) -> Optional[Instance]:
        inst = self.sched.remove_instance()
        if inst is not None and inst in self.instances:
            self.instances.remove(inst)
        return inst
