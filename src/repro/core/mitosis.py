"""Mitosis scaling (paper §3.5) + the serializable InstanceHandler proxy.

Expansion: instances are added to a macro instance until its size exceeds
``N_u``; then a new macro instance of ``N_l`` instances splits off
(Fig. 7 step 2).  Further instances go to the original until it is full
again, then to the new one.

Contraction: instances are removed from the smallest macro instance until
it reaches ``N_l``; then from a full one; when the two smallest macro
instances together hold ``N_u`` instances, they merge after one more
removal (Fig. 7 steps 5-8).

Migration between macro instances moves an ``InstanceHandler`` — a
pickle-serializable proxy (actor id, worker address, callable registry
reference) — NOT the instance process itself: the instance keeps executing
through the move (<100 ms in the paper; a pickle round-trip here).
"""
from __future__ import annotations

import dataclasses
import pickle
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.instance import Instance
from repro.core.macro import MacroInstance
from repro.core.request import Request
from repro.core.slo import SLO, SLOClassSet, as_slo_class_set
from repro.obs.events import NULL_TRACER

# process-local registry standing in for the RPC actor table: handlers
# resolve their instance through it after deserialization, which is what
# makes migration purely *logical* (no re-initialization).
_ACTOR_REGISTRY: Dict[int, Instance] = {}


def register_instance(inst: Instance) -> None:
    _ACTOR_REGISTRY[inst.iid] = inst


def unregister_instance(inst: Instance) -> None:
    """Inverse of ``register_instance``: contraction, merge cleanup, and
    fault teardown must drop the actor-table entry, or the registry grows
    without bound and stale handlers silently resolve dead instances."""
    _ACTOR_REGISTRY.pop(inst.iid, None)


def registry_size() -> int:
    """Test/diagnostic hook: current actor-table population."""
    return len(_ACTOR_REGISTRY)


class StaleHandlerError(LookupError):
    """An ``InstanceHandler`` pointed at an actor that is no longer
    registered (retired by contraction or torn down by a fault)."""


@dataclasses.dataclass
class InstanceHandler:
    """Serializable proxy for an instance (paper §3.5.2)."""
    actor_id: int
    worker_address: str
    capabilities: Dict[str, Any]

    def resolve(self) -> Instance:
        inst = _ACTOR_REGISTRY.get(self.actor_id)
        if inst is None:
            raise StaleHandlerError(
                f"actor {self.actor_id} is not registered (instance "
                "retired or lost); the handler is stale")
        if not getattr(inst, "alive", True):
            raise StaleHandlerError(
                f"actor {self.actor_id} resolved to a dead instance "
                "(crashed or preempted); the handler is stale")
        return inst

    def serialize(self) -> bytes:
        return pickle.dumps(self)

    @staticmethod
    def deserialize(blob: bytes) -> "InstanceHandler":
        return pickle.loads(blob)

    @staticmethod
    def for_instance(inst: Instance, address: str = "local:0",
                     **caps: Any) -> "InstanceHandler":
        register_instance(inst)
        return InstanceHandler(actor_id=inst.iid, worker_address=address,
                               capabilities=dict(caps))


@dataclasses.dataclass
class MigrationRecord:
    src_macro: int
    dst_macro: int
    actor_id: int
    seconds: float


class OverallScheduler:
    """Top-level scheduler: dispatches to macro instances and runs the
    mitosis expansion/contraction state machine."""

    # flight-recorder hook; ``new_macro`` propagates it to every macro
    # instance so rotations minted after attachment are captured too
    tracer = NULL_TRACER

    def __init__(self, slo, predict_prefill: Callable[[int], float],
                 n_lower: int = 4, n_upper: int = 16,
                 conservative: bool = False, reachable=None):
        """``slo`` is a bare ``SLO`` or a multi-tenant ``SLOClassSet``;
        dispatch hands the class set down to every macro instance so each
        request is admitted against its own class budgets.  ``reachable``
        is the transport's (iid, now) -> bool health view; macro routing
        fails over around unreachable instances under network faults."""
        assert 1 <= n_lower <= n_upper
        self.slo_set: SLOClassSet = as_slo_class_set(slo)
        self.slo: SLO = self.slo_set.default_slo
        self.predict_prefill = predict_prefill
        self.n_lower = n_lower
        self.n_upper = n_upper
        self.conservative = conservative
        self.reachable = reachable
        self.macros: List[MacroInstance] = []
        self._next_mid = 0
        self.migrations: List[MigrationRecord] = []

    # ---------------- dispatch ---------------------------------------- #
    def dispatch(self, req: Request, now: float) -> Instance:
        """Route to macro instances (least-loaded first); fall back to
        forced admission on the emptiest one."""
        order = sorted(self.macros, key=lambda m: m.utilization(now))
        for m in order:
            inst = m.route(req, now)
            if inst is not None:
                return inst
        return order[0].route_forced(req, now)

    # ---------------- expansion --------------------------------------- #
    def new_macro(self, instances: List[Instance]) -> MacroInstance:
        m = MacroInstance(self._next_mid, instances, self.slo_set,
                          self.predict_prefill,
                          conservative=self.conservative,
                          reachable=self.reachable)
        self._next_mid += 1
        self.macros.append(m)
        if self.tracer.enabled:
            m.tracer = self.tracer
        return m

    def add_instance(self, inst: Instance) -> MacroInstance:
        """Mitosis expansion: fill the largest non-full macro instance;
        split when it would exceed N_u."""
        register_instance(inst)
        if not self.macros:
            return self.new_macro([inst])
        candidates = [m for m in self.macros if m.size < self.n_upper]
        if candidates:
            # fill the fullest non-full macro first (Fig. 7 steps 1 & 3)
            target = max(candidates, key=lambda m: m.size)
            target.add_instance(inst)
            return target
        # all full -> split: N_l instances seed a new macro (step 2)
        target = max(self.macros, key=lambda m: m.size)
        seeds = [target.remove_instance() for _ in range(self.n_lower - 1)]
        seeds = [s for s in seeds if s is not None] + [inst]
        new = self.new_macro(seeds)
        trc = self.tracer
        if trc.enabled:
            trc.instance(trc.now(), inst.iid, "split")
        for s in seeds[:-1]:
            self._record_migration(target.mid, new.mid, s)
        return new

    # ---------------- contraction -------------------------------------- #
    def remove_instance(self) -> Optional[Instance]:
        """Mitosis contraction: shrink the smallest macro down to N_l, then
        shrink a full one; merge the two smallest when they jointly hold
        N_u (Fig. 7 steps 5-8)."""
        if not self.macros:
            return None
        smallest = min(self.macros, key=lambda m: m.size)
        if smallest.size > self.n_lower or len(self.macros) == 1:
            victim = smallest
        else:
            victim = max(self.macros, key=lambda m: m.size)
        inst = victim.remove_instance()
        if victim.size == 0:
            self.macros.remove(victim)
        self._maybe_merge()
        if inst is not None:
            # the retired instance drains outside the pool; its actor
            # entry goes with it so stale handlers fail loudly
            unregister_instance(inst)
        return inst

    def discard_instance(self, inst: Instance) -> bool:
        """Remove a *specific* instance (fault teardown: crash or spot
        preemption picked the victim, not the contraction heuristic).
        Returns False when the instance is not in any macro."""
        for m in self.macros:
            if m.remove_specific(inst):
                if m.size == 0:
                    self.macros.remove(m)
                self._maybe_merge()
                unregister_instance(inst)
                return True
        return False

    def _maybe_merge(self) -> None:
        if len(self.macros) < 2:
            return
        by_size = sorted(self.macros, key=lambda m: m.size)
        a, b = by_size[0], by_size[1]
        if a.size + b.size <= self.n_upper:
            trc = self.tracer
            if trc.enabled:
                trc.instance(trc.now(), a.mid, "merge")
            # merge a into b via handler migration
            while a.size:
                inst = a.remove_instance()
                if inst is None:
                    break
                self._record_migration(a.mid, b.mid, inst)
                b.add_instance(inst)
            self.macros.remove(a)

    # ---------------- handler migration -------------------------------- #
    def _record_migration(self, src: int, dst: int, inst: Instance) -> None:
        t0 = time.perf_counter()
        handler = InstanceHandler.for_instance(inst)
        blob = handler.serialize()                 # leaves src scheduler
        restored = InstanceHandler.deserialize(blob)   # arrives at dst
        resolved = restored.resolve()
        assert resolved is inst                    # logical migration only
        dt = time.perf_counter() - t0
        self.migrations.append(
            MigrationRecord(src_macro=src, dst_macro=dst,
                            actor_id=inst.iid, seconds=dt))

    # ---------------- views -------------------------------------------- #
    @property
    def total_instances(self) -> int:
        return sum(m.size for m in self.macros)

    def sizes(self) -> List[int]:
        return sorted(m.size for m in self.macros)
