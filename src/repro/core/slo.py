"""Service-level objectives and attainment metrics (paper Table 4)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float      # seconds
    tpot: float      # seconds per output token


# Table 4: SLOs depend only on the application, not the model size.
DATASET_SLOS: Dict[str, SLO] = {
    "alpaca": SLO(ttft=1.0, tpot=0.100),
    "sharegpt": SLO(ttft=5.0, tpot=0.100),
    "longbench": SLO(ttft=15.0, tpot=0.100),
}


def request_meets_slo(req: Request, slo: SLO) -> bool:
    if req.ttft is None or req.ttft > slo.ttft:
        return False
    if req.tokens_generated > 1:
        return req.avg_tpot is not None and req.avg_tpot <= slo.tpot
    return True


def attainment(reqs: Iterable[Request], slo: SLO) -> float:
    done = [r for r in reqs if r.finish_time is not None]
    if not done:
        return 0.0
    ok = sum(1 for r in done if request_meets_slo(r, slo))
    return ok / len(done)


def percentile_latencies(reqs: List[Request]) -> Dict[str, float]:
    import numpy as np
    done = [r for r in reqs if r.finish_time is not None]
    out: Dict[str, float] = {"n": float(len(done))}
    if not done:
        return out
    ttfts = np.array([r.ttft for r in done])
    tpots = np.array([r.avg_tpot for r in done if r.avg_tpot is not None])
    for p in (50, 90, 99):
        out[f"ttft_p{p}"] = float(np.percentile(ttfts, p))
        if len(tpots):
            out[f"tpot_p{p}"] = float(np.percentile(tpots, p))
    return out
