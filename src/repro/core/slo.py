"""Service-level objectives and attainment metrics (paper Table 4).

Multi-tenant extension: production traffic mixes the paper's Table 4
workloads, each with its own TTFT budget ("Inference without
Interference").  ``SLOClassSet`` maps a request's ``slo_class`` tag to
its own ``SLO``; ``attainment_by_class`` scores each class against its
own budget so a DistServe-style goodput search can bisect on the
*min-over-classes* attainment instead of the aggregate (one starved
tenant caps the frontier).  A single-class set is behaviourally
identical to passing the bare ``SLO`` everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Tuple, Union

from repro.core.request import Request

DEFAULT_SLO_CLASS = "default"


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float      # seconds
    tpot: float      # seconds per output token


# Table 4: SLOs depend only on the application, not the model size.
DATASET_SLOS: Dict[str, SLO] = {
    "alpaca": SLO(ttft=1.0, tpot=0.100),
    "sharegpt": SLO(ttft=5.0, tpot=0.100),
    "longbench": SLO(ttft=15.0, tpot=0.100),
}


@dataclasses.dataclass(frozen=True)
class SLOClassSet:
    """Immutable ``slo_class`` tag -> ``SLO`` mapping.

    ``default`` names the class used for requests whose tag is unknown
    (legacy untagged traffic carries ``DEFAULT_SLO_CLASS``); it must be a
    key of ``classes``.
    """
    classes: Tuple[Tuple[str, SLO], ...]
    default: str

    def __post_init__(self):
        if not self.classes:
            raise ValueError("SLOClassSet needs at least one class")
        by_name = dict(self.classes)
        if self.default not in by_name:
            raise KeyError(f"default class {self.default!r} not among "
                           f"{sorted(by_name)}")
        # lookup cache (non-field: routing resolves a class per request)
        object.__setattr__(self, "_by_name", by_name)

    @staticmethod
    def make(classes: Mapping[str, SLO],
             default: str = None) -> "SLOClassSet":
        items = tuple(sorted(classes.items()))
        if default is None:
            default = (DEFAULT_SLO_CLASS if DEFAULT_SLO_CLASS in classes
                       else items[0][0])
        return SLOClassSet(items, default)

    @staticmethod
    def single(slo: SLO, name: str = DEFAULT_SLO_CLASS) -> "SLOClassSet":
        return SLOClassSet(((name, slo),), name)

    # ---- views -------------------------------------------------------- #
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.classes)

    @property
    def is_single(self) -> bool:
        return len(self.classes) == 1

    @property
    def default_slo(self) -> SLO:
        return self._by_name[self.default]

    def get(self, name: str) -> SLO:
        return self._by_name.get(name, self._by_name[self.default])

    def for_request(self, req: Request) -> SLO:
        return self.get(req.slo_class)

    # scalar shims: schedulers sized against "the" SLO (queue timeouts,
    # instance defaults) use the default class's budgets
    @property
    def ttft(self) -> float:
        return self.default_slo.ttft

    @property
    def tpot(self) -> float:
        return self.default_slo.tpot


def as_slo_class_set(slo: Union[SLO, SLOClassSet]) -> SLOClassSet:
    """Coerce a bare ``SLO`` (the pre-multi-tenant calling convention) to
    a single-class set; pass ``SLOClassSet`` through unchanged."""
    if isinstance(slo, SLOClassSet):
        return slo
    return SLOClassSet.single(slo)


def request_meets_slo(req: Request, slo: SLO) -> bool:
    if req.ttft is None or req.ttft > slo.ttft:
        return False
    if req.tokens_generated > 1:
        return req.avg_tpot is not None and req.avg_tpot <= slo.tpot
    return True


def attainment(reqs: Iterable[Request], slo: SLO) -> float:
    done = [r for r in reqs if r.finish_time is not None]
    if not done:
        return 0.0
    ok = sum(1 for r in done if request_meets_slo(r, slo))
    return ok / len(done)


def attainment_summary(reqs: Iterable[Request], classes: SLOClassSet
                       ) -> Tuple[float, Dict[str, float]]:
    """One scoring pass -> (aggregate, per-class grid).

    Every class in ``classes`` gets a grid key, scored only over that
    class's finished requests against that class's budget; a class with
    no finished requests reports 0.0 (matching the scalar ``attainment``
    convention for an empty set).  Requests tagged with an unknown class
    are scored under the default class.  The aggregate is the same
    every-request-against-its-own-budget ratio the per-class counts
    imply — one pass keeps the two views arithmetically inseparable."""
    buckets: Dict[str, List[Request]] = {n: [] for n in classes.names}
    for r in reqs:
        name = r.slo_class if r.slo_class in buckets else classes.default
        buckets[name].append(r)
    per: Dict[str, float] = {}
    ok_total = done_total = 0
    for name, rs in buckets.items():
        slo = classes.get(name)
        done = [r for r in rs if r.finish_time is not None]
        ok = sum(1 for r in done if request_meets_slo(r, slo))
        per[name] = ok / len(done) if done else 0.0
        ok_total += ok
        done_total += len(done)
    agg = ok_total / done_total if done_total else 0.0
    return agg, per


def attainment_mixed(reqs: Iterable[Request],
                     classes: SLOClassSet) -> float:
    """Aggregate attainment with every request scored against its OWN
    class budget.  Identical to ``attainment(reqs, slo)`` when
    ``classes`` holds a single class equal to ``slo``."""
    return attainment_summary(reqs, classes)[0]


def attainment_by_class(reqs: Iterable[Request],
                        classes: SLOClassSet) -> Dict[str, float]:
    """Per-class attainment grid (see ``attainment_summary``)."""
    return attainment_summary(reqs, classes)[1]


def percentile_latencies(reqs: List[Request]) -> Dict[str, float]:
    import numpy as np
    done = [r for r in reqs if r.finish_time is not None]
    out: Dict[str, float] = {"n": float(len(done))}
    if not done:
        return out
    ttfts = np.array([r.ttft for r in done])
    tpots = np.array([r.avg_tpot for r in done if r.avg_tpot is not None])
    for p in (50, 90, 99):
        out[f"ttft_p{p}"] = float(np.percentile(ttfts, p))
        if len(tpots):
            out[f"tpot_p{p}"] = float(np.percentile(tpots, p))
    return out
