"""Request lifecycle shared by the simulator and the real-exec engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"          # not yet admitted to an instance
    PENDING = "pending"        # admitted, waiting for a prefill slot
    DECODING = "decoding"      # prefill done, generating
    FINISHED = "finished"
    FAILED = "failed"          # lost to a fault past the retry budget


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    prompt_len: int
    output_len: int                      # ground-truth generation length;
                                         # schedulers never read it directly
    # multi-tenant tag: which SLO class this request is scored against
    # (see ``repro.core.slo.SLOClassSet``); single-tenant runs leave it at
    # DEFAULT_SLO_CLASS and behave exactly as before
    slo_class: str = "default"
    # fleet tag: which model the client asked for (``repro.fleet`` routes
    # on it; trace converters preserve it from the raw logs).  None =
    # untagged — single-model systems never read it
    model: Optional[str] = None
    state: RequestState = RequestState.QUEUED
    # times this request was resubmitted after losing its instance to a
    # fault (repro.faults); arrival_time is never reset on resubmission,
    # so TTFT keeps charging the full wait including lost work
    retries: int = 0

    # --- runtime bookkeeping -------------------------------------------- #
    admitted_time: Optional[float] = None
    first_token_time: Optional[float] = None   # prefill completion
    second_token_time: Optional[float] = None  # first decode iteration done
    finish_time: Optional[float] = None
    tokens_generated: int = 0
    instance_id: Optional[int] = None
    prompt_tokens: Optional[list] = None       # real-exec engine only
    generated: Optional[list] = None

    # ------------------------------------------------------------------ #
    @property
    def ttft(self) -> Optional[float]:
        """Paper §3.3: strict TTFT = prefill completion - arrival; includes
        queueing and phase-switching wait."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def avg_tpot(self) -> Optional[float]:
        """Mean time per output token, measured from the request's first
        decode iteration (paper §3.3: "the measurement of TPOT begins
        after the phase-switching delay" — the wait between prefill
        completion and the decode phase is charged to the strict TTFT,
        not to TPOT)."""
        if self.finish_time is None:
            return None
        if self.tokens_generated > 2 and self.second_token_time is not None:
            return ((self.finish_time - self.second_token_time)
                    / (self.tokens_generated - 2))
        if self.tokens_generated > 1 and self.first_token_time is not None:
            return ((self.finish_time - self.first_token_time)
                    / (self.tokens_generated - 1))
        return None

    def saved_tpot(self, now: float, slo_tpot: float) -> float:
        """Algorithm 2 line 15: accumulated decode slack."""
        if self.first_token_time is None:
            return 0.0
        return (self.tokens_generated * slo_tpot
                - (now - self.first_token_time))

    def kv_tokens(self) -> int:
        return self.prompt_len + self.tokens_generated
