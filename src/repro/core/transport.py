"""Transport: every cross-instance / cross-plane interaction as an
explicit, failable message.

``PolicySystemBase`` owns one ``Transport``; the FuDG KV hand-off hooks,
the ``migrate:K`` evacuation RPCs, and the control loop's signal
snapshots all route through it.  With no network plane attached
(``network is None`` — every fault-free or instance-fault-only cell) the
transport is *ideal*: transfers take exactly what their ``Link`` says
and RPCs/snapshots succeed instantly, reproducing the pre-transport
event timeline bit-exactly.  Attaching a ``NetworkModel``
(``repro.faults.network``, built by the fault injector from ``netdelay``
/ ``netloss`` / ``netdegrade`` / ``partition`` clauses) turns on the
degradation path:

* **transfers** — delivery time adds the plane's extra latency and
  divides the link bandwidth by its degradation factor; each message
  may be *lost* (loss draw, or either endpoint partitioned), in which
  case the sender notices only at a per-call timeout and retries with
  exponential backoff + deterministic jitter up to a retry budget;
* **per-link circuit breaker** — consecutive failures on one
  (src, dst) pair open the breaker for a cooldown, turning further
  sends into fast-fails (no timeout wait) and marking the destination
  unreachable to the routing layer;
* **RPCs** — the synchronous coordination path (handler round-trips at
  evacuation slots): a bounded number of loss draws decides success;
  failures trip the same breaker;
* **snapshots** — control-plane telemetry may be dropped (the
  controller holds its last decision via the staleness guard) or
  arrive one network delay late.

Everything is pure sim-time and deterministic: the only randomness is
the ``NetworkModel``'s counter-keyed hash draws, seeded from
CRC32(spec) ^ cell-seed exactly like the fault schedule, so transport
logs reproduce bit-exactly across runs and worker counts.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.events import NULL_TRACER

POOL = -2            # MoonCake's centralized KV pool endpoint
CTRL = -1            # the coordination plane (scheduler / controller)

# per-(src,dst)-link counter template: which fates a single link can see
_LINK_KEYS = ("sent", "delivered", "lost", "retries", "timeouts",
              "breaker_opens")


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Retry/timeout knobs for the degraded path (documented in
    benchmarks/README.md; the ideal path never reads them)."""

    timeout_factor: float = 3.0   # per-call timeout = factor x nominal time
    min_timeout: float = 0.050    # timeout floor (s)
    retries: int = 3              # retry budget per message (attempts - 1)
    backoff_base: float = 0.040   # first backoff (s); doubles per attempt
    backoff_cap: float = 1.0      # backoff ceiling (s)
    jitter: float = 0.5           # +/- fraction, deterministic hash draw
    rpc_latency: float = 1e-3     # nominal one-way latency of a bare RPC
    breaker_threshold: int = 3    # consecutive failures that open a link
    breaker_cooldown: float = 4.0 # seconds a tripped breaker stays open


class CircuitBreaker:
    """Per-link consecutive-failure breaker with a cooldown half-open:
    after the cooldown the next call is allowed through and its outcome
    re-closes or re-opens the circuit."""

    __slots__ = ("threshold", "cooldown", "fails", "open_until", "opens")

    def __init__(self, threshold: int, cooldown: float):
        self.threshold = threshold
        self.cooldown = cooldown
        self.fails = 0
        self.open_until = float("-inf")
        self.opens = 0

    def allow(self, now: float) -> bool:
        return now >= self.open_until

    def record_ok(self) -> None:
        self.fails = 0
        self.open_until = float("-inf")

    def record_fail(self, now: float) -> bool:
        """Count a failure; returns True when this one opened the
        circuit."""
        self.fails += 1
        if self.fails >= self.threshold:
            self.open_until = now + self.cooldown
            self.fails = 0
            self.opens += 1
            return True
        return False


class Transport:
    """The message plane between instances and the coordination plane."""

    # flight-recorder hook (repro.obs.attach_tracer)
    tracer = NULL_TRACER

    def __init__(self, config: Optional[TransportConfig] = None):
        self.config = config or TransportConfig()
        # None = ideal links (the default); the fault injector attaches a
        # NetworkModel when the schedule carries network clauses
        self.network = None
        self._breakers: Dict[Tuple[int, int], CircuitBreaker] = {}
        self._dst_open: Dict[int, float] = {}   # dst -> breaker open_until
        self._msg_ids = itertools.count()
        self.log: List[Dict[str, Any]] = []
        self.stats: Dict[str, int] = {
            "sent": 0, "delivered": 0, "lost": 0, "retries": 0,
            "timeouts": 0, "breaker_opens": 0, "breaker_fastfails": 0,
            "rpc_calls": 0, "rpc_retries": 0, "rpc_failures": 0,
            "snapshots_dropped": 0, "snapshots_delayed": 0,
        }
        # per-(src,dst) message fates; populated only on the degraded
        # path (mirrors ``stats``), so clean cells report no links
        self.link_stats: Dict[Tuple[int, int], Dict[str, int]] = {}

    def _link(self, src: int, dst: int) -> Dict[str, int]:
        ls = self.link_stats.get((src, dst))
        if ls is None:
            ls = self.link_stats[(src, dst)] = dict.fromkeys(_LINK_KEYS, 0)
        return ls

    # ---------------- plane attachment / reachability ------------------- #
    def attach_network(self, network) -> None:
        """Install the degradation plane (idempotent per run; the fault
        injector calls this once at attach time)."""
        self.network = network

    def instance_reachable(self, iid: int, now: float) -> bool:
        """Scheduler-side health view of an instance: not partitioned
        from the coordination plane and no open circuit toward it.  The
        routing layer (rolling activation, prefill dispatch, hand-off
        target choice) consults this to fail over instead of sending
        into a black hole."""
        net = self.network
        if net is None:
            return True
        if net.partitioned(iid):
            return False
        return now >= self._dst_open.get(iid, float("-inf"))

    def filter_reachable(self, instances, now: float):
        """Reachable subset of ``instances`` (the same list object when
        the plane is clean — zero cost on the default path)."""
        if self.network is None:
            return instances
        return [i for i in instances
                if self.instance_reachable(i.iid, now)]

    # ---------------- bulk transfers (FuDG KV hand-off) ----------------- #
    def transfer(self, engine, src: int, dst: int, nbytes: float,
                 now: float, deliver: Callable[[], None],
                 on_lost: Callable[[], None], link=None,
                 kind: str = "kv") -> None:
        """Move ``nbytes`` from ``src`` to ``dst`` over ``link`` and call
        ``deliver()`` at arrival — or ``on_lost()`` once the retry budget
        is exhausted.  The ideal path is byte-identical to the historic
        ``engine.push(link.transfer(...), deliver)``."""
        if self.network is None:
            done = link.transfer(nbytes, now) if link is not None else now
            engine.push(done, deliver)
            return
        mid = next(self._msg_ids)
        self.stats["sent"] += 1
        self._link(src, dst)["sent"] += 1
        trc = self.tracer
        if trc.enabled:
            trc.transport(now, "send", kind, src, dst)
        self._attempt(engine, mid, kind, src, dst, nbytes, now, now,
                      deliver, on_lost, link, 0)

    def _nominal(self, nbytes: float, link) -> float:
        """Unqueued clean-link time the *sender* expects — the basis of
        its per-call timeout (it knows the size and rated bandwidth, not
        the live congestion or degradation)."""
        if link is None:
            return self.config.rpc_latency
        return link.latency + nbytes / link.bandwidth

    def _attempt(self, engine, mid: int, kind: str, src: int, dst: int,
                 nbytes: float, t0: float, t: float, deliver, on_lost,
                 link, attempt: int) -> None:
        net, cfg = self.network, self.config
        breaker = self._breakers.get((src, dst))
        if breaker is None:
            breaker = CircuitBreaker(cfg.breaker_threshold,
                                     cfg.breaker_cooldown)
            self._breakers[(src, dst)] = breaker
        if not breaker.allow(t):
            # open circuit: fail fast, no timeout wait
            self.stats["breaker_fastfails"] += 1
            trc = self.tracer
            if trc.enabled:
                trc.transport(t, "fastfail", kind, src, dst)
            self._retry_or_lose(engine, mid, kind, src, dst, nbytes, t0,
                                t, deliver, on_lost, link, attempt)
            return
        lost = (net.partitioned(src) or net.partitioned(dst)
                or self._loss_draw(mid, attempt))
        if not lost:
            breaker.record_ok()
            done = link.transfer(nbytes, t, factor=net.degrade(),
                                 extra_latency=net.delay()) \
                if link is not None else t + net.delay()
            self.stats["delivered"] += 1
            self._link(src, dst)["delivered"] += 1
            self._log(mid, kind, src, dst, attempt + 1, "delivered",
                      t0, done)
            trc = self.tracer
            if trc.enabled:
                trc.transport(done, "deliver", kind, src, dst)
            engine.push(done, deliver)
            return
        # lost in flight: the sender only notices at its timeout
        timeout = max(cfg.min_timeout,
                      cfg.timeout_factor * self._nominal(nbytes, link))
        t_detect = t + timeout
        self.stats["timeouts"] += 1
        self._link(src, dst)["timeouts"] += 1
        trc = self.tracer
        if trc.enabled:
            trc.transport(t_detect, "timeout", kind, src, dst)
        if breaker.record_fail(t_detect):
            self.stats["breaker_opens"] += 1
            self._link(src, dst)["breaker_opens"] += 1
            if trc.enabled:
                trc.transport(t_detect, "breaker_open", kind, src, dst)
            self._dst_open[dst] = max(self._dst_open.get(dst, 0.0),
                                      breaker.open_until)
        engine.push_call(t_detect, self._retry_or_lose, engine, mid, kind,
                         src, dst, nbytes, t0, t_detect, deliver, on_lost,
                         link, attempt)

    def _retry_or_lose(self, engine, mid: int, kind: str, src: int,
                       dst: int, nbytes: float, t0: float, t: float,
                       deliver, on_lost, link, attempt: int) -> None:
        cfg = self.config
        trc = self.tracer
        if attempt >= cfg.retries:
            self.stats["lost"] += 1
            self._link(src, dst)["lost"] += 1
            self._log(mid, kind, src, dst, attempt + 1, "lost", t0, t)
            if trc.enabled:
                trc.transport(t, "lost", kind, src, dst)
            on_lost()
            return
        self.stats["retries"] += 1
        self._link(src, dst)["retries"] += 1
        if trc.enabled:
            trc.transport(t, "retry", kind, src, dst)
        backoff = min(cfg.backoff_cap, cfg.backoff_base * (2 ** attempt))
        jitter = (2.0 * self.network.draw("jit", mid, attempt) - 1.0)
        backoff *= 1.0 + cfg.jitter * jitter
        engine.push_call(t + backoff, self._attempt, engine, mid, kind,
                         src, dst, nbytes, t0, t + backoff, deliver,
                         on_lost, link, attempt + 1)

    def _loss_draw(self, mid: int, attempt: int) -> bool:
        p = self.network.loss()
        if p <= 0.0:
            return False
        return self.network.draw("loss", mid, attempt) < p

    def _log(self, mid, kind, src, dst, attempts, outcome, t0, t1):
        self.log.append({
            "id": mid, "kind": kind, "src": src, "dst": dst,
            "attempts": attempts, "outcome": outcome,
            "t0": round(t0, 6), "t1": round(t1, 6)})

    # ---------------- synchronous coordination RPCs --------------------- #
    def try_rpc(self, now: float, src: int, dst: int) -> bool:
        """One coordination round-trip (e.g. the ``InstanceHandler``
        serialize/resolve path at an evacuation slot).  The caller's own
        cadence is the outer retry loop — evacuations re-run every slot
        boundary until the notice deadline — so a failure here just means
        "not this slot"; internally a bounded number of loss draws models
        in-call retries.  Clean plane: always True, zero cost."""
        net = self.network
        if net is None:
            return True
        self.stats["rpc_calls"] += 1
        breaker = self._breakers.get((src, dst))
        if breaker is None:
            breaker = CircuitBreaker(self.config.breaker_threshold,
                                     self.config.breaker_cooldown)
            self._breakers[(src, dst)] = breaker
        if not breaker.allow(now):
            self.stats["breaker_fastfails"] += 1
            self.stats["rpc_failures"] += 1
            return False
        if net.partitioned(src) or net.partitioned(dst):
            self.stats["rpc_failures"] += 1
            trc = self.tracer
            if trc.enabled:
                trc.transport(now, "rpc_fail", "rpc", src, dst)
            if breaker.record_fail(now):
                self.stats["breaker_opens"] += 1
                self._link(src, dst)["breaker_opens"] += 1
                if trc.enabled:
                    trc.transport(now, "breaker_open", "rpc", src, dst)
                self._dst_open[dst] = max(self._dst_open.get(dst, 0.0),
                                          breaker.open_until)
            return False
        mid = next(self._msg_ids)
        p = net.loss()
        for attempt in range(self.config.retries + 1):
            if p <= 0.0 or net.draw("rpc", mid, attempt) >= p:
                if attempt:
                    self.stats["rpc_retries"] += attempt
                breaker.record_ok()
                return True
        self.stats["rpc_retries"] += self.config.retries
        self.stats["rpc_failures"] += 1
        trc = self.tracer
        if trc.enabled:
            trc.transport(now, "rpc_fail", "rpc", src, dst)
        if breaker.record_fail(now):
            self.stats["breaker_opens"] += 1
            self._link(src, dst)["breaker_opens"] += 1
            if trc.enabled:
                trc.transport(now, "breaker_open", "rpc", src, dst)
            self._dst_open[dst] = max(self._dst_open.get(dst, 0.0),
                                      breaker.open_until)
        return False

    # ---------------- control-plane telemetry --------------------------- #
    def snapshot_channel(self, now: float) -> Tuple[str, float]:
        """Fate of one controller signal snapshot crossing the plane:
        ``("ok", 0)`` delivered now, ``("delay", d)`` delivered ``d``
        seconds late, ``("drop", 0)`` lost (the harness keeps its last
        delivered snapshot and the controller's staleness guard holds)."""
        net = self.network
        if net is None:
            return ("ok", 0.0)
        mid = next(self._msg_ids)
        p = net.loss()
        if p > 0.0 and net.draw("snap", mid) < p:
            self.stats["snapshots_dropped"] += 1
            trc = self.tracer
            if trc.enabled:
                trc.transport(now, "snapshot_drop", "snapshot", CTRL, CTRL)
            return ("drop", 0.0)
        d = net.delay()
        if d > 0.0:
            self.stats["snapshots_delayed"] += 1
            trc = self.tracer
            if trc.enabled:
                trc.transport(now, "snapshot_delay", "snapshot", CTRL, CTRL)
            return ("delay", d)
        return ("ok", 0.0)

    # ---------------- accounting ---------------------------------------- #
    def summary(self) -> Dict[str, Any]:
        """JSON-safe counters for result rows (the per-message ``log``
        stays in-process: determinism tests compare it, goldens pin only
        these totals).  ``links`` breaks the totals down per
        (src, dst) pair — empty on a clean plane, since only the
        degraded path touches ``link_stats``."""
        out: Dict[str, Any] = dict(self.stats)
        out["links"] = {f"{src}->{dst}": dict(v)
                        for (src, dst), v in sorted(self.link_stats.items())}
        return out
