"""The formal ``ServingSystem`` protocol and the shared policy core.

``ServingSystem`` is the contract the simulation engine (and the
real-exec server) drives: ``submit`` new requests, get ``on_slot_end``
callbacks at every slot boundary, ``scale_up``/``scale_down`` under the
mitosis benchmarks, and ``describe()`` the strategy composition so every
result row is self-documenting.

``PolicySystemBase`` is the one implementation of the queue/retry/drain
machinery that used to be copy-pasted (or absent) across
``padg_system.py`` and the baselines.  Behaviour is composed from three
policies (``repro.core.policies``):

    submit(req)        -> admission.try_admit -> routing.place/select
                          (queued on refusal)
    on_slot_end(...)   -> drain the queue in queue_discipline order
                          (instance states just changed)
    scale_up/down      -> routing.add_instance / routing.remove_instance

The drain loop is bounded per call (``max_tries``, 4 consecutive
failures) so an overload backlog cannot make every slot boundary
O(queue); with the FIFO discipline it is bit-identical to the
pre-policy-API deque loop, which is what keeps the golden grids
reproducing exactly through the redesigned construction path.
"""
from __future__ import annotations

from collections import deque
from typing import (Any, Deque, Dict, List, Optional, Protocol,
                    runtime_checkable)

from repro.core.instance import Instance
from repro.core.mitosis import unregister_instance
from repro.core.policies import (AdmissionPolicy, FIFODiscipline,
                                 QueueDiscipline, RoutingPolicy,
                                 make_admission, make_queue_discipline,
                                 make_routing)
from repro.core.request import Request
from repro.core.slo import SLO, SLOClassSet, as_slo_class_set
from repro.core.transport import Transport
from repro.faults.policies import FailurePolicy, make_failure_policy
from repro.obs.events import NULL_TRACER, attach_decision_log


@runtime_checkable
class ServingSystem(Protocol):
    """What the discrete-event engine (and the mitosis benchmarks)
    require of any serving strategy."""

    instances: List[Instance]

    def submit(self, req: Request, now: float, engine) -> None:
        """A request arrived; admit it somewhere or queue it."""
        ...

    def on_slot_end(self, inst: Instance, kind: str, reqs: List[Request],
                    now: float, engine) -> None:
        """An instance finished a slot (prefill batch / decode iteration
        / FuDG hand-off); instance states just changed."""
        ...

    def scale_up(self, engine=None) -> Optional[Instance]:
        """Add one instance to the serving pool (mitosis expansion)."""
        ...

    def scale_down(self, now: Optional[float] = None,
                   engine=None) -> Optional[Instance]:
        """Retire one instance (mitosis contraction); its in-flight work
        is drained or resubmitted per the system's ``FailurePolicy``."""
        ...

    def describe(self) -> Dict[str, Any]:
        """Self-documenting policy composition (JSON/pickle-safe)."""
        ...


class PolicySystemBase:
    """Shared queue/retry/drain core; strategies differ only in their
    policy bundle, instance construction, and (for FuDG) the KV
    hand-off hook."""

    # family identity + declarative policy defaults (overridden per class;
    # ``StrategySpec.describe`` reads these to resolve None policy slots)
    base_name = "base"
    default_queue = "fifo"
    default_admission = "immediate"
    default_routing = "least-kv"
    default_failure = "drop"

    # Flight-recorder hook (repro.obs): NULL_TRACER keeps the hot path
    # allocation-free — one attribute read per emission site.
    tracer = NULL_TRACER
    _decision_log: Optional[List] = None

    @property
    def decision_log(self) -> Optional[List]:
        """Compat shim for the PR 8 scheduling-decision trace: attaching
        a list installs it as a tracer mirror, so every admission outcome
        is appended as ("admit"|"queue"|"drain", now, rid[, iid]) through
        the event bus.  The engines log slot events into the same list,
        so one sequence totally orders the scheduling decisions a run
        makes.  None (the default) keeps the hot path allocation-free."""
        return self._decision_log

    @decision_log.setter
    def decision_log(self, log: Optional[List]) -> None:
        attach_decision_log(self, log)

    def __init__(self, cost, n_instances: int, slo=None, *,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, iid_base: int = 0):
        """``slo`` is a bare ``SLO``, an ``SLOClassSet``, or None for the
        SLO-blind baselines; policies may be declarative strings
        (``"timeout-forced:4"``) or policy instances.  ``failure``
        (``"drop"`` / ``"resubmit:K"`` / ``"migrate:K"``,
        ``repro.faults``) decides the fate of in-flight requests when an
        instance crashes, is preempted, or retires under contraction.

        ``iid_base`` offsets every instance id the system mints.  The
        engine's slot table and the mitosis actor registry are keyed by
        ``iid`` globally, so systems sharing one engine (``repro.fleet``
        pools) must mint from disjoint bands; 0 (the default) keeps every
        single-system id — and therefore every golden — exactly as
        before."""
        self.cost = cost
        self.iid_base = iid_base
        self.slo_set: Optional[SLOClassSet] = (
            as_slo_class_set(slo) if slo is not None else None)
        self.slo: Optional[SLO] = (
            self.slo_set.default_slo if self.slo_set is not None else None)
        self.queue_discipline: QueueDiscipline = make_queue_discipline(
            queue_discipline if queue_discipline is not None
            else self.default_queue)
        self.admission: AdmissionPolicy = make_admission(
            admission if admission is not None else self.default_admission)
        self.routing: RoutingPolicy = make_routing(
            routing if routing is not None else self.default_routing)
        self.failure: FailurePolicy = make_failure_policy(
            failure if failure is not None else self.default_failure)
        # describe() reports the failure slot only when a caller pinned
        # it: pre-fault-layer golden rows must keep their exact bundles
        self._failure_explicit = failure is not None
        # iid -> evacuation deadline (inf for migrating planned
        # removals); populated by the fault hooks, checked per slot end
        self._evacuating: Dict[int, float] = {}
        self.fault_stats: Dict[str, int] = {
            "crashes": 0, "preemptions": 0, "slowdowns": 0,
            "planned_removals": 0, "lost": 0, "dropped": 0,
            "resubmitted": 0, "requeued": 0, "migrated": 0}
        self.queue: Deque[Request] = deque()
        self.instances: List[Instance] = []
        # every cross-instance / cross-plane interaction (FuDG KV
        # hand-offs, evacuation RPCs, controller snapshots) routes
        # through the transport; ideal until a fault schedule with
        # network clauses attaches a NetworkModel.  Built before
        # _build(): PaDG construction wires its reachability predicate.
        self.transport = Transport()
        # set by StrategySpec.build; direct construction keeps family name
        self.spec_name: Optional[str] = None
        self.provenance: str = ""
        self._build(n_instances)
        self._next_iid = 1 + max((i.iid for i in self.instances),
                                 default=self.iid_base - 1)

    # ---------------- construction hooks -------------------------------- #
    def _build(self, n_instances: int) -> None:
        for i in range(n_instances):
            self.instances.append(self._make_instance(self.iid_base + i))

    def _make_instance(self, iid: int) -> Instance:
        return Instance(iid, self.cost,
                        kv_capacity_tokens=self.cost.kv_capacity_tokens())

    # ---------------- engine hooks --------------------------------------- #
    def submit(self, req: Request, now: float, engine) -> None:
        inst = self.admission.try_admit(self, req, now)
        trc = self.tracer
        if trc.enabled:
            if inst is not None:
                trc.admit(now, req.rid, inst.iid)
            else:
                trc.enqueue(now, req.rid)
        if inst is not None:
            engine.activate(inst)
        else:
            self.queue.append(req)

    def on_slot_end(self, inst: Instance, kind: str, reqs: List[Request],
                    now: float, engine) -> None:
        if kind == "prefill_handoff":
            self._on_prefill_handoff(inst, reqs, now, engine)
            return
        if self._evacuating and inst.iid in self._evacuating:
            # slot boundaries are the only legal moment to move in-flight
            # work off an instance under a preemption notice / migrating
            # planned removal (slots are uninterruptible)
            self.failure.on_evacuation_slot(self, inst, now, engine)
        # retry queued admissions: instance states just changed
        self._drain_queue(now, engine)

    def _on_prefill_handoff(self, inst: Instance, reqs: List[Request],
                            now: float, engine) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} routed a request to a prefill-only "
            "instance but defines no KV hand-off hook")

    # ---------------- queue ---------------------------------------------- #
    def _drain_queue(self, now: float, engine, max_tries: int = 64) -> None:
        """Retry queued admissions in discipline order; bounded per call
        so an overload backlog cannot make every slot boundary O(queue).
        Requests that fail (or are never reached) keep their arrival
        order in the underlying deque."""
        if not self.queue:
            return
        order = self.queue_discipline.order(self.queue, now, self.slo_set,
                                            limit=max_tries)
        admitted = set()
        tries = 0
        fails = 0
        for req in order:
            if tries >= max_tries or fails >= 4:
                break
            tries += 1
            inst = self.admission.try_admit(self, req, now)
            if inst is not None:
                trc = self.tracer
                if trc.enabled:
                    trc.drain(now, req.rid, inst.iid)
                engine.activate(inst)
                admitted.add(id(req))
                fails = 0
            else:
                fails += 1
        if admitted:
            if isinstance(self.queue_discipline, FIFODiscipline):
                # FIFO drained a prefix of the deque: pop it and push
                # back the survivors — O(tried) per slot boundary, not
                # O(queue) (an overload backlog would otherwise pay a
                # full rebuild on every admitted request)
                for _ in range(len(order)):
                    self.queue.popleft()
                self.queue.extendleft(
                    r for r in reversed(order) if id(r) not in admitted)
            else:
                # priority disciplines admit from anywhere in the deque
                self.queue = deque(
                    r for r in self.queue if id(r) not in admitted)

    # ---------------- mitosis hooks (dynamic scaling bench) -------------- #
    def scale_up(self, engine=None) -> Instance:
        inst = self._make_instance(self._next_iid)
        self._next_iid += 1
        self.instances.append(inst)
        self.routing.add_instance(self, inst)
        trc = self.tracer
        if trc.enabled:
            trc.instance(trc.now(), inst.iid, "scale_up")
        return inst

    def scale_down(self, now: Optional[float] = None,
                   engine=None) -> Optional[Instance]:
        inst = self.routing.remove_instance(self)
        if inst is not None and inst in self.instances:
            self.instances.remove(inst)
        if inst is not None:
            self.fault_stats["planned_removals"] += 1
            trc = self.tracer
            if trc.enabled:
                trc.instance(now if now is not None else trc.now(),
                             inst.iid, "scale_down")
            self.failure.on_planned_removal(self, inst, now, engine)
        return inst

    # ---------------- fault hooks (repro.faults) ------------------------- #
    def detach_instance(self, inst: Instance) -> None:
        """Remove a *specific* instance from the routable pool (fault
        teardown picks the victim, unlike ``scale_down``'s heuristic)."""
        if inst in self.instances:
            self.instances.remove(inst)
        self.routing.discard_instance(self, inst)

    def fault_crash(self, inst: Instance, now: float,
                    engine) -> List[Request]:
        """Unannounced instance loss: the in-flight slot is discarded by
        the engine, the KV cache is gone, and every request on the
        instance flows through the failure policy.  Returns the lost
        requests (post-policy: requeued, migrated, or FAILED)."""
        inst.alive = False
        self.detach_instance(inst)
        # macro routing unregisters through the scheduler; on the
        # baselines nothing else does, and handlers minted during
        # evacuation (migrate:K targets) would leak actor-table entries
        unregister_instance(inst)
        self._evacuating.pop(inst.iid, None)
        lost = list(inst.pending) + list(inst.decoding)
        for r in list(inst.pending):
            inst.remove_pending(r)
        for r in list(inst.decoding):
            inst.remove_decoding(r)
        self.fault_stats["crashes"] += 1
        self.fault_stats["lost"] += len(lost)
        trc = self.tracer
        if trc.enabled:
            trc.instance(now, inst.iid, "crash")
        self.failure.on_instance_fault(self, inst, lost, now, engine)
        if engine is not None:
            self._drain_queue(now, engine)
        return lost

    def fault_preempt(self, inst: Instance, notice: float, now: float,
                      engine) -> None:
        """Spot preemption with a notice window: the instance stops
        receiving new work immediately, keeps executing until
        ``now + notice`` (the failure policy may evacuate work at slot
        boundaries in between), then dies like a crash."""
        self.detach_instance(inst)
        deadline = now + notice
        self._evacuating[inst.iid] = deadline
        self.fault_stats["preemptions"] += 1
        trc = self.tracer
        if trc.enabled:
            trc.instance(now, inst.iid, "preempt")
        self.failure.on_notice(self, inst, deadline, now, engine)
        engine.push_call(deadline, self._preempt_deadline, inst, engine)

    def _preempt_deadline(self, inst: Instance, engine) -> None:
        self._evacuating.pop(inst.iid, None)
        if not inst.alive:
            return
        inst.alive = False
        unregister_instance(inst)
        lost = list(inst.pending) + list(inst.decoding)
        for r in list(inst.pending):
            inst.remove_pending(r)
        for r in list(inst.decoding):
            inst.remove_decoding(r)
        self.fault_stats["lost"] += len(lost)
        trc = self.tracer
        if trc.enabled:
            trc.instance(engine.now, inst.iid, "preempt_dead")
        if lost:
            self.failure.on_instance_fault(self, inst, lost, engine.now,
                                           engine)
            self._drain_queue(engine.now, engine)

    def fault_lost_requests(self, reqs: List[Request], now: float,
                            engine) -> None:
        """Requests lost with no owning instance (e.g. a FuDG KV transfer
        whose decode target died mid-flight)."""
        self.fault_stats["lost"] += len(reqs)
        self.failure.on_instance_fault(self, None, reqs, now, engine)
        if engine is not None:
            self._drain_queue(now, engine)

    # ---------------- self-description ----------------------------------- #
    def describe(self) -> Dict[str, Any]:
        """The live policy composition (strings, ints — pickle/JSON safe;
        the worker boundary round-trips this through pickle)."""
        d = {
            "strategy": self.spec_name or self.base_name,
            "base": self.base_name,
            "queue": self.queue_discipline.describe(),
            "admission": self.admission.describe(),
            "routing": self.routing.describe(),
            "n_instances": len(self.instances),
            "provenance": self.provenance,
        }
        if self._failure_explicit:
            # only when pinned: pre-fault-layer golden rows must keep
            # their exact describe() bundles
            d["failure"] = self.failure.describe()
        return d
