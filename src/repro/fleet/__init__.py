"""Multi-model fleet serving: cost-aware model routing + budget-
constrained pool rebalancing.

One cluster, several model pools — each an independent strategy stack
(``repro.baselines``) over its own ``repro.configs`` model and cost
model — sharing a global GPU budget:

    FleetSpec / PoolSpec   -- "name=model/strategy/n,...;budget=G"
    FleetSystem            -- the ServingSystem over the pools (disjoint
                              instance-id bands, fault-hook delegation,
                              FleetTransport)
    FleetRouter            -- request -> pool under "pinned" /
                              "cheapest-feasible" / "quality-tiered"
    FleetRebalanceHarness  -- per-pool control loops reconciled under
                              the budget: donor-funded capacity moves
                              through the mitosis/actuator path

``repro.simulator.metrics.run_once`` installs the rebalancer for
``control="rebalance"`` fleet cells; the experiment runner exposes the
whole layer as the seed-neutral ``fleet=`` axis (the strategy slot then
names routers).  Depends on ``repro.baselines``/``repro.control``; the
simulator imports *us* lazily.
"""
from repro.fleet.rebalance import FleetRebalanceHarness
from repro.fleet.router import (ROUTERS, CheapestFeasibleRouter,
                                FleetRouter, PinnedRouter,
                                QualityTieredRouter, make_router)
from repro.fleet.spec import (DEFAULT_GPU_PRICES, FleetSpec, PoolSpec,
                              dollars_per_token, parse_fleet)
from repro.fleet.system import BAND, FleetSystem, FleetTransport

__all__ = [
    "BAND", "DEFAULT_GPU_PRICES", "CheapestFeasibleRouter",
    "FleetRebalanceHarness", "FleetRouter", "FleetSpec", "FleetSystem",
    "FleetTransport", "PinnedRouter", "PoolSpec", "QualityTieredRouter",
    "ROUTERS", "dollars_per_token", "make_router", "parse_fleet",
]
