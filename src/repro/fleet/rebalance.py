"""Budget-constrained fleet rebalancing: scaling as *moving* capacity.

The PR 5 control plane scales one pool against an unbounded machine
supply; a fleet shares a fixed GPU budget, so growth must usually be
funded by shrinking someone else.  ``FleetRebalanceHarness`` runs one
``SignalCollector`` + ``TargetBandController`` + ``Actuator`` triple
per pool (min_instances=1, max capped by the budget) off a single
engine tick, and reconciles their per-pool wishes under the budget:

1. **repair** — capacity lost to faults is re-provisioned toward each
   pool's last committed intent first (the PR 6 contract);
2. **downs** — pools whose controller asked to shrink release budget
   (refused contractions roll the controller's cooldown back);
3. **ups** — pools asking to grow are served in pressure order
   (backlog per committed instance, then pool index).  A grow fits
   inside free budget when there is any; otherwise a **donor-funded
   move**: the calmest eligible donor — not asking to grow itself,
   above one instance, backlog at or under the controller's
   ``queue_low`` band, and freeing enough devices — is contracted and
   the receiver commissioned in the same tick, provisioning delay and
   all.  No eligible donor means the grow waits.

Invariants, enforced structurally and pinned by tests: committed
devices (live + provisioning, priced per pool) never exceed the
budget, and no pool's committed count drops below one instance.
Decisions, signals, and timelines are pure sim-time bookkeeping —
fleet cells stay bit-reproducible across worker counts.

Completions are dispatched to per-pool collectors through the fleet's
``pool_of_rid`` record (the engine's ``finished`` list is global), and
arrivals through the fleet's ``on_route`` tap, so each pool's
controller sees only its own traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.control import (Actuator, ControllerConfig, ScalingTimeline,
                           SignalCollector, TargetBandController,
                           make_controller)


def _rebalance_config(control) -> ControllerConfig:
    """``"rebalance"`` or ``"rebalance:interval=1,target=0.92"`` — the
    options ride the band controller's parser so both spell knobs the
    same way."""
    name, _, args = str(control).partition(":")
    if name != "rebalance":
        raise KeyError(f"unknown fleet control spec {control!r}; "
                       "expected 'rebalance[:k=v,...]'")
    proto = make_controller("band" + (f":{args}" if args else ""))
    return proto.config


class _FleetTimeline:
    """Duck-types the single-pool ``ScalingTimeline`` for ``run_once``:
    ``summary()`` nests per-pool timelines under the pool names plus the
    fleet-level move accounting."""

    def __init__(self, harness: "FleetRebalanceHarness"):
        self._h = harness

    def summary(self) -> Dict[str, Any]:
        h = self._h
        return {
            "budget": h.fleet.budget,
            "n_moves": h.n_moves,
            "n_ups": h.n_ups,
            "n_downs": h.n_downs,
            "n_repairs": h.n_repairs,
            "per_pool": {name: tl.summary()
                         for name, tl in zip(h.fleet.pool_names,
                                             h.timelines)},
        }


class FleetRebalanceHarness:
    """Closed loop over a live (fleet, engine) pair under one budget."""

    def __init__(self, fleet, engine, control="rebalance"):
        self.fleet = fleet
        self.engine = engine
        base = _rebalance_config(control)
        self.interval = base.interval
        self.collectors: List[SignalCollector] = []
        self.controllers: List[TargetBandController] = []
        self.actuators: List[Actuator] = []
        self.timelines: List[ScalingTimeline] = []
        for pool in fleet.pools:
            cap = max(1, fleet.budget // pool.cost.devices)
            cfg = dataclasses.replace(base, min_instances=1,
                                      max_instances=cap)
            tl = ScalingTimeline()
            self.collectors.append(SignalCollector(
                pool.slo_set or fleet.slo_set,
                window=max(5.0, 4 * cfg.interval)))
            self.controllers.append(TargetBandController(cfg))
            self.actuators.append(Actuator(pool, engine, cfg, tl))
            self.timelines.append(tl)
        self.timeline = _FleetTimeline(self)
        self._finished_by_pool: List[List] = [[] for _ in fleet.pools]
        self._n_seen = 0              # prefix of engine.finished dispatched
        self._next_tick = self.interval
        self.n_moves = 0
        self.n_ups = 0
        self.n_downs = 0
        self.n_repairs = 0

    # ---------------- wiring ------------------------------------------- #
    def attach(self) -> "FleetRebalanceHarness":
        def on_route(k: int, req, now: float) -> None:
            self.collectors[k].on_arrival(req, now)

        self.fleet.on_route = on_route
        prev_tick = self.engine.on_tick

        def on_tick(now: float):
            if prev_tick is not None:
                prev_tick(now)
            self._maybe_control(now)

        self.engine.on_tick = on_tick
        return self

    # ---------------- per-tick control --------------------------------- #
    def _dispatch_finished(self) -> None:
        """Route engine completions to the owning pool's append-only
        list (each collector keeps its own consumed-prefix cursor)."""
        finished = self.engine.finished
        for r in finished[self._n_seen:]:
            k = self.fleet.pool_of_rid.get(r.rid)
            if k is not None:
                self._finished_by_pool[k].append(r)
        self._n_seen = len(finished)

    def _signals(self, k: int, now: float) -> Dict[str, float]:
        pool = self.fleet.pools[k]
        col = self.collectors[k]
        col.consume_finished(self._finished_by_pool[k], now)
        return {
            "t": now,
            "rate_ewma": col.rate_ewma(now),
            "queue_depth": float(SignalCollector.queue_depth(pool)),
            "kv_occupancy": SignalCollector.kv_occupancy(pool),
            "attainment_window": col.attainment_window(),
            "arrivals_total": float(col._arrivals),
            "n_instances": float(len(pool.instances)),
        }

    def _maybe_control(self, now: float) -> None:
        if now < self._next_tick:
            return
        self._dispatch_finished()
        sigs = [self._signals(k, now) for k in range(len(self.fleet.pools))]
        for k, act in enumerate(self.actuators):
            self.n_repairs += act.repair(now, sigs[k])
        wants = [self.controllers[k].decide(sigs[k],
                                            self.actuators[k].n_target)
                 for k in range(len(self.fleet.pools))]
        self._reconcile(wants, now, sigs)
        for k, act in enumerate(self.actuators):
            act.note_intent(act.n_target)
            self.timelines[k].record_tick(
                now, len(self.fleet.pools[k].instances), act.n_target)
        self._next_tick = now + self.interval

    # ---------------- budget arithmetic -------------------------------- #
    def committed_devices(self) -> int:
        """GPUs committed fleet-wide: live + provisioning, priced by
        each pool's per-instance device count."""
        return sum(act.n_target * pool.cost.devices
                   for act, pool in zip(self.actuators, self.fleet.pools))

    def _queue_per_target(self, k: int, sigs) -> float:
        return sigs[k]["queue_depth"] / max(1, self.actuators[k].n_target)

    def _pick_donor(self, receiver: int, wants: List[int], sigs,
                    need: int) -> Optional[int]:
        """Calmest pool that can fund the receiver's grow: not asking to
        grow itself, above one committed instance, backlog at or under
        the band's ``queue_low``, and whose per-instance device count
        covers the shortfall.  Deterministic: lowest backlog, then pool
        index."""
        free = self.fleet.budget - self.committed_devices()
        candidates = []
        for j in range(len(self.fleet.pools)):
            if j == receiver or wants[j] > 0:
                continue
            if self.actuators[j].n_target <= 1:
                continue
            cfg = self.controllers[j].config
            if self._queue_per_target(j, sigs) > cfg.queue_low:
                continue
            if free + self.fleet.pools[j].cost.devices < need:
                continue            # the donation would not fit the grow
            candidates.append(j)
        if not candidates:
            return None
        return min(candidates,
                   key=lambda j: (self._queue_per_target(j, sigs), j))

    def _reconcile(self, wants: List[int], now: float, sigs) -> None:
        # 1. voluntary contractions release budget first
        for k, w in enumerate(wants):
            if w < 0:
                if self.actuators[k].n_target <= 1:
                    # structural floor: never empty a pool, whatever the
                    # per-pool controller asked for
                    self.controllers[k].on_down_refused()
                elif self.actuators[k].apply(-1, now, sigs[k]):
                    self.n_downs += 1
                else:
                    self.controllers[k].on_down_refused()
        # 2. expansions in pressure order (worst backlog per committed
        #    instance first; pool index breaks ties)
        ups = sorted((k for k, w in enumerate(wants) if w > 0),
                     key=lambda k: (-self._queue_per_target(k, sigs), k))
        for k in ups:
            need = self.fleet.pools[k].cost.devices
            if self.committed_devices() + need <= self.fleet.budget:
                self.actuators[k].apply(+1, now, sigs[k])
                self.n_ups += 1
                continue
            donor = self._pick_donor(k, wants, sigs, need)
            if donor is None:
                # nobody can safely fund it: the grow waits (the
                # controller's up-cooldown spaces out re-asks)
                continue
            if not self.actuators[donor].apply(-1, now, sigs[donor]):
                # the donor pool refused (e.g. a FuDG base protecting
                # its last decoder): nothing moved
                self.controllers[donor].on_down_refused()
                continue
            if self.committed_devices() + need <= self.fleet.budget:
                self.actuators[k].apply(+1, now, sigs[k])
                self.n_moves += 1

    # ---------------- reporting ---------------------------------------- #
    def summary(self) -> Dict[str, Any]:
        return self.timeline.summary()
