"""Fleet declaration: which model pools share the cluster, under what
GPU budget, at what price.

A fleet is N independent strategy stacks ("pools"), each serving one
model with its own cost model, mitosis machinery, and policy bundle,
sharing a global GPU budget.  ``parse_fleet`` turns the grid-spec string

    "chat=llama-30b/ecoserve/4,code=qwen1.5-32b/ecoserve/2;budget=24"

into a ``FleetSpec``: comma-separated pools (``name=model/strategy/n``
— slash-separated inside a pool because strategy names carry ``+``),
then ``;``-separated fleet options (only ``budget=<gpus>`` today).  The
budget defaults to the committed device count, i.e. a fully packed
cluster where growth is only possible by taking capacity from a donor
pool — the regime the rebalancer exists for.

``dollars_per_token`` prices a pool's *decode* output from its cost
model at a reference operating point (batch 8, 1k context): the
cheapest-feasible router ranks pools by it, so "cheap" means measured
throughput per list-price dollar, not parameter count.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# list-price-style $/GPU-hour figures (on-demand cloud ballpark; only
# the RATIOS matter to the router, and they ride in result rows so the
# assumption is auditable)
DEFAULT_GPU_PRICES: Dict[str, float] = {
    "L20": 1.28,
    "A800": 2.80,
    "tpu-v5e": 1.20,
}

# decode reference operating point for $/token pricing
_REF_BATCH = 8
_REF_CTX = 1024


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One model pool: a named strategy stack inside the fleet."""

    name: str
    model: str            # repro.configs model key ("llama-30b", ...)
    strategy: str         # any resolvable strategy / grammar composition
    n_instances: int      # initial instance count


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Pools + the shared GPU budget (in devices, i.e. tp*pp units)."""

    pools: Tuple[PoolSpec, ...]
    budget: int           # total GPUs the fleet may commit at once

    def committed_devices(self, devices_per_instance: int) -> int:
        """Initial committed GPUs with a uniform parallelism degree."""
        return sum(p.n_instances for p in self.pools) * devices_per_instance


def parse_fleet(spec: str, devices_per_instance: int = 1) -> FleetSpec:
    """Parse a fleet spec string; ``devices_per_instance`` (= tp*pp of
    the cells the fleet will run under) sizes the default budget."""
    spec = spec.strip()
    if not spec:
        raise ValueError("empty fleet spec")
    pool_part, _, opt_part = spec.partition(";")
    pools = []
    seen = set()
    for entry in pool_part.split(","):
        entry = entry.strip()
        name, eq, rest = entry.partition("=")
        fields = rest.split("/")
        if not eq or len(fields) != 3 or not name:
            raise ValueError(
                f"bad pool entry {entry!r}; expected name=model/strategy/n")
        model, strategy, n_str = (f.strip() for f in fields)
        n = int(n_str)
        if n < 1:
            raise ValueError(f"pool {name!r} needs >= 1 instance, got {n}")
        if name in seen:
            raise ValueError(f"duplicate pool name {name!r}")
        seen.add(name)
        pools.append(PoolSpec(name=name, model=model, strategy=strategy,
                              n_instances=n))
    budget = None
    if opt_part.strip():
        for opt in opt_part.split(";"):
            k, _, v = opt.strip().partition("=")
            if k != "budget" or not v:
                raise ValueError(f"unknown fleet option {opt.strip()!r}; "
                                 "expected budget=<gpus>")
            budget = int(v)
    fleet = FleetSpec(pools=tuple(pools), budget=budget or 0)
    committed = fleet.committed_devices(devices_per_instance)
    if budget is None:
        fleet = dataclasses.replace(fleet, budget=committed)
    elif budget < committed:
        raise ValueError(
            f"fleet budget {budget} GPUs < {committed} committed by the "
            f"pool spec at {devices_per_instance} devices/instance")
    return fleet


def dollars_per_token(cost, hw_name: str,
                      prices: Dict[str, float] = None) -> float:
    """Decode $/token of one instance under ``cost``
    (``InstanceCostModel`` or a calibrated executor with the same
    surface) at the reference operating point."""
    price_hr = (prices or DEFAULT_GPU_PRICES).get(hw_name)
    if price_hr is None:
        raise KeyError(f"no GPU price for hardware {hw_name!r}; known: "
                       f"{tuple(DEFAULT_GPU_PRICES)}")
    dollars_per_s = cost.devices * price_hr / 3600.0
    iter_time = cost.decode_time(_REF_BATCH, ctx_sum=_REF_BATCH * _REF_CTX)
    tokens_per_s = _REF_BATCH / iter_time
    return dollars_per_s / tokens_per_s
