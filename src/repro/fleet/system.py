"""``FleetSystem``: N model pools behind one router, one engine, one
GPU budget.

Each pool is a full, independent strategy stack built by
``repro.baselines.make_system`` — any registered spec or grammar
composition, over any ``repro.configs`` model, with its own
``InstanceCostModel`` — minted into a disjoint instance-id band
(``iid_base = k * BAND``) so the engine's slot table and the mitosis
actor registry never collide across pools.  The fleet itself implements
the ``ServingSystem`` protocol: ``submit`` routes each request to a pool
(``repro.fleet.router``) and records the assignment in ``pool_of_rid``
(the metrics layer scores per-pool attainment off it), ``on_slot_end``
dispatches to the owning pool by id band, and the fault hooks delegate
the same way so crash/preempt/network schedules compose unchanged.

A ``FleetTransport`` fronts the pools' transports: attaching a network
plane (fault injector) degrades every pool at once, and ``summary()``
sums the per-pool counters.  Capacity changes are the rebalancer's job
(``repro.fleet.rebalance``); the fleet-level ``scale_up``/``scale_down``
exist for protocol conformance and act on the most/least pressured pool.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from repro.configs import get_config
from repro.core.request import Request
from repro.core.slo import as_slo_class_set
from repro.core.transport import Transport
from repro.fleet.router import make_router
from repro.obs.events import NULL_TRACER
from repro.fleet.spec import (DEFAULT_GPU_PRICES, FleetSpec, dollars_per_token,
                              parse_fleet)
from repro.simulator.cost_model import (GPU_A800, GPU_L20, TPU_V5E_SIM,
                                        InstanceCostModel)

# instance-id band stride per pool: far above any realistic per-pool id
# (FuDG decode ids sit at base+1000, scale-ups count from the band max)
BAND = 10_000

_HARDWARE = {"L20": GPU_L20, "A800": GPU_A800, "tpu-v5e": TPU_V5E_SIM}


class FleetTransport(Transport):
    """Fleet-level message plane fronting the per-pool transports: one
    ``attach_network`` degrades every pool, ``summary`` sums the fleet's
    own counters with the pools'."""

    def __init__(self, pool_transports: List[Transport]):
        super().__init__()
        self._pool_transports = list(pool_transports)

    def attach_network(self, network) -> None:
        super().attach_network(network)
        for t in self._pool_transports:
            t.attach_network(network)

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = dict(self.stats)
        for t in self._pool_transports:
            for k, v in t.stats.items():
                out[k] = out.get(k, 0) + v
        # pools mint iids in disjoint bands, but CTRL/POOL endpoints are
        # shared — merge per-link rows by key-sum
        merged: Dict[Any, Dict[str, int]] = {}
        for src_stats in ([self.link_stats]
                          + [t.link_stats for t in self._pool_transports]):
            for key, row in src_stats.items():
                acc = merged.setdefault(key, dict.fromkeys(row, 0))
                for k, v in row.items():
                    acc[k] = acc.get(k, 0) + v
        out["links"] = {f"{src}->{dst}": v
                        for (src, dst), v in sorted(merged.items())}
        return out


class FleetSystem:
    """Several model pools sharing one engine and one GPU budget."""

    base_name = "fleet"
    # flight-recorder hook (repro.obs.attach_tracer wires this plus every
    # member pool's own tracer slot)
    tracer = NULL_TRACER

    def __init__(self, spec, slo, *, hw: str = "L20", tp: int = 4,
                 pp: int = 1, router="pinned",
                 prices: Optional[Dict[str, float]] = None):
        # imported here: repro.baselines imports the simulator package,
        # which must stay importable without the fleet layer
        from repro.baselines import make_system
        if isinstance(spec, str):
            spec = parse_fleet(spec, devices_per_instance=tp * pp)
        if not isinstance(spec, FleetSpec):
            raise TypeError(f"cannot build a fleet from {type(spec)!r}")
        self.spec = spec
        self.hw = hw
        self.budget = spec.budget
        self.router = make_router(router)
        self.slo_set = as_slo_class_set(slo)
        self.prices = dict(prices or DEFAULT_GPU_PRICES)
        self.pools: List[Any] = []
        self.pool_names: List[str] = []
        self.pool_by_model: Dict[str, int] = {}
        self.pool_quality: List[float] = []   # pool model param count
        self.model_quality: Dict[str, float] = {}
        self.cost_per_token: List[float] = []
        self.routed_counts: List[int] = []
        for k, ps in enumerate(spec.pools):
            cfg = get_config(ps.model)
            cost = InstanceCostModel(cfg=cfg, hw=_HARDWARE[hw],
                                     tp=tp, pp=pp)
            pool = make_system(ps.strategy, cost, ps.n_instances,
                               slo, iid_base=k * BAND)
            self.pools.append(pool)
            self.pool_names.append(ps.name)
            self.pool_by_model.setdefault(ps.model, k)
            q = float(cfg.param_count())
            self.pool_quality.append(q)
            self.model_quality[ps.model] = q
            self.cost_per_token.append(
                dollars_per_token(cost, hw, self.prices))
            self.routed_counts.append(0)
        committed = sum(p.n_instances * self.pools[k].cost.devices
                       for k, p in enumerate(spec.pools))
        if committed > self.budget:
            raise ValueError(f"fleet commits {committed} GPUs over its "
                             f"budget of {self.budget}")
        self.pool_of_rid: Dict[int, int] = {}
        # rebalancer arrival tap: called as on_route(k, req, now) right
        # after the router assigns a pool; None keeps submit tap-free
        self.on_route: Optional[Callable[[int, Request, float], None]] = None
        self.transport = FleetTransport([p.transport for p in self.pools])
        self.spec_name: Optional[str] = None
        self.provenance = ""

    # ---------------- pool lookup -------------------------------------- #
    def pool_index_of_iid(self, iid: int) -> int:
        return iid // BAND

    def owner_of(self, inst) -> Any:
        """The pool system owning an instance (fault injector hook: the
        per-pool ``fault_stats`` must take the accounting)."""
        return self.pools[self.pool_index_of_iid(inst.iid)]

    @property
    def instances(self) -> List:
        return [i for p in self.pools for i in p.instances]

    @property
    def fault_stats(self) -> Dict[str, int]:
        """Fleet-wide fault accounting: the sum over pools.  Read-only
        by construction — mutators must go through ``owner_of``."""
        out: Dict[str, int] = {}
        for p in self.pools:
            for k, v in p.fault_stats.items():
                out[k] = out.get(k, 0) + v
        return out

    # ---------------- engine hooks ------------------------------------- #
    def submit(self, req: Request, now: float, engine) -> None:
        if req.model is not None and req.model not in self.model_quality:
            # capability rank of a tag no pool serves: its config's size
            # when registered, else 0 (no claim -> feasible anywhere)
            try:
                q = float(get_config(req.model).param_count())
            except KeyError:
                q = 0.0
            self.model_quality[req.model] = q
        k = self.router.route(req, self, now)
        self.pool_of_rid[req.rid] = k
        self.routed_counts[k] += 1
        trc = self.tracer
        if trc.enabled:
            trc.control(now, "fleet_route", (req.rid, k))
        if self.on_route is not None:
            self.on_route(k, req, now)
        self.pools[k].submit(req, now, engine)

    def on_slot_end(self, inst, kind: str, reqs: List[Request],
                    now: float, engine) -> None:
        self.pools[self.pool_index_of_iid(inst.iid)].on_slot_end(
            inst, kind, reqs, now, engine)

    # ---------------- scaling (protocol conformance) ------------------- #
    def _queue_per_inst(self, k: int) -> float:
        pool = self.pools[k]
        depth = len(pool.queue) + sum(len(i.pending) for i in pool.instances)
        return depth / max(1, len(pool.instances))

    def scale_up(self, engine=None):
        """Grow the most backlogged pool (deterministic tie: pool
        order).  The rebalancer drives per-pool actuators directly; this
        fleet-level hook serves the bare mitosis protocol."""
        k = max(range(len(self.pools)),
                key=lambda j: (self._queue_per_inst(j), -j))
        return self.pools[k].scale_up(engine)

    def scale_down(self, now=None, engine=None):
        """Shrink the calmest pool that can spare an instance."""
        order = sorted(range(len(self.pools)),
                       key=lambda j: (self._queue_per_inst(j), j))
        for k in order:
            if len(self.pools[k].instances) > 1:
                gone = self.pools[k].scale_down(now, engine)
                if gone is not None:
                    return gone
        return None

    # ---------------- fault hooks (delegated by id band) --------------- #
    def detach_instance(self, inst) -> None:
        self.owner_of(inst).detach_instance(inst)

    def fault_crash(self, inst, now: float, engine) -> List[Request]:
        return self.owner_of(inst).fault_crash(inst, now, engine)

    def fault_preempt(self, inst, notice: float, now: float,
                      engine) -> None:
        self.owner_of(inst).fault_preempt(inst, notice, now, engine)

    def fault_lost_requests(self, reqs: List[Request], now: float,
                            engine) -> None:
        # no owning instance: attribute by routing record (all of one
        # transfer's requests share a pool), pool 0 as a last resort
        k = self.pool_of_rid.get(reqs[0].rid, 0) if reqs else 0
        self.pools[k].fault_lost_requests(reqs, now, engine)

    # ---------------- self-description --------------------------------- #
    def describe(self) -> Dict[str, Any]:
        return {
            "strategy": self.spec_name or f"fleet:{self.router.name}",
            "base": "fleet",
            "router": self.router.describe(),
            "budget": self.budget,
            "pools": [{
                "name": self.pool_names[k],
                "model": ps.model,
                "strategy": ps.strategy,
                "n_instances": len(self.pools[k].instances),
                "devices_per_instance": self.pools[k].cost.devices,
                "dollars_per_token": round(self.cost_per_token[k], 10),
            } for k, ps in enumerate(self.spec.pools)],
            "provenance": self.provenance,
        }

    def fleet_summary(self) -> Dict[str, Any]:
        """JSON-safe routing/budget digest for result rows."""
        return {
            "router": self.router.name,
            "budget": self.budget,
            "committed": sum(len(p.instances) * p.cost.devices
                             for p in self.pools),
            "routed": dict(zip(self.pool_names, self.routed_counts)),
            "n_instances": {self.pool_names[k]: len(p.instances)
                            for k, p in enumerate(self.pools)},
        }
