"""Fleet routers: request -> pool assignment policies.

Every arriving request carries an optional ``model`` tag (trace
converters preserve it from the raw logs; ``MixedScenario`` tenants pin
it); the router turns that tag plus live pool state into a pool index.
Three policies, all pure functions of (request, fleet state) with
deterministic tie-breaks, so fleet cells replay bit-exactly:

* ``pinned`` — the model tag maps to the pool serving that model;
  untagged or unknown-model requests land on the first pool (the
  fleet's declared default).  The static-assignment baseline.
* ``cheapest-feasible`` — among pools whose model is at least as large
  as the requested one (parameter count, the capability proxy), pick
  the lowest decode $/token; unknown/untagged requests may land
  anywhere.  Ignores queues entirely: the cost-floor baseline.
* ``quality-tiered`` — prefer the pinned pool, but when its estimated
  queue wait already breaches the request's TTFT budget, spill to the
  cheapest other pool that is not itself breaching.  Trades model
  quality for latency only under pressure.

Routers see the fleet read-only; capacity changes are the rebalancer's
job (``repro.fleet.rebalance``).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.request import Request

ROUTERS: Dict[str, type] = {}


def register_router(cls):
    ROUTERS[cls.name] = cls
    return cls


class FleetRouter:
    """Base: ``route`` returns the pool index for one request."""

    name = "router"

    def route(self, req: Request, fleet, now: float) -> int:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name

    # ---------------- shared helpers ----------------------------------- #
    @staticmethod
    def _pinned_pool(req: Request, fleet) -> int:
        """Pool serving the request's model tag; pool 0 for untagged or
        unknown tags (the declared default pool)."""
        if req.model is not None:
            k = fleet.pool_by_model.get(req.model)
            if k is not None:
                return k
        return 0

    @staticmethod
    def _feasible(req: Request, fleet):
        """Pool indices capable of serving the request: pools whose
        model is at least as large as the requested one.  Untagged or
        unregistered model names are feasible everywhere (no capability
        claim to honor)."""
        want = fleet.model_quality.get(req.model, 0.0) \
            if req.model is not None else 0.0
        ks = [k for k in range(len(fleet.pools))
              if fleet.pool_quality[k] >= want]
        return ks or list(range(len(fleet.pools)))

    @staticmethod
    def _queue_wait_estimate(fleet, k: int, req: Request) -> float:
        """Crude head-of-line wait bound for pool ``k``: backlog depth
        per live instance times one prefill of the request's own length
        (queued work is tenant-correlated, so the request's own shape is
        the cheapest proxy for what sits ahead of it).  Deliberately
        model-based and state-light — the router must stay O(pools) per
        request and fully deterministic."""
        pool = fleet.pools[k]
        depth = len(pool.queue) + sum(len(i.pending) for i in pool.instances)
        n = max(1, sum(1 for i in pool.instances if i.alive))
        return (depth / n) * pool.cost.predict_prefill(req.prompt_len)


@register_router
class PinnedRouter(FleetRouter):
    name = "pinned"

    def route(self, req: Request, fleet, now: float) -> int:
        return self._pinned_pool(req, fleet)


@register_router
class CheapestFeasibleRouter(FleetRouter):
    name = "cheapest-feasible"

    def route(self, req: Request, fleet, now: float) -> int:
        return min(self._feasible(req, fleet),
                   key=lambda k: (fleet.cost_per_token[k], k))


@register_router
class QualityTieredRouter(FleetRouter):
    name = "quality-tiered"

    def route(self, req: Request, fleet, now: float) -> int:
        preferred = self._pinned_pool(req, fleet)
        budget = fleet.slo_set.for_request(req).ttft
        if self._queue_wait_estimate(fleet, preferred, req) <= budget:
            return preferred
        # preferred pool is drowning: spill to the cheapest other pool
        # that still has TTFT headroom (deterministic: price, then index)
        spill = [k for k in range(len(fleet.pools)) if k != preferred
                 and self._queue_wait_estimate(fleet, k, req) <= budget]
        if not spill:
            return preferred        # everyone is breaching: don't shuffle
        return min(spill, key=lambda k: (fleet.cost_per_token[k], k))


def make_router(spec) -> FleetRouter:
    """``"pinned"`` / ``"cheapest-feasible"`` / ``"quality-tiered"`` or a
    ``FleetRouter`` instance passed through."""
    if isinstance(spec, FleetRouter):
        return spec
    if not isinstance(spec, str) or spec not in ROUTERS:
        raise KeyError(f"unknown fleet router {spec!r}; expected one of "
                       f"{tuple(ROUTERS)}")
    return ROUTERS[spec]()
