"""Decode (KV-cache) attention kernel — the serving memory-bound hot spot.

One new token per sequence attends over its cached context.  Grid:
``(batch, kv_head, kv_blocks)`` with the kv dimension innermost and
sequential; online-softmax state for the G grouped query heads lives in
VMEM scratch.  The KV cache streams HBM->VMEM exactly once (this is the
traffic the roofline's decode memory term is made of); q is tiny and
stays resident.  Valid-length masking handles ragged batches (continuous
batching) and ring buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                  # (G, D)
    k = k_ref[0, 0]                  # (block_s, D)
    v = v_ref[0, 0]
    d = q.shape[-1]
    valid_len = len_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * (d ** -0.5)   # (G, block_s)
    pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < valid_len, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,          # (B, Hq, D) one new token per sequence
    k_cache: jnp.ndarray,    # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,    # (B,) valid cache entries per sequence
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv

    block_s = min(block_s, S)
    ns = -(-S // block_s)
    Sp = ns * block_s
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)

    qg = q.reshape(B, Hkv, G, D)
    kg = jnp.moveaxis(k_cache, 2, 1)      # (B, Hkv, Sp, D)
    vg = jnp.moveaxis(v_cache, 2, 1)
    len2 = lengths.astype(jnp.int32).reshape(B, 1)

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_s=block_s),
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, si: (b, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, si: (b, h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(len2, qg, kg, vg)
    return out.reshape(B, Hq, D)
