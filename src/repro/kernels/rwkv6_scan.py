"""RWKV-6 (Finch) WKV kernel: chunked linear attention with
data-dependent per-channel decay.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})

TPU-native tiling: grid ``(batch, head, t_blocks)`` — time innermost and
sequential, with the (D x D) per-head state carried in VMEM scratch.
Within a chunk the recurrence is re-associated into three MXU matmuls
(intra-chunk lower-triangular attention, carried-state contribution, and
the state update), exactly the chunked form of the reference; the decay
products are computed as exp of cumulative log sums on the VPU.  Chunk
length is MXU-aligned; D = head_dim (64/128) fits a lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                  block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0]        # (block_t, D) f32
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    lw = lw_ref[0, 0]      # log decay, <= 0
    u = u_ref[0]           # (1, D) bonus
    S = s_ref[...]         # (D, D)

    cum = jnp.cumsum(lw, axis=0)              # inclusive
    dec_in = jnp.exp(cum - lw)                # decay up to t-1
    r_dec = r * dec_in
    # carried-state contribution
    o_state = jax.lax.dot_general(
        r_dec, S, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (block_t, D)
    # intra-chunk strictly-causal attention
    kin = k * jnp.exp(-cum)
    att = jax.lax.dot_general(
        r_dec, kin, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (block_t, block_t)
    idx = jax.lax.broadcasted_iota(jnp.int32, att.shape, 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, att.shape, 1)
    att = jnp.where(idx > jdx, att, 0.0)
    o_intra = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # bonus diagonal term
    o_diag = jnp.sum(r * u * k, axis=-1, keepdims=True) * v

    o_ref[0, 0] = (o_state + o_intra + o_diag).astype(o_ref.dtype)

    # state update to the end of the chunk
    dec_all = jnp.exp(cum[-1])                        # (D,)
    k_end = k * jnp.exp(cum[-1][None, :] - cum)
    s_ref[...] = S * dec_all[:, None] + jax.lax.dot_general(
        k_end, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def rwkv6_scan(
    r: jnp.ndarray,        # (B, T, H, D) f32
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,        # (B, T, H, D) decay in (0, 1)
    u: jnp.ndarray,        # (H, D) bonus
    *,
    block_t: int = 128,
    interpret: bool = False,
):
    """Returns (o: (B,T,H,D) f32, final_state: (B,H,D,D) f32)."""
    B, T, H, D = r.shape
    block_t = min(block_t, T)
    nt = -(-T // block_t)
    Tp = nt * block_t
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)   # identity decay

    # head-major layout
    rm = jnp.moveaxis(r, 2, 1)      # (B, H, Tp, D)
    km = jnp.moveaxis(k, 2, 1)
    vm = jnp.moveaxis(v, 2, 1)
    lw = jnp.log(jnp.maximum(jnp.moveaxis(w, 2, 1), 1e-12))

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    spec = pl.BlockSpec((1, 1, block_t, D), lambda b, h, ti: (b, h, ti, 0))
    o = pl.pallas_call(
        functools.partial(_rwkv6_kernel, block_t=block_t),
        grid=(B, H, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, D), lambda b, h, ti: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(rm, km, vm, lw, u.astype(jnp.float32))
    o = jnp.moveaxis(o, 1, 2)[:, :T]

    # final state is recomputed cheaply on the host path when needed by
    # decode; here we return it via a second scan-free reduction
    return o


def rwkv6_scan_with_state(r, k, v, w, u, *, block_t: int = 128,
                          interpret: bool = False):
    """Convenience wrapper also returning the final state (B,H,D,D),
    computed with the same chunked math in jnp (cheap: one pass)."""
    from repro.models.layers import rwkv6_chunked_jnp
    o = rwkv6_scan(r, k, v, w, u, block_t=block_t, interpret=interpret)
    _, s = rwkv6_chunked_jnp(r, k, v, w, u, chunk=block_t)
    return o, s
