"""RG-LRU linear-recurrence kernel (RecurrentGemma/Griffin hot spot).

h_t = exp(log_a_t) * h_{t-1} + b_t, elementwise over channels.

TPU-native tiling: grid ``(batch, d_blocks, t_blocks)`` — time innermost
and sequential, carrying the channel-block state h in VMEM scratch; the
channel dimension is lane-aligned (block_d multiple of 128) and each
(log_a, b) tile streams HBM->VMEM once.  The in-block time loop is a
``fori_loop`` over VPU elementwise ops (this recurrence has no matmul, so
the MXU is idle by construction — the kernel exists to keep the scan OFF
the XLA while-loop path, which would round-trip h through HBM every
step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(la_ref, b_ref, h0_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[0, :] = h0_ref[0, :].astype(jnp.float32)

    la = la_ref[0, ...]       # (block_t, block_d) f32
    b = b_ref[0, ...]

    # log-depth in-VMEM scan over the time block (VPU elementwise ops):
    # (la1,b1) o (la2,b2) = (la1+la2, b1*exp(la2)+b2)
    def op(l, r):
        (la1, b1), (la2, b2) = l, r
        return la1 + la2, b1 * jnp.exp(la2) + b2

    cum_la, acc_b = jax.lax.associative_scan(op, (la, b), axis=0)
    h_in = h_ref[0, :]
    h_all = jnp.exp(cum_la) * h_in[None, :] + acc_b
    o_ref[0, ...] = h_all.astype(o_ref.dtype)
    h_ref[0, :] = h_all[-1]


def rglru_scan(
    log_a: jnp.ndarray,       # (B, T, d) f32
    b: jnp.ndarray,           # (B, T, d) f32
    h0: jnp.ndarray = None,   # (B, d) initial state
    *,
    block_t: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, T, d = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, d), jnp.float32)

    block_t = min(block_t, T)
    block_d = min(block_d, d)
    nt = -(-T // block_t)
    nd = -(-d // block_d)
    Tp, dp = nt * block_t, nd * block_d
    if (Tp, dp) != (T, d):
        # pad time with identity steps (log_a=0 would scale; use b=0 and
        # log_a=0 -> h unchanged), channels with zeros
        log_a = jnp.pad(log_a, ((0, 0), (0, Tp - T), (0, dp - d)))
        b = jnp.pad(b, ((0, 0), (0, Tp - T), (0, dp - d)))
        h0 = jnp.pad(h0, ((0, 0), (0, dp - d)))

    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d),
                         lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, block_d),
                         lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (bi, di)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_d),
                               lambda bi, di, ti: (bi, ti, di)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(log_a, b, h0)
    return out[:, :T, :d]
