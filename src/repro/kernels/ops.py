"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs step-by-step in Python/XLA so correctness is fully
testable; on a real TPU backend the same `pl.pallas_call` lowers to
Mosaic.  ``repro.models`` uses the pure-jnp path by default and these
kernels are opt-in hot-spot replacements (`use_pallas=True` plumbing in
the serving engine).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_scan_with_state


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k"))
def flash_prefill_op(q, k, v, *, causal=True, window=0, q_offset=0,
                     block_q=128, block_k=128):
    return flash_prefill(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, block_q=block_q, block_k=block_k,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention_op(q, k_cache, v_cache, lengths, *, block_s=512):
    return decode_attention(q, k_cache, v_cache, lengths, block_s=block_s,
                            interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_t", "block_d"))
def rglru_scan_op(log_a, b, h0=None, *, block_t=256, block_d=256):
    return rglru_scan(log_a, b, h0, block_t=block_t, block_d=block_d,
                      interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("block_t",))
def rwkv6_scan_op(r, k, v, w, u, *, block_t=128):
    return rwkv6_scan(r, k, v, w, u, block_t=block_t,
                      interpret=not _on_tpu())
