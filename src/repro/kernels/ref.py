"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode tests assert against)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B,T,Hq,D); k,v: (B,S,Hkv,D).  Naive masked softmax attention."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    q_pos = q_offset + jnp.arange(T)
    k_pos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    att = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can happen with window+offset): zero them
    att = jnp.where(jnp.any(mask, -1)[None, None, None, :, None], att, 0.0)
    o = jnp.einsum("bhgqs,bshd->bqhgd", att, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B,Hq,D); caches: (B,S,Hkv,D); lengths: (B,) valid entries."""
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (D ** -0.5)
    mask = jnp.arange(S)[None] < lengths[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    att = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", att, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)


def rglru_scan_ref(log_a, b, h0=None):
    """h_t = exp(log_a_t) h_{t-1} + b_t; inputs (B,T,d) f32, h0 (B,d)."""
    B, T, d = log_a.shape
    h = jnp.zeros((B, d), jnp.float32) if h0 is None else h0.astype(
        jnp.float32)
    outs = []
    for t in range(T):   # deliberately naive: the oracle
        h = jnp.exp(log_a[:, t]) * h + b[:, t]
        outs.append(h)
    return jnp.stack(outs, axis=1)


def rwkv6_ref(r, k, v, w, u, s0=None):
    """Naive per-step WKV6 recurrence.  All (B,T,H,D) f32; u (H,D);
    returns (o, final_state)."""
    B, T, H, D = r.shape
    S = (jnp.zeros((B, H, D, D), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))
    outs = []
    for t in range(T):
        kv = jnp.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        o = jnp.einsum("bhd,hd,bhd->bh", r[:, t], u.astype(jnp.float32),
                       k[:, t])[..., None] * v[:, t]
        o = o + jnp.einsum("bhd,bhde->bhe", r[:, t], S)
        S = S * w[:, t][..., None] + kv
        outs.append(o)
    return jnp.stack(outs, axis=1), S
