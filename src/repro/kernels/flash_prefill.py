"""Flash-attention prefill kernel (TPU Pallas).

TPU-native tiling: the (q-block, kv-block) loop runs on a 4-D grid
``(batch, kv_head, q_blocks, kv_blocks)`` with the kv dimension innermost
and sequential ("arbitrary"), carrying the online-softmax state (m, l,
acc) in VMEM scratch between kv steps.  Block sizes are MXU-aligned
(multiples of 128 on the contracting/lane dims).  GQA is handled by
folding the q-heads of one kv head into the q-block rows, so the KV cache
is never repeated in memory — the HBM->VMEM streams are q once, k/v once
per q-block.

Supports: causal masking, sliding-window attention, and a q_offset for
chunked prefill (queries at absolute positions q_offset + i attending to
a kv prefix).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, q_offset: int,
                  block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]              # (G*block_q, D) q rows for this kv head
    k = k_ref[0, 0]              # (block_k, D)
    v = v_ref[0, 0]              # (block_k, D)
    d = q.shape[-1]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * (d ** -0.5)  # (G*bq, bk)

    # absolute positions: q rows are G stacked copies of block_q queries
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % block_q
    q_pos = q_offset + qi * block_q + rows
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_prefill(
    q: jnp.ndarray,            # (B, T, Hq, D)
    k: jnp.ndarray,            # (B, S, Hkv, D)
    v: jnp.ndarray,            # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv

    block_q = min(block_q, T)
    block_k = min(block_k, S)
    nq = -(-T // block_q)
    nk = -(-S // block_k)
    Tp, Sp = nq * block_q, nk * block_k
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))

    # (B, Hkv, G, T, D): queries grouped under their kv head
    qg = jnp.moveaxis(q.reshape(B, Tp, Hkv, G, D), (2, 3), (1, 2))
    kg = jnp.moveaxis(k, 2, 1)       # (B, Hkv, Sp, D)
    vg = jnp.moveaxis(v, 2, 1)
    # fold G into q rows: (B, Hkv, G*T, D) with row = g*block... we instead
    # fold G into the q-block: rows [g*block_q + i] per block
    qg = qg.reshape(B, Hkv, G * Tp, D)

    grid = (B, Hkv, nq, nk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k, kv_len=S),
        grid=grid,
        in_specs=[
            # q rows for (b, h, qi): _group_rows lays the G query groups of
            # each q-block out contiguously, so block qi delivers the
            # G*block_q rows this kv head attends with
            pl.BlockSpec((1, 1, G * block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * Tp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((G * block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((G * block_q, D), jnp.float32),   # output accum
        ],
        interpret=interpret,
        **kwargs,
    )(_group_rows(qg, G, nq, block_q, Tp), kg, vg)

    out = _ungroup_rows(out, G, nq, block_q, Tp)    # (B, Hkv, G, Tp, D)
    out = jnp.moveaxis(out, (1, 2), (2, 3)).reshape(B, Tp, Hq, D)
    return out[:, :T]


def _group_rows(qg, G, nq, block_q, Tp):
    """(B,Hkv,G*Tp,D) time-major -> block-major rows so that q-block qi
    holds rows [g*block_q + i] contiguously."""
    B, Hkv, _, D = qg.shape
    x = qg.reshape(B, Hkv, G, nq, block_q, D)
    x = jnp.swapaxes(x, 2, 3)          # (B, Hkv, nq, G, block_q, D)
    return x.reshape(B, Hkv, nq * G * block_q, D)


def _ungroup_rows(out, G, nq, block_q, Tp):
    B, Hkv, _, D = out.shape
    x = out.reshape(B, Hkv, nq, G, block_q, D)
    x = jnp.swapaxes(x, 2, 3)          # (B, Hkv, G, nq, block_q, D)
    return x.reshape(B, Hkv, G, Tp, D)
