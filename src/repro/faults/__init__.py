"""Seeded, pure-sim-time fault model (ROADMAP item 5).

EcoServe's macro-instance orchestration claims graceful degradation
where FuDG systems — whose prefill->decode KV transfer depends on every
decode instance staying healthy — collapse.  This package makes instance
churn a first-class, reproducible experiment axis:

    FaultSchedule / make_fault_schedule
        declarative spec ("crash:t=14;spot:mtbf=20,notice=2") + cell
        seed -> a deterministic event list, fixed before the run
    FaultInjector
        pushes the schedule through the engine's event heap; resolves
        victims against the live pool at fire time
    FailurePolicy (drop / resubmit:K / migrate:K)
        the new slot on ``PolicySystemBase`` deciding the fate of
        in-flight requests when their instance goes away

``repro.simulator.metrics.run_once(faults=...)`` installs the injector
for a cell; the experiment runner exposes it as the seed-neutral
``faults=`` grid axis (same contract as ``autoscale=``: identical
arrivals across fault levels, so degradation deltas isolate the fault).
Depends only on ``repro.core`` — the simulator imports *us*.
"""
from repro.faults.injector import FaultInjector, SlowExecutor
from repro.faults.network import NETWORK_KINDS, NetworkModel
from repro.faults.policies import (FAILURE_POLICIES, DropFailure,
                                   FailurePolicy, MigrateFailure,
                                   ResubmitFailure, make_failure_policy)
from repro.faults.schedule import (FAULT_KINDS, FaultEvent, FaultSchedule,
                                   make_fault_schedule)

__all__ = [
    "FaultInjector", "SlowExecutor",
    "NETWORK_KINDS", "NetworkModel",
    "FAILURE_POLICIES", "DropFailure", "FailurePolicy", "MigrateFailure",
    "ResubmitFailure", "make_failure_policy",
    "FAULT_KINDS", "FaultEvent", "FaultSchedule", "make_fault_schedule",
]
