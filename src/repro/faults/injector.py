"""Fault injection through the engine's event loop.

``FaultInjector.attach`` pushes one engine event per scheduled fault;
at fire time the event resolves its victim against the live pool and
calls the system's fault hooks (``fault_crash`` / ``fault_preempt``) or
installs a slowdown wrapper.  Everything runs in sim-time on the shared
event heap — injection order against arrivals and completions is the
deterministic heap order, so faulted cells reproduce bit-exactly across
worker counts.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.instance import ExecutorModel, Instance
from repro.faults.network import NETWORK_KINDS, NetworkModel
from repro.faults.schedule import (FaultEvent, FaultSchedule,
                                   _schedule_seed)


class SlowExecutor:
    """Straggler wrapper: every predicted duration is multiplied by
    ``factor``.  The scheduler-side cost model (``predict_prefill`` on
    the macro scheduler) is untouched — the control plane does not know
    the instance degraded, exactly like a real slow node."""

    def __init__(self, inner: ExecutorModel, factor: float):
        self.inner = inner
        self.factor = factor
        # preserve the engine's O(1) summed-context fast path marker
        if hasattr(inner, "ctx_clamp"):
            self.ctx_clamp = inner.ctx_clamp

    def prefill_time(self, lens):
        return self.factor * self.inner.prefill_time(lens)

    def decode_time(self, *args, **kw):
        return self.factor * self.inner.decode_time(*args, **kw)

    def hybrid_time(self, *args, **kw):
        return self.factor * self.inner.hybrid_time(*args, **kw)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class FaultInjector:
    """Binds a ``FaultSchedule`` to a live (system, engine) pair."""

    def __init__(self, schedule: FaultSchedule, system):
        self.schedule = schedule
        self.system = system
        self.log: List[Dict] = []

    def attach(self, engine) -> "FaultInjector":
        if any(ev.kind in NETWORK_KINDS for ev in self.schedule.events):
            # the schedule carries network clauses: build the degradation
            # plane up front (seeded exactly like the schedule itself) so
            # every transfer from t=0 routes through the degraded path
            transport = getattr(self.system, "transport", None)
            if transport is not None and transport.network is None:
                transport.attach_network(NetworkModel(
                    _schedule_seed(self.schedule.spec,
                                   self.schedule.seed)))
        for ev in self.schedule.events:
            engine.push_call(ev.t, self._fire, ev, engine)
        return self

    # ------------------------------------------------------------------ #
    def _fire(self, ev: FaultEvent, engine) -> None:
        system = self.system
        live = [i for i in system.instances if i.alive]
        entry: Dict = {"t": round(engine.now, 6), "kind": ev.kind}
        if ev.kind in NETWORK_KINDS:
            self._fire_network(ev, engine, live, entry)
            self.log.append(entry)
            trc = engine.tracer
            if trc.enabled and "skipped" not in entry:
                trc.fault(engine.now, ev.kind, entry.get("iid"))
            return
        if ev.kind == "slow":
            victims = [i for i in live
                       if not isinstance(i.executor, SlowExecutor)]
            if not victims:
                entry["skipped"] = "no-victim"
                self.log.append(entry)
                return
            victim = victims[int(ev.pick * len(victims))]
            victim.set_executor(SlowExecutor(victim.executor, ev.factor))
            engine.push_call(engine.now + ev.duration,
                             self._end_slow, victim)
            # composite systems (repro.fleet) aggregate fault_stats from
            # their member pools: charge the stat to the victim's owner
            owner = system.owner_of(victim) \
                if hasattr(system, "owner_of") else system
            owner.fault_stats["slowdowns"] += 1
            entry.update(iid=victim.iid, factor=ev.factor,
                         dur=ev.duration)
        else:
            if len(live) <= 1:
                # never take the whole pool down: a zero-instance system
                # can only report vacuous metrics
                entry["skipped"] = "last-instance"
                self.log.append(entry)
                return
            victim = live[int(ev.pick * len(live))]
            entry["iid"] = victim.iid
            if ev.kind == "crash":
                lost = system.fault_crash(victim, engine.now, engine)
                entry["lost"] = len(lost)
            else:
                system.fault_preempt(victim, ev.notice, engine.now,
                                     engine)
                entry["notice"] = ev.notice
        self.log.append(entry)
        trc = engine.tracer
        if trc.enabled and "skipped" not in entry:
            trc.fault(engine.now, ev.kind, entry.get("iid"))

    def _fire_network(self, ev: FaultEvent, engine, live: List[Instance],
                      entry: Dict) -> None:
        """Toggle a degradation episode on the system transport's
        network plane (``duration == 0`` means until the end of the
        run).  Network events never touch ``fault_stats`` — the
        transport keeps its own counters — so instance-fault goldens
        keep their exact key sets."""
        transport = getattr(self.system, "transport", None)
        net = getattr(transport, "network", None)
        if net is None:
            entry["skipped"] = "no-transport"
            return
        if ev.kind == "partition":
            if not live:
                entry["skipped"] = "no-victim"
                return
            victim = live[int(ev.pick * len(live))]
            net.begin_partition(victim.iid)
            engine.push_call(engine.now + ev.duration,
                             net.end_partition, victim.iid)
            entry.update(iid=victim.iid, dur=ev.duration)
            return
        net.apply(ev.kind, ev.factor)
        entry["value"] = ev.factor
        if ev.duration > 0.0:
            engine.push_call(engine.now + ev.duration,
                             net.revert, ev.kind, ev.factor)
            entry["dur"] = ev.duration

    @staticmethod
    def _end_slow(victim: Instance) -> None:
        if isinstance(victim.executor, SlowExecutor):
            victim.set_executor(victim.executor.inner)

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict:
        """JSON-safe digest for result rows (pinned by the fault-scenario
        golden): the schedule identity, what actually fired, and the
        system's fault accounting."""
        applied: Dict[str, int] = {}
        for e in self.log:
            if "skipped" not in e:
                applied[e["kind"]] = applied.get(e["kind"], 0) + 1
        out = {
            "spec": self.schedule.spec,
            "n_scheduled": len(self.schedule.events),
            "applied": applied,
            "n_skipped": sum(1 for e in self.log if "skipped" in e),
            "log": self.log,
            "stats": dict(self.system.fault_stats),
        }
        transport = getattr(self.system, "transport", None)
        if transport is not None and transport.network is not None:
            # only when the schedule engaged the network plane: rows of
            # instance-fault-only cells keep their pre-transport shape
            out["transport"] = transport.summary()
        return out
