"""Seeded, pure-sim-time fault schedules.

A schedule is a list of ``FaultEvent``s fixed *before* the simulation
starts, built from a declarative spec string and a cell seed — never
from live simulation state — so a faulted cell is bit-reproducible
across worker counts and scheduling orders, exactly like the arrival
processes in ``repro.simulator.scenarios``.

Spec grammar: ``;``-separated clauses, each ``<kind>:<k>=<v>,...``:

* ``crash:t=14``            — one unannounced instance loss at t=14
* ``crash:mtbf=30``         — Poisson crashes, mean time between 30 s
* ``preempt:t=26,notice=2`` — spot preemption: 2 s notice, then loss
* ``spot:mtbf=20,notice=2`` — recurring spot preemptions (Poisson)
* ``slow:t=10,factor=3,dur=8`` — straggler: one instance runs 3x slower
  for 8 s (``slow:mtbf=...`` draws recurring slowdowns)

Network clauses (``repro.faults.network``) use positional arguments —
one magnitude plus an optional episode length:

* ``netdelay:ms[:dur]``   — every message +``ms`` milliseconds latency
* ``netloss:p[:dur]``     — per-message loss probability ``p``
* ``netdegrade:F[:dur]``  — link bandwidth divided by ``F``
* ``partition:dur``       — one instance cut off for ``dur`` seconds

With ``dur`` the episode starts at a seeded uniform time in
[0, duration - dur); without it the effect covers the whole run
(``FaultEvent.duration == 0`` encodes "until the end").  The magnitude
rides in ``FaultEvent.factor`` (``netdelay`` converted to seconds).

Victim choice is part of the schedule: every event carries a ``pick``
uniform in [0, 1) drawn at build time; the injector maps it onto the
live pool at fire time (``live[int(pick * len(live))]``).  The RNG is
seeded from CRC32(spec) XOR a Knuth-mixed cell seed — the same recipe
as ``repro.simulator.runner.cell_seed`` — so two cells differing only
in the fault spec draw different schedules while sharing arrivals.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Tuple

import numpy as np

from repro.faults.network import NETWORK_KINDS

FAULT_KINDS = ("crash", "preempt", "slow")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    t: float                 # sim-time the fault fires
    kind: str                # FAULT_KINDS or NETWORK_KINDS
    pick: float              # uniform [0,1) victim selector
    notice: float = 0.0      # preempt: seconds of warning before loss
    factor: float = 1.0      # slow: time multiplier; net: effect value
    duration: float = 0.0    # episode seconds (net: 0 = whole run)


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    spec: str
    seed: int
    events: Tuple[FaultEvent, ...]

    def __len__(self) -> int:
        return len(self.events)


def _schedule_seed(spec: str, seed: int) -> int:
    return (zlib.crc32(spec.encode()) ^ (seed * 2654435761)) & 0x7FFFFFFF


def _parse_net_clause(kind: str, argstr: str, clause: str
                      ) -> Tuple[str, List[float]]:
    """Positional network clause: ``kind:value[:dur]`` (``partition`` is
    duration-only)."""
    parts = [p.strip() for p in argstr.split(":")] if argstr else []
    try:
        args = [float(p) for p in parts if p]
    except ValueError:
        raise ValueError(f"malformed network clause {clause!r} "
                         f"(expected {kind}:<float>[:<dur>])")
    if len(args) != len(parts):
        raise ValueError(f"malformed network clause {clause!r} "
                         f"(empty argument)")
    want = (1,) if kind == "partition" else (1, 2)
    if len(args) not in want:
        raise ValueError(
            f"network clause {clause!r} takes "
            f"{'dur' if kind == 'partition' else 'value[:dur]'} "
            f"({' or '.join(map(str, want))} args), got {len(args)}")
    if kind == "netloss" and not 0.0 <= args[0] <= 1.0:
        raise ValueError(f"netloss probability must be in [0, 1], got "
                         f"{args[0]} in {clause!r}")
    if kind == "netdegrade" and args[0] < 1.0:
        raise ValueError(f"netdegrade factor must be >= 1, got "
                         f"{args[0]} in {clause!r}")
    if args[0] < 0.0 or (len(args) > 1 and args[1] <= 0.0):
        raise ValueError(f"network clause {clause!r} needs non-negative "
                         "value and positive dur")
    return kind, args


def _parse_clause(clause: str) -> Tuple[str, dict]:
    kind, _, argstr = clause.partition(":")
    kind = kind.strip()
    if kind == "spot":               # alias: recurring preemption
        kind = "preempt"
    if kind in NETWORK_KINDS:
        return _parse_net_clause(kind, argstr, clause)
    if kind not in FAULT_KINDS:
        raise KeyError(f"unknown fault kind {kind!r}; expected one of "
                       f"{FAULT_KINDS + NETWORK_KINDS} (or 'spot')")
    args = {}
    for part in filter(None, (p.strip() for p in argstr.split(","))):
        k, _, v = part.partition("=")
        if not v:
            raise ValueError(f"malformed fault option {part!r} in "
                             f"{clause!r} (expected k=v)")
        args[k.strip()] = float(v)
    if ("t" in args) == ("mtbf" in args):
        raise ValueError(f"fault clause {clause!r} needs exactly one of "
                         "t= (one-shot) or mtbf= (recurring)")
    known = {"t", "mtbf", "notice", "factor", "dur"}
    unknown = set(args) - known
    if unknown:
        raise ValueError(f"unknown fault options {sorted(unknown)} in "
                         f"{clause!r}; expected {sorted(known)}")
    return kind, args


def make_fault_schedule(spec: str, seed: int,
                        duration: float) -> FaultSchedule:
    """Materialize a spec into a deterministic event list over
    [0, duration).  Clauses draw from one shared RNG stream in clause
    order, so the whole schedule is a pure function of (spec, seed,
    duration)."""
    rng = np.random.default_rng(_schedule_seed(spec, seed))
    events: List[FaultEvent] = []
    for clause in filter(None, (c.strip() for c in spec.split(";"))):
        kind, args = _parse_clause(clause)
        if kind in NETWORK_KINDS:
            if kind == "partition":
                value, dur = 0.0, args[0]
            else:
                value = args[0] / 1000.0 if kind == "netdelay" else args[0]
                dur = args[1] if len(args) > 1 else 0.0   # 0 = whole run
            if dur > 0.0:
                # a bounded episode starts at a seeded uniform time
                t = float(rng.uniform(0.0, max(0.0, duration - dur)))
            else:
                t = 0.0
            events.append(FaultEvent(
                t=t, kind=kind, pick=float(rng.random()),
                factor=value, duration=dur))
            continue
        notice = args.get("notice", 0.0)
        factor = args.get("factor", 2.0)
        dur = args.get("dur", 5.0)
        if "t" in args:
            times = [args["t"]]
        else:
            times, t = [], 0.0
            while True:
                t += float(rng.exponential(args["mtbf"]))
                if t >= duration:
                    break
                times.append(t)
        for t in times:
            events.append(FaultEvent(
                t=float(t), kind=kind, pick=float(rng.random()),
                notice=notice, factor=factor, duration=dur))
    events.sort(key=lambda e: (e.t, e.kind, e.pick))
    return FaultSchedule(spec=spec, seed=seed, events=tuple(events))
