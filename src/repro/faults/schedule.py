"""Seeded, pure-sim-time fault schedules.

A schedule is a list of ``FaultEvent``s fixed *before* the simulation
starts, built from a declarative spec string and a cell seed — never
from live simulation state — so a faulted cell is bit-reproducible
across worker counts and scheduling orders, exactly like the arrival
processes in ``repro.simulator.scenarios``.

Spec grammar: ``;``-separated clauses, each ``<kind>:<k>=<v>,...``:

* ``crash:t=14``            — one unannounced instance loss at t=14
* ``crash:mtbf=30``         — Poisson crashes, mean time between 30 s
* ``preempt:t=26,notice=2`` — spot preemption: 2 s notice, then loss
* ``spot:mtbf=20,notice=2`` — recurring spot preemptions (Poisson)
* ``slow:t=10,factor=3,dur=8`` — straggler: one instance runs 3x slower
  for 8 s (``slow:mtbf=...`` draws recurring slowdowns)

Victim choice is part of the schedule: every event carries a ``pick``
uniform in [0, 1) drawn at build time; the injector maps it onto the
live pool at fire time (``live[int(pick * len(live))]``).  The RNG is
seeded from CRC32(spec) XOR a Knuth-mixed cell seed — the same recipe
as ``repro.simulator.runner.cell_seed`` — so two cells differing only
in the fault spec draw different schedules while sharing arrivals.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Tuple

import numpy as np

FAULT_KINDS = ("crash", "preempt", "slow")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    t: float                 # sim-time the fault fires
    kind: str                # "crash" | "preempt" | "slow"
    pick: float              # uniform [0,1) victim selector
    notice: float = 0.0      # preempt: seconds of warning before loss
    factor: float = 1.0      # slow: executor-time multiplier
    duration: float = 0.0    # slow: seconds the slowdown lasts


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    spec: str
    seed: int
    events: Tuple[FaultEvent, ...]

    def __len__(self) -> int:
        return len(self.events)


def _schedule_seed(spec: str, seed: int) -> int:
    return (zlib.crc32(spec.encode()) ^ (seed * 2654435761)) & 0x7FFFFFFF


def _parse_clause(clause: str) -> Tuple[str, dict]:
    kind, _, argstr = clause.partition(":")
    kind = kind.strip()
    if kind == "spot":               # alias: recurring preemption
        kind = "preempt"
    if kind not in FAULT_KINDS:
        raise KeyError(f"unknown fault kind {kind!r}; expected one of "
                       f"{FAULT_KINDS} (or 'spot')")
    args = {}
    for part in filter(None, (p.strip() for p in argstr.split(","))):
        k, _, v = part.partition("=")
        if not v:
            raise ValueError(f"malformed fault option {part!r} in "
                             f"{clause!r} (expected k=v)")
        args[k.strip()] = float(v)
    if ("t" in args) == ("mtbf" in args):
        raise ValueError(f"fault clause {clause!r} needs exactly one of "
                         "t= (one-shot) or mtbf= (recurring)")
    known = {"t", "mtbf", "notice", "factor", "dur"}
    unknown = set(args) - known
    if unknown:
        raise ValueError(f"unknown fault options {sorted(unknown)} in "
                         f"{clause!r}; expected {sorted(known)}")
    return kind, args


def make_fault_schedule(spec: str, seed: int,
                        duration: float) -> FaultSchedule:
    """Materialize a spec into a deterministic event list over
    [0, duration).  Clauses draw from one shared RNG stream in clause
    order, so the whole schedule is a pure function of (spec, seed,
    duration)."""
    rng = np.random.default_rng(_schedule_seed(spec, seed))
    events: List[FaultEvent] = []
    for clause in filter(None, (c.strip() for c in spec.split(";"))):
        kind, args = _parse_clause(clause)
        notice = args.get("notice", 0.0)
        factor = args.get("factor", 2.0)
        dur = args.get("dur", 5.0)
        if "t" in args:
            times = [args["t"]]
        else:
            times, t = [], 0.0
            while True:
                t += float(rng.exponential(args["mtbf"]))
                if t >= duration:
                    break
                times.append(t)
        for t in times:
            events.append(FaultEvent(
                t=float(t), kind=kind, pick=float(rng.random()),
                notice=notice, factor=factor, duration=dur))
    events.sort(key=lambda e: (e.t, e.kind, e.pick))
    return FaultSchedule(spec=spec, seed=seed, events=tuple(events))
