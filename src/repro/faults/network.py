"""Seeded, pure-sim-time network degradation plane.

The fault-spec grammar (``repro.faults.schedule``) gains four network
clauses — positional arguments, unlike the instance clauses' ``k=v``
form, because each is a single magnitude plus an optional episode
length:

* ``netdelay:ms[:dur]``   — every message gains ``ms`` milliseconds of
  latency (whole run, or an episode of ``dur`` seconds starting at a
  seeded uniform time)
* ``netloss:p[:dur]``     — each message is lost with probability ``p``
* ``netdegrade:F[:dur]``  — link bandwidth divides by ``F``
* ``partition:dur``       — one seeded victim instance is cut off from
  the coordination plane for ``dur`` seconds (messages to/from it are
  lost; routing fails over around it)

The injector applies these events to the system transport's
``NetworkModel`` — a bag of currently-active episode effects plus a
counter-keyed hash RNG.  Per-message randomness (loss draws, backoff
jitter) is a pure function of (schedule seed, message id, attempt), so
transport behaviour is bit-reproducible across runs and worker counts
regardless of how messages interleave with other events.
"""
from __future__ import annotations

import zlib
from typing import Dict, List

NETWORK_KINDS = ("netdelay", "netloss", "netdegrade", "partition")


class NetworkModel:
    """Currently-active degradation effects + the deterministic RNG the
    transport draws from.  Episodes are toggled by injector events
    (``apply``/``revert``, ``begin_partition``/``end_partition``); the
    model itself holds no schedule."""

    def __init__(self, seed: int):
        self.seed = int(seed) & 0xFFFFFFFF
        self._delay = 0.0                # summed active netdelay (s)
        self._degrade = 1.0              # product of active netdegrade Fs
        self._loss_terms: List[float] = []   # active netloss probabilities
        self._partitioned: Dict[int, int] = {}   # iid -> episode count

    # ---------------- state reads --------------------------------------- #
    def delay(self) -> float:
        return self._delay

    def degrade(self) -> float:
        return self._degrade

    def loss(self) -> float:
        """Combined per-message loss probability of the active episodes
        (independent-loss composition: 1 - prod(1 - p))."""
        if not self._loss_terms:
            return 0.0
        keep = 1.0
        for p in self._loss_terms:
            keep *= 1.0 - p
        return 1.0 - keep

    def partitioned(self, iid: int) -> bool:
        return iid in self._partitioned

    # ---------------- episode toggles (fault injector) ------------------ #
    def apply(self, kind: str, value: float) -> None:
        if kind == "netdelay":
            self._delay += value
        elif kind == "netdegrade":
            self._degrade *= value
        elif kind == "netloss":
            self._loss_terms.append(value)
        else:
            raise KeyError(f"unknown network effect {kind!r}")

    def revert(self, kind: str, value: float) -> None:
        if kind == "netdelay":
            self._delay = max(0.0, self._delay - value)
        elif kind == "netdegrade":
            self._degrade = max(1.0, self._degrade / value)
        elif kind == "netloss":
            if value in self._loss_terms:
                self._loss_terms.remove(value)
        else:
            raise KeyError(f"unknown network effect {kind!r}")

    def begin_partition(self, iid: int) -> None:
        self._partitioned[iid] = self._partitioned.get(iid, 0) + 1

    def end_partition(self, iid: int) -> None:
        n = self._partitioned.get(iid, 0) - 1
        if n <= 0:
            self._partitioned.pop(iid, None)
        else:
            self._partitioned[iid] = n

    # ---------------- deterministic randomness -------------------------- #
    def draw(self, *key) -> float:
        """Uniform [0, 1) as a pure function of (seed, key): loss draws
        and backoff jitter are keyed by message id + attempt, never by a
        shared stream, so they are independent of event interleaving."""
        h = zlib.crc32(repr(key).encode(), self.seed)
        return (h & 0xFFFFFF) / float(1 << 24)
