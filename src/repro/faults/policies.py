"""Failure policies: what happens to in-flight requests when their
instance goes away.

``PolicySystemBase`` carries one ``FailurePolicy`` next to its queue /
admission / routing policies.  The system's fault hooks (``fault_crash``,
``fault_preempt``, ``scale_down``) detach the instance and hand the
affected requests here; the policy decides their fate:

* ``drop`` (default)  — unplanned losses are terminal: the request is
  marked FAILED and counts as an SLO miss.  Planned removals keep the
  pre-fault behaviour bit-exactly: the retiring instance drains its
  in-flight work in place.
* ``resubmit[:K]``    — lost requests return to the system queue with
  their ORIGINAL ``arrival_time`` (TTFT keeps charging the full wait,
  including the lost work) and a retry budget of K; past the budget
  they are dropped.  Planned removals requeue not-yet-prefilled work
  (nothing is lost — the KV was never built) and let decodes drain.
* ``migrate[:K]``     — spot preemption with a notice window: at the
  next slot boundary (slots are uninterruptible) the instance's decodes
  move to a live peer through the mitosis ``InstanceHandler`` path —
  serialized proxy, token counts intact, no re-prefill — and pending
  prefills requeue.  Unplanned crashes (no notice, KV gone) fall back
  to resubmission with budget K.

All hooks run in sim-time through the engine's event loop; none of them
consults a wall clock or an unseeded RNG, so faulted cells stay
bit-reproducible.
"""
from __future__ import annotations

from typing import List, Optional, Union

from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.obs.events import NULL_TRACER


def _fmt(x: float) -> str:
    return f"{x:g}"


class FailurePolicy:
    """Decides the fate of requests whose instance faulted or retired."""

    name = "failure"

    # ---- hook points (called by PolicySystemBase) --------------------- #
    def on_instance_fault(self, system, inst: Optional[Instance],
                          reqs: List[Request], now: float, engine) -> None:
        """Unplanned loss: ``reqs`` were on ``inst`` (already detached and
        dead) when it crashed or hit its preemption deadline.  ``inst`` is
        None for requests lost in transit (FuDG KV hand-off to a dead
        decode instance)."""
        raise NotImplementedError

    def on_notice(self, system, inst: Instance, deadline: float,
                  now: float, engine) -> None:
        """A preemption notice arrived: ``inst`` stops receiving new work
        now and dies at ``deadline``.  Default: drain what the window
        allows; leftovers reach ``on_instance_fault`` at the deadline."""

    def on_evacuation_slot(self, system, inst: Instance, now: float,
                           engine) -> None:
        """A slot just completed on an instance under evacuation (notice
        window or migrating planned removal) — the only moment in-flight
        work may legally move (slots are uninterruptible)."""

    def on_planned_removal(self, system, inst: Instance,
                           now: Optional[float], engine) -> None:
        """Contraction chose ``inst``: it left the routable pool but is
        still alive.  Default: drain in place (the pre-fault-layer
        behaviour, bit-exact)."""

    # ---- shared helpers ----------------------------------------------- #
    @staticmethod
    def _drop(system, req: Request) -> None:
        req.state = RequestState.FAILED
        req.instance_id = None
        system.fault_stats["dropped"] += 1
        # getattr: fault hooks also run against bare test stubs that
        # don't inherit PolicySystemBase's tracer attribute
        trc = getattr(system, "tracer", NULL_TRACER)
        if trc.enabled:
            trc.fail(trc.now(), req.rid, "dropped")

    def describe(self) -> str:
        return self.name


class DropFailure(FailurePolicy):
    """Terminal losses: faulted requests never finish and score as SLO
    misses.  The honest baseline — degradation curves under this policy
    measure raw capacity loss, with no retry machinery blurring it."""

    name = "drop"

    def on_instance_fault(self, system, inst, reqs, now, engine):
        for r in reqs:
            self._drop(system, r)


class ResubmitFailure(FailurePolicy):
    """Lost requests go back to the system queue (original arrival time,
    zeroed execution state) with a bounded retry budget."""

    name = "resubmit"

    def __init__(self, budget: float = 2.0):
        self.budget = int(budget)

    def describe(self) -> str:
        return f"{self.name}:{_fmt(self.budget)}"

    def _resubmit(self, system, req: Request, charge: bool = True) -> bool:
        """Return the request to the queue for a fresh admission attempt.
        ``charge`` spends a unit of retry budget (unplanned losses);
        planned evacuations of not-yet-prefilled work are free — no KV
        was lost, the request merely returns to the line it came from."""
        if charge:
            if req.retries >= self.budget:
                self._drop(system, req)
                return False
            req.retries += 1
            system.fault_stats["resubmitted"] += 1
        else:
            system.fault_stats["requeued"] += 1
        req.state = RequestState.QUEUED
        req.admitted_time = None
        req.first_token_time = None
        req.second_token_time = None
        req.finish_time = None
        req.tokens_generated = 0
        req.instance_id = None
        system.queue.append(req)
        trc = getattr(system, "tracer", NULL_TRACER)
        if trc.enabled:
            trc.requeue(trc.now(), req.rid)
        return True

    def on_instance_fault(self, system, inst, reqs, now, engine):
        for r in reqs:
            self._resubmit(system, r, charge=True)

    def on_planned_removal(self, system, inst, now, engine):
        # pending prefills lose nothing by requeueing (no KV built yet)
        # and regain access to the whole pool; decodes drain in place —
        # their KV is resident and killing it would waste finished work
        for r in list(inst.pending):
            inst.remove_pending(r)
            _clear_chunk_progress(inst, r)
            self._resubmit(system, r, charge=False)
        if engine is not None:
            system._drain_queue(now if now is not None else engine.now,
                                engine)


class MigrateFailure(ResubmitFailure):
    """Notice-window migration through the mitosis ``InstanceHandler``
    path: decodes move to a live peer with token counts intact; crashes
    (no notice) fall back to resubmission."""

    name = "migrate"

    def on_evacuation_slot(self, system, inst, now, engine):
        # slots are uninterruptible: this runs at a slot boundary, the
        # one moment the instance's lists are not captured by an
        # in-flight completion event
        from repro.core.mitosis import InstanceHandler
        for r in list(inst.pending):
            inst.remove_pending(r)
            _clear_chunk_progress(inst, r)
            self._resubmit(system, r, charge=False)
        targets = [i for i in system.instances
                   if i.alive and i.decode_here and i is not inst]
        tr = getattr(system, "transport", None)
        if tr is not None and tr.network is not None:
            targets = tr.filter_reachable(targets, now)
        for r in list(inst.decoding):
            if not targets:
                inst.remove_decoding(r)
                self._resubmit(system, r, charge=True)
                continue
            target = min(targets, key=lambda i: i.kv_tokens_used())
            if tr is not None and not tr.try_rpc(now, inst.iid, target.iid):
                # the handler round-trip failed on the degraded plane;
                # the request stays put — evacuation re-runs at the next
                # slot boundary and the notice deadline bounds the wait
                continue
            # the paper's <100 ms logical migration: the serialized proxy
            # crosses the scheduler boundary, not the instance state
            handler = InstanceHandler.for_instance(target)
            resolved = InstanceHandler.deserialize(
                handler.serialize()).resolve()
            inst.remove_decoding(r)
            r.instance_id = resolved.iid
            resolved.add_decoding(r)
            system.fault_stats["migrated"] += 1
            trc = getattr(system, "tracer", NULL_TRACER)
            if trc.enabled:
                trc.migrate(now, r.rid, inst.iid, resolved.iid)
            if engine is not None:
                engine.activate(resolved)
        if not inst.pending and not inst.decoding:
            system._evacuating.pop(inst.iid, None)

    def on_planned_removal(self, system, inst, now, engine):
        # evacuate at the next slot boundary instead of draining; with no
        # engine driving slots (bare scale_down in tests) this is a
        # drain-in-place no-op, same as the default
        system._evacuating[inst.iid] = float("inf")


def _clear_chunk_progress(inst: Instance, req: Request) -> None:
    """Forget partial chunked-prefill progress for a request leaving the
    instance (EcoServe-CP ``_chunk_progress`` / Sarathi ``_progress``):
    its KV prefix lives on this instance only, so a re-admission
    elsewhere restarts the prefill from scratch."""
    for attr in ("_chunk_progress", "_progress"):
        d = getattr(inst, attr, None)
        if d is not None:
            d.pop(req.rid, None)


# --------------------------------------------------------------------- #
# declarative construction (same shape as repro.core.policies)
# --------------------------------------------------------------------- #

FAILURE_POLICIES = {
    DropFailure.name: DropFailure,
    ResubmitFailure.name: ResubmitFailure,
    MigrateFailure.name: MigrateFailure,
}


def make_failure_policy(
        spec: Union[str, FailurePolicy]) -> FailurePolicy:
    """``"drop"`` / ``"resubmit[:K]"`` / ``"migrate[:K]"`` (``:K`` is the
    retry budget) or an instance (passed through)."""
    if isinstance(spec, FailurePolicy):
        return spec
    if isinstance(spec, str):
        name, _, arg = spec.partition(":")
        if name not in FAILURE_POLICIES:
            raise KeyError(f"unknown failure policy {name!r}; expected "
                           f"one of {tuple(FAILURE_POLICIES)}")
        cls = FAILURE_POLICIES[name]
        return cls(float(arg)) if arg else cls()
    raise TypeError(f"cannot build a failure policy from {spec!r}")
