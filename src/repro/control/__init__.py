"""Closed-loop autoscaling control plane (paper §3.5 made *reactive*).

The mitosis machinery (``repro.core.mitosis``) defines how the pool
grows and shrinks; this package decides *when*, from observed load:

    SignalCollector  -- engine/system events -> windowed load signals
    ScalingController -- signals -> scale decisions (target band +
                         hysteresis/cooldown; plus a trace-oblivious
                         threshold baseline for ablation)
    Actuator          -- decisions -> ``scale_up``/``scale_down`` with a
                         modeled provisioning delay, recorded on a
                         ``ScalingTimeline``
    ControlLoopHarness -- wires all three onto a live (system, engine)

``repro.simulator.metrics.run_once(control=...)`` installs the harness
for a cell; the experiment runner exposes it as the ``autoscale=`` grid
axis.  Depends only on ``repro.core`` — the simulator imports *us*.
"""
from repro.control.actuator import (Actuator, ControlLoopHarness,
                                    ScalingEvent, ScalingTimeline)
from repro.control.controller import (CONTROLLERS, ControllerConfig,
                                      ScalingController,
                                      TargetBandController,
                                      ThresholdController, make_controller)
from repro.control.signals import SignalCollector

__all__ = [
    "Actuator", "ControlLoopHarness", "ScalingEvent", "ScalingTimeline",
    "CONTROLLERS", "ControllerConfig", "ScalingController",
    "TargetBandController", "ThresholdController", "make_controller",
    "SignalCollector",
]
