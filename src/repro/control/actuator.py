"""Actuation: applying scale decisions through the serving system's
mitosis machinery, with a modeled provisioning delay.

``Actuator.apply`` turns a controller decision into real pool changes:

* **expand** — a new instance is *committed* immediately (the controller
  sees it in ``n_target`` so it cannot double-scale while provisioning)
  but only joins the pool ``provision_delay`` sim-seconds later, via an
  engine event that calls ``system.scale_up`` — which routes through the
  existing machinery (for EcoServe: ``RoutingPolicy.add_instance`` ->
  ``OverallScheduler.add_instance``, i.e. mitosis expansion/split,
  Fig. 7) and immediately retries the waiting queue against the new
  capacity;
* **contract** — ``system.scale_down`` runs at decision time (for
  EcoServe: ``OverallScheduler.remove_instance``, the Fig. 7
  contraction/merge path); the retired instance drains its in-flight
  work but receives no new requests, so no delay is modeled.

Every decision is recorded in a ``ScalingTimeline`` — (decision time,
effective time, direction, pool sizes, triggering signals) plus the
per-tick ``(t, n_live, n_target)`` trajectory — whose ``summary()`` is
JSON-safe and rides on result rows for the dynamic-scaling golden.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.control.controller import ControllerConfig, ScalingController
from repro.control.signals import SignalCollector


@dataclasses.dataclass
class ScalingEvent:
    t_decision: float
    t_effective: float
    action: str                     # "up" | "down" | "repair"
    n_before: int                   # live instances at decision time
    n_target: int                   # committed count after the decision
    queue_depth: float
    attainment_window: Optional[float]


@dataclasses.dataclass
class ScalingTimeline:
    events: List[ScalingEvent] = dataclasses.field(default_factory=list)
    trajectory: List[Dict[str, float]] = dataclasses.field(
        default_factory=list)

    def record_tick(self, now: float, n_live: int, n_target: int) -> None:
        self.trajectory.append(
            {"t": round(now, 6), "n": n_live, "n_target": n_target})

    def mean_instances(self, t0: float, t1: float) -> float:
        """Time-weighted mean live-instance count over [t0, t1].

        The trajectory is piecewise constant between control ticks; the
        value entering the window comes from the last tick at/before
        ``t0`` (the pool size does not reset at a window edge) and the
        final segment is carried to ``t1``, so the divisor is the full
        window, not just the inter-tick sub-span."""
        if t1 <= t0 or not self.trajectory:
            return 0.0
        # value in force at t0: last point at/before it, else the first
        # recorded value (the pool existed before the first tick too)
        current = self.trajectory[0]["n"]
        for p in self.trajectory:
            if p["t"] > t0:
                break
            current = p["n"]
        area, t = 0.0, t0
        for p in self.trajectory:
            if p["t"] <= t0:
                continue
            if p["t"] >= t1:
                break
            area += (p["t"] - t) * current
            t, current = p["t"], p["n"]
        area += (t1 - t) * current
        return area / (t1 - t0)

    def summary(self) -> Dict[str, Any]:
        """JSON-safe digest for result rows (the full trajectory is kept:
        the dynamic-scaling golden pins it bit-exactly)."""
        ns = [p["n"] for p in self.trajectory]
        return {
            "events": [{
                "t_decision": round(e.t_decision, 6),
                "t_effective": round(e.t_effective, 6),
                "action": e.action,
                "n_before": e.n_before,
                "n_target": e.n_target,
            } for e in self.events],
            "n_scale_ups": sum(1 for e in self.events
                               if e.action == "up"),
            "n_scale_downs": sum(1 for e in self.events
                                 if e.action == "down"),
            "n_min": min(ns) if ns else 0,
            "n_max": max(ns) if ns else 0,
            "n_final": ns[-1] if ns else 0,
            "trajectory": self.trajectory,
        }


class Actuator:
    """Applies controller decisions to a live (system, engine) pair."""

    def __init__(self, system, engine,
                 config: ControllerConfig, timeline: ScalingTimeline):
        self.system = system
        self.engine = engine
        self.config = config
        self.timeline = timeline
        self._provisioning = 0      # committed, not yet live
        self._cancelled = 0         # pending commissions revoked by "down"
        self._intent: Optional[int] = None   # controller's last target

    @property
    def n_target(self) -> int:
        return len(self.system.instances) + self._provisioning

    def apply(self, decision: int, now: float,
              signals: Dict[str, float]) -> bool:
        """Apply a decision; returns False when the system refused it
        (only contraction can be refused) so the caller can roll the
        controller's cooldown state back."""
        if decision == 0:
            return True
        n_live = len(self.system.instances)
        if decision > 0:
            self._provisioning += 1
            t_eff = now + self.config.provision_delay
            self.engine.push_call(t_eff, self._commission)
            self.timeline.events.append(ScalingEvent(
                t_decision=now, t_effective=t_eff, action="up",
                n_before=n_live, n_target=self.n_target,
                queue_depth=signals["queue_depth"],
                attainment_window=signals["attainment_window"]))
            return True
        if self._provisioning > 0:
            # a commission is still in flight: revoke it instead of
            # shrinking the live pool — otherwise the provisioning
            # instance joins anyway and the pool overshoots the target
            self._provisioning -= 1
            self._cancelled += 1
            self.timeline.events.append(ScalingEvent(
                t_decision=now, t_effective=now, action="down",
                n_before=n_live, n_target=self.n_target,
                queue_depth=signals["queue_depth"],
                attainment_window=signals["attainment_window"]))
            return True
        gone = self.system.scale_down(now, self.engine)
        if gone is None:            # routing refused (e.g. last decoder)
            return False
        self.timeline.events.append(ScalingEvent(
            t_decision=now, t_effective=now, action="down",
            n_before=n_live, n_target=self.n_target,
            queue_depth=signals["queue_depth"],
            attainment_window=signals["attainment_window"]))
        return True

    def note_intent(self, n: int) -> None:
        """Record the controller's committed pool size after a decision;
        ``repair`` re-provisions toward it when faults destroy capacity."""
        self._intent = n

    def repair(self, now: float, signals: Dict[str, float]) -> int:
        """Re-provision capacity lost to faults: when ``n_target`` has
        dropped *below* the controller's last committed intent — which
        only happens when instances died outside the control loop
        (crash/preemption), never from its own decisions — commission
        replacements.  Returns the number started."""
        if self._intent is None:
            return 0
        started = 0
        while self._intent - self.n_target > 0:
            self._provisioning += 1
            t_eff = now + self.config.provision_delay
            self.engine.push_call(t_eff, self._commission)
            self.timeline.events.append(ScalingEvent(
                t_decision=now, t_effective=t_eff, action="repair",
                n_before=len(self.system.instances),
                n_target=self.n_target,
                queue_depth=signals["queue_depth"],
                attainment_window=signals["attainment_window"]))
            started += 1
        return started

    def _commission(self) -> None:
        """Provisioning finished: the instance joins the pool and the
        waiting queue is retried against the new capacity."""
        if self._cancelled > 0:     # revoked by a later "down" decision
            self._cancelled -= 1
            return
        self._provisioning -= 1
        inst = self.system.scale_up(self.engine)
        trc = self.engine.tracer
        if trc.enabled:
            trc.control(self.engine.now, "commission",
                        getattr(inst, "iid", None))
        self.system._drain_queue(self.engine.now, self.engine)


class ControlLoopHarness:
    """Closed loop over a live simulation: taps arrivals via a ``submit``
    wrapper, samples signals every ``interval`` sim-seconds off the
    engine's tick callback, and actuates decisions.

    Install with ``attach``; the harness chains any pre-existing
    ``on_tick`` so callers that already observe the engine keep working.
    """

    def __init__(self, system, engine, controller: ScalingController,
                 collector: Optional[SignalCollector] = None):
        self.system = system
        self.engine = engine
        self.controller = controller
        cfg = controller.config
        self.collector = collector or SignalCollector(
            system.slo_set, window=max(5.0, 4 * cfg.interval))
        self.timeline = ScalingTimeline()
        self.actuator = Actuator(system, engine, cfg, self.timeline)
        self._next_tick = cfg.interval
        # last signal snapshot DELIVERED over the telemetry channel; on
        # a clean plane every snapshot arrives instantly, under network
        # faults snapshots may arrive late or not at all and the
        # controller decides on this (possibly stale) reading
        self._inbox: Optional[Dict[str, float]] = None

    def attach(self) -> "ControlLoopHarness":
        orig_submit = self.system.submit

        def submit(req, now, engine):
            self.collector.on_arrival(req, now)
            orig_submit(req, now, engine)

        self.system.submit = submit
        prev_tick = self.engine.on_tick

        def on_tick(now: float):
            if prev_tick is not None:
                prev_tick(now)
            self._maybe_control(now)

        self.engine.on_tick = on_tick
        return self

    def _maybe_control(self, now: float) -> None:
        # at most one decision per control period, evaluated at the time
        # of the first event past the period boundary — signals always
        # describe the system state that actually exists at ``now``, and
        # commissioned instances always land strictly in the future
        if now < self._next_tick:
            return
        snap = self.collector.snapshot(self.system, self.engine, now)
        trc = self.engine.tracer
        if trc.enabled:
            trc.control(now, "snapshot", round(snap.get("queue_depth",
                                                        0.0), 6))
        transport = getattr(self.system, "transport", None)
        if transport is not None and transport.network is not None:
            # telemetry crosses the degraded plane: the snapshot may be
            # dropped (the controller keeps deciding on its last
            # delivered one) or arrive a network delay late
            fate, d = transport.snapshot_channel(now)
            if fate == "ok":
                self._inbox = snap
            elif fate == "delay":
                self.engine.push_call(now + d, self._receive_snapshot,
                                      snap)
        else:
            self._inbox = snap
        if self._inbox is None:
            # nothing ever arrived (first snapshots all lost): no basis
            # to decide on, but the tick cadence must not stall
            self.timeline.record_tick(now, len(self.system.instances),
                                      self.actuator.n_target)
            self._next_tick = now + self.controller.config.interval
            return
        signals = dict(self._inbox)
        signals["stale"] = now - self._inbox["t"]
        # replace capacity lost to faults first (n_target below the last
        # committed intent) so the controller decides against the pool it
        # actually asked for; a no-op in fault-free runs
        self.actuator.repair(now, signals)
        decision = self.controller.decide(signals, self.actuator.n_target)
        if trc.enabled:
            trc.control(now, "decision", decision)
        if not self.actuator.apply(decision, now, signals):
            # contraction refused: the pool did not change, so the
            # controller must not sit out a cooldown for it
            self.controller.on_down_refused()
        self.actuator.note_intent(self.actuator.n_target)
        self.timeline.record_tick(now, len(self.system.instances),
                                  self.actuator.n_target)
        self._next_tick = now + self.controller.config.interval

    def _receive_snapshot(self, snap: Dict[str, float]) -> None:
        """A delayed telemetry snapshot finally arrived; keep the newest
        reading (a slower older one must not overwrite a fresher one)."""
        if self._inbox is None or snap["t"] >= self._inbox["t"]:
            self._inbox = snap
