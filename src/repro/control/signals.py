"""Windowed load signals for the autoscaling control plane.

``SignalCollector`` taps the two places load becomes observable in a
serving system — request arrivals (via a ``submit`` wrapper the harness
installs) and request completions (read incrementally off
``engine.finished``) — and folds them into the small set of signals the
``ScalingController`` consumes:

* ``rate_ewma`` — an event-driven exponentially-weighted arrival-rate
  estimate (each arrival bumps a decayed counter; no fixed bin edges, so
  the estimate is exact under any arrival pattern and fully
  deterministic given the event sequence);
* ``queue_depth`` — system-level waiting queue plus per-instance
  admitted-but-unprefilled backlog (requests, not tokens: the controller
  reasons in requests per instance);
* ``attainment_window`` — per-class SLO attainment over requests that
  *finished* in the trailing ``window`` seconds, reduced to the
  min-over-classes scalar (same worst-tenant discipline as the goodput
  search) — None until the first completion lands;
* ``kv_occupancy`` — aggregate KV-token utilization across instances.

Everything here is pure simulation-time bookkeeping: no wall clock, no
RNG, so a control loop driven by these signals is bit-reproducible.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.request import Request
from repro.core.slo import SLOClassSet, request_meets_slo


class SignalCollector:
    """Folds arrival/completion events into the controller's signals."""

    def __init__(self, slo_set: SLOClassSet, window: float = 10.0,
                 ewma_tau: float = 8.0, min_samples: int = 8):
        assert window > 0 and ewma_tau > 0
        self.slo_set = slo_set
        self.window = window
        self.ewma_tau = ewma_tau
        self.min_samples = min_samples
        self._rate = 0.0               # decayed arrivals / tau
        self._rate_t = 0.0             # time of last EWMA update
        self._arrivals = 0
        # (finish_time, met_slo, slo_class) over the trailing window
        self._finished: Deque[Tuple[float, bool, str]] = deque()
        self._n_seen = 0               # prefix of engine.finished consumed

    # ---------------- event taps --------------------------------------- #
    def on_arrival(self, req: Request, now: float) -> None:
        self._decay_to(now)
        self._rate += 1.0 / self.ewma_tau
        self._arrivals += 1

    def consume_finished(self, finished: List[Request], now: float) -> None:
        """Fold completions the engine recorded since the last call into
        the sliding attainment window (incremental: ``engine.finished``
        is append-only)."""
        for r in finished[self._n_seen:]:
            met = request_meets_slo(r, self.slo_set.for_request(r))
            cls = r.slo_class if r.slo_class in self.slo_set.names \
                else self.slo_set.default
            self._finished.append((r.finish_time, met, cls))
        self._n_seen = len(finished)
        cutoff = now - self.window
        while self._finished and self._finished[0][0] < cutoff:
            self._finished.popleft()

    # ---------------- signal reads ------------------------------------- #
    def _decay_to(self, now: float) -> None:
        if now > self._rate_t:
            self._rate *= math.exp(-(now - self._rate_t) / self.ewma_tau)
            self._rate_t = now

    def rate_ewma(self, now: float) -> float:
        self._decay_to(now)
        return self._rate

    def attainment_window(self) -> Optional[float]:
        """Min-over-classes attainment over the trailing window; None
        until ``min_samples`` completions populate it — one straggler in
        a near-empty window must not read as an SLO collapse (or a
        single lucky request as perfect health).  The guard is
        *per-class*: a class with fewer than ``min_samples`` window
        completions is excluded from the min (its one straggler says
        nothing), and only when NO class qualifies is the whole signal
        None.  With a single class this is exactly the old global
        guard."""
        if len(self._finished) < self.min_samples:
            return None
        hits: Dict[str, int] = {}
        tot: Dict[str, int] = {}
        for _, met, cls in self._finished:
            tot[cls] = tot.get(cls, 0) + 1
            hits[cls] = hits.get(cls, 0) + (1 if met else 0)
        vals = [hits[c] / tot[c] for c in tot
                if tot[c] >= self.min_samples]
        return min(vals) if vals else None

    @staticmethod
    def queue_depth(system) -> int:
        """System queue + admitted-but-unprefilled instance backlog."""
        return len(system.queue) + sum(
            len(i.pending) for i in system.instances)

    @staticmethod
    def kv_occupancy(system) -> float:
        cap = sum(i.kv_capacity_tokens for i in system.instances)
        if cap <= 0:
            return 0.0
        return sum(i.kv_tokens_used() for i in system.instances) / cap

    def snapshot(self, system, engine, now: float) -> Dict[str, float]:
        """One controller-tick reading of every signal."""
        self.consume_finished(engine.finished, now)
        att = self.attainment_window()
        return {
            "t": now,
            "rate_ewma": self.rate_ewma(now),
            "queue_depth": float(self.queue_depth(system)),
            "kv_occupancy": self.kv_occupancy(system),
            "attainment_window": att,
            "arrivals_total": float(self._arrivals),
            "n_instances": float(len(system.instances)),
        }
