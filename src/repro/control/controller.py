"""Scaling controllers: when to grow or shrink the instance pool.

Two policies, both emitting ``-1 | 0 | +1`` decisions per control tick:

* ``TargetBandController`` (the closed-loop default) — target-band logic
  with hysteresis and per-direction cooldowns.  Scale up when the
  sliding-window attainment falls below the SLO target or the
  per-instance queue backlog breaches the band's upper edge; scale down
  only when attainment sits above a *higher* water mark AND the queue is
  near-empty AND KV occupancy is low — the asymmetric thresholds are the
  hysteresis gap that keeps a constant-rate trace from flapping.  A
  breach must persist for ``hold`` consecutive ticks before the
  controller acts, and each action arms that direction's cooldown.
* ``ThresholdController`` (the trace-oblivious ablation baseline) —
  reacts to the *instantaneous* queue depth against fixed thresholds:
  no EWMA, no attainment window, no hold counter, no cooldown.  It
  exists to show what the hysteresis machinery buys.

Decisions are pure functions of (signals, controller state), both fully
deterministic, so autoscaled simulation cells stay bit-reproducible.

Controllers reason about ``n_target`` — the instance count *including*
still-provisioning additions the ``Actuator`` has in flight — so a
provisioning delay cannot be mistaken for an unanswered breach and
double-scaled.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Shared knobs for both controller kinds (the threshold baseline
    reads only the subset it understands)."""

    interval: float = 2.0          # control period (sim-seconds)
    min_instances: int = 2
    max_instances: int = 8
    # target band (closed-loop controller)
    target_attainment: float = 0.9   # band floor: below this, scale up
    att_high: float = 0.98           # band ceiling: above this, may shrink
    att_safe: float = 0.97           # above this, a deep queue alone is
    #                                  NOT an up-breach (still in budget)
    queue_high: float = 8.0          # per-instance queued reqs forcing up
    queue_low: float = 4.0           # per-instance backlog allowing down
    kv_low: float = 0.5              # occupancy ceiling for scale-down
    # asymmetric hold: expansion answers a burst after one breaching
    # tick (under-capacity burns SLO immediately); contraction is the
    # risky direction, so it must see the calm persist
    hold_up: int = 1
    hold_down: int = 3
    cooldown_up: float = 4.0         # seconds after an expansion
    cooldown_down: float = 8.0       # seconds after a contraction
    # contraction-regret backoff: an expansion this soon after a
    # contraction means the shrink was wrong — double the effective
    # contraction cooldown (capped) so a rate with no stable pool size
    # inside the hysteresis band cannot sustain a limit cycle
    regret_window: float = 16.0
    regret_cap: float = 8.0          # max cooldown_down multiplier
    # staleness guard: with the telemetry channel degraded (network
    # faults dropping/delaying snapshots) a controller acting on a
    # reading older than this holds its last decision instead of
    # scaling on stale evidence
    staleness_limit: float = 6.0
    # threshold baseline
    threshold_up: float = 16.0       # absolute queue depth forcing up
    # actuation
    provision_delay: float = 1.5     # sim-seconds until a new instance
    #                                  starts taking traffic (modeled
    #                                  spin-up: weights load + warm-up)


class ScalingController:
    """Base: per-tick decide(); subclasses implement ``_decide``."""

    name = "controller"

    def __init__(self, config: ControllerConfig = None):
        self.config = config or ControllerConfig()
        self._last_up = -1e18
        self._last_down = -1e18
        self._breach_up = 0
        self._breach_down = 0
        self._down_penalty = 1.0     # contraction-regret multiplier

    def decide(self, signals: Dict[str, float], n_target: int) -> int:
        """-1 (contract), 0 (hold), or +1 (expand) — already clamped to
        the configured [min_instances, max_instances] pool bounds.
        Subclasses see the bounds too (via ``_can_up``/``_can_down``):
        a breach that CANNOT be acted on must not arm cooldowns, or a
        pool pinned at max would phantom-refresh its up-cooldown forever
        and never contract when the load passes."""
        d = self._decide(signals, n_target)
        if d > 0 and not self._can_up(n_target):
            return 0
        if d < 0 and not self._can_down(n_target):
            return 0
        return d

    def _can_up(self, n_target: int) -> bool:
        return n_target < self.config.max_instances

    def _can_down(self, n_target: int) -> bool:
        return n_target > self.config.min_instances

    def on_down_refused(self) -> None:
        """The actuator reports the system refused a contraction (e.g. a
        FuDG base protecting its last decoder): no instance was removed,
        so disarm the contraction cooldown — and with it the regret
        window — that ``_decide`` armed for a shrink that never
        happened.  Same contract as bound-clamped breaches: state must
        track what the pool actually did."""
        self._last_down = -1e18

    def _decide(self, signals: Dict[str, float], n_target: int) -> int:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class TargetBandController(ScalingController):
    """Closed-loop target band + hysteresis + per-direction cooldown."""

    name = "band"

    def _decide(self, signals, n_target):
        cfg = self.config
        if signals.get("stale", 0.0) > cfg.staleness_limit:
            # the snapshot is too old to act on (dropped/delayed
            # telemetry): hold the last decision — and do NOT arm the
            # breach counters off evidence that no longer describes the
            # pool
            return 0
        now = signals["t"]
        att = signals["attainment_window"]
        q_per_inst = signals["queue_depth"] / max(1, n_target)

        # a deep queue is an up-breach only while attainment is unknown
        # or already slipping: EcoServe's temporal disaggregation runs a
        # healthy prefill backlog by design, and a pool that is meeting
        # its SLO with room (att >= att_safe) is not under-provisioned
        breach_up = ((att is not None and att < cfg.target_attainment) or
                     (q_per_inst > cfg.queue_high and
                      (att is None or att < cfg.att_safe)))
        # contraction requires positive evidence of health: an unknown
        # attainment window (too few completions) blocks downs — acting
        # on "no data" is how pools get shredded during quiet starts
        breach_down = (att is not None and att >= cfg.att_high and
                       q_per_inst <= cfg.queue_low and
                       signals["kv_occupancy"] < cfg.kv_low)

        self._breach_up = self._breach_up + 1 if breach_up else 0
        self._breach_down = self._breach_down + 1 if breach_down else 0

        if (self._can_up(n_target) and
                self._breach_up >= cfg.hold_up and
                now - self._last_up >= cfg.cooldown_up):
            if now - self._last_down < cfg.regret_window:
                # the recent shrink is what we're now undoing: back off
                self._down_penalty = min(cfg.regret_cap,
                                         self._down_penalty * 2.0)
            self._last_up = now
            self._breach_up = 0
            self._breach_down = 0
            return +1
        if (self._can_down(n_target) and
                self._breach_down >= cfg.hold_down and
                now - self._last_down >= cfg.cooldown_down *
                self._down_penalty and
                now - self._last_up >= cfg.cooldown_up):
            self._last_down = now
            self._breach_down = 0
            return -1
        return 0


class ThresholdController(ScalingController):
    """Trace-oblivious ablation baseline: instantaneous queue depth vs
    fixed thresholds; no windowing, no hold, no cooldown."""

    name = "threshold"

    def _decide(self, signals, n_target):
        q = signals["queue_depth"]
        if q > self.config.threshold_up:
            return +1
        if q == 0 and signals["kv_occupancy"] < self.config.kv_low:
            return -1
        return 0


CONTROLLERS = {
    TargetBandController.name: TargetBandController,
    ThresholdController.name: ThresholdController,
}


def make_controller(spec, config: Optional[ControllerConfig] = None
                    ) -> ScalingController:
    """``"band"`` / ``"threshold"`` (optionally ``"band:max=12,delay=2"``
    style overrides: ``min``, ``max``, ``interval``, ``delay``, ``hold``)
    or a ``ScalingController`` instance passed through."""
    if isinstance(spec, ScalingController):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"cannot build a controller from {spec!r}")
    name, _, args = spec.partition(":")
    if name not in CONTROLLERS:
        raise KeyError(f"unknown controller {name!r}; expected one of "
                       f"{tuple(CONTROLLERS)}")
    cfg = config or ControllerConfig()
    if args:
        keymap = {"min": "min_instances", "max": "max_instances",
                  "interval": "interval", "delay": "provision_delay",
                  "hold": "hold_down", "target": "target_attainment"}
        updates = {}
        for kv in args.split(","):
            k, _, v = kv.partition("=")
            if k not in keymap or not v:
                raise KeyError(f"unknown controller option {kv!r}; "
                               f"expected k=v with k in {tuple(keymap)}")
            field = keymap[k]
            typ = int if field in ("min_instances", "max_instances",
                                   "hold_down") else float
            updates[field] = typ(v)
        cfg = dataclasses.replace(cfg, **updates)
    return CONTROLLERS[name](cfg)
