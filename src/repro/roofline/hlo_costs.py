"""Instruction-level cost model over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
model whose layers run under ``lax.scan`` is undercounted by ~num_layers x.
This module re-derives per-device FLOPs / HBM bytes / collective wire bytes
by walking the HLO with a call-graph multiplier (entry=1, while bodies x
known_trip_count, fusions inherit the caller's multiplier).

  * FLOPs: every ``dot`` op: 2 * prod(output dims) * prod(lhs contracting
    dims) (+ convolutions if present, treated the same way).
  * HBM bytes: at the top level of entry/while bodies, each instruction
    reads its operands and writes its output once (fusion internals stay
    on-chip) — operand/output byte sizes resolved from a symbol table.
  * Collective wire bytes: ring factors (all-reduce 2x, others 1x).

CPU-backend caveat (documented in EXPERIMENTS.md): the CPU compiler
promotes bf16 dot inputs to f32, so some weight tensors appear at 2x the
bytes the TPU target would move.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

from repro.roofline.analysis import (_DTYPE_BYTES, _HEADER_RE, _WIRE_FACTOR,
                                     _shape_bytes, _split_computations,
                                     _while_trip_counts)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"(\(?[a-z0-9]+\[[^=]*?)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")

_SKIP_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "broadcast", "reshape", "copy-start", "copy-done",
}


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return "f32", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _headers(hlo: str) -> Dict[str, str]:
    """computation name -> header line (for param shapes)."""
    out = {}
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            out[m.group(1)] = line
    return out


def _symbols(header: str, body: str) -> Dict[str, str]:
    syms: Dict[str, str] = {}
    if header:
        for m in _PARAM_RE.finditer(header.split("->")[0]):
            syms[m.group(1)] = m.group(2)
    for line in body.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, shape = m.group(1), m.group(2)
            syms[name] = shape.split("{")[0].strip()
    return syms


def _call_multipliers(hlo: str, comps: Dict[str, str]) -> Dict[str, float]:
    """computation -> how many times it runs per step execution."""
    trips = _while_trip_counts(hlo, comps)
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            entry = m.group(1)
            break
    mult: Dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}

    def visit(name: str, m: float):
        if m <= mult.get(name, 0.0):
            return
        mult[name] = m
        body = comps.get(name, "")
        for cm in re.finditer(
                r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)", body):
            callee = cm.group(1)
            factor = trips.get(callee, 1) if callee in trips else 1
            # `body=` computations run trip-count times
            visit(callee, m * (factor if callee in trips else 1))

    visit(entry, 1.0)
    # computations never reached (dead) default to 0 -> skip them
    return mult


def _dot_flops(line: str, syms: Dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    out_shape = m.group(2).split("{")[0].strip()
    _, out_dims = _shape_dims(out_shape)
    # lhs operand: first token inside dot(...)
    dm = re.search(r"dot\(([^)]*)\)", line)
    if not dm:
        return 0.0
    first = dm.group(1).split(",")[0].strip()
    sm = _SHAPE_RE.match(first)
    if sm:
        lhs_shape = first.split("{")[0].split(" ")[0]
    else:
        name = first.lstrip("%")
        lhs_shape = syms.get(name, "")
    _, lhs_dims = _shape_dims(lhs_shape)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if cm and cm.group(1) and lhs_dims:
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contract *= lhs_dims[di]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contract


def _line_bytes(line: str, op: str, syms: Dict[str, str]) -> float:
    """HBM traffic estimate for a top-level instruction.

    Sliced accesses move only the slice, not the buffer:
      * dynamic-update-slice / scatter (incl. fusions named after them):
        2 x the small operands (read update + write update; the big buffer
        is aliased in place).
      * dynamic-slice / gather: 2 x output (read slice, write result).
    Everything else: output + all operands.
    """
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    name, out_shape_part = m.group(1), m.group(2)
    out_bytes = 0.0
    for s in _SHAPE_RE.finditer(out_shape_part):
        out_bytes += _shape_bytes(s.group(0))

    operands: List[float] = []
    pm = re.search(rf"{op}\(([^)]*)\)", line)
    if pm:
        for tok in pm.group(1).split(","):
            tok = tok.strip()
            if _SHAPE_RE.match(tok):
                operands.append(_shape_bytes(tok.split(" ")[0]))
            elif tok.startswith("%"):
                shape = syms.get(tok.lstrip("%"), "")
                if shape.startswith("("):
                    continue  # tuples: elements counted at their own defs
                operands.append(_shape_bytes(shape))

    tag = f"{name} {op}"
    if "dynamic-update-slice" in tag or "scatter" in tag:
        return 2.0 * sum(b for b in operands if b < out_bytes)
    if "dynamic-slice" in tag or "gather" in tag:
        return 2.0 * out_bytes
    return out_bytes + sum(operands)


@dataclasses.dataclass
class HloCosts:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    collectives: Dict[str, Dict[str, float]]
    trip_counted_computations: int


def analyze_hlo(hlo: str) -> HloCosts:
    comps = _split_computations(hlo)
    headers = _headers(hlo)
    mult = _call_multipliers(hlo, comps)
    trips = _while_trip_counts(hlo, comps)

    flops = 0.0
    hbm = 0.0
    wire = 0.0
    coll: Dict[str, Dict[str, float]] = {}

    # which computations are "top level" memory-wise: entry + while bodies
    mem_comps = set(trips)
    for line in hlo.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w\.\-]+)", line)
        if m:
            mem_comps.add(m.group(1))

    for name, body in comps.items():
        k = mult.get(name, 0.0)
        if k <= 0:
            continue
        syms = _symbols(headers.get(name, ""), body)
        count_mem = name in mem_comps
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            op = dm.group(3)
            if op == "dot" or op.startswith("convolution"):
                flops += k * _dot_flops(line, syms)
            kind = op[:-6] if op.endswith("-start") else op
            if kind in _WIRE_FACTOR:
                nbytes = 0.0
                for s in _SHAPE_RE.finditer(dm.group(2)):
                    nbytes += _shape_bytes(s.group(0))
                w = nbytes * _WIRE_FACTOR[kind] * k
                wire += w
                c = coll.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
                c["count"] += k
                c["wire_bytes"] += w
            if count_mem and op not in _SKIP_MEM_OPS:
                hbm += k * _line_bytes(line, op, syms)
    return HloCosts(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                    collectives=coll,
                    trip_counted_computations=len(trips))
