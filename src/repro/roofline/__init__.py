from repro.roofline.analysis import (  # noqa: F401
    HardwareSpec,
    TPU_V5E,
    collect_collectives,
    roofline_terms,
)
