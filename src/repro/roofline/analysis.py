"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs  / (chips x peak_FLOP/s)
    memory  term    = HLO_bytes  / (chips x HBM_bw)
    collective term = wire_bytes / (chips x link_bw)

``cost_analysis()`` provides HLO_FLOPs / HLO_bytes.  Collective bytes are
NOT in cost_analysis: we parse the post-SPMD HLO text, sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, multiply ops inside ``while`` bodies by the loop trip
count (layer scan), and convert to on-wire bytes with the standard ring
factors (all-reduce moves ~2x its operand; AG/RS/A2A ~1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# on-wire factor per collective kind (ring algorithms, large-N limit)
_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per ICI link
    hbm_bytes: float

TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,2560]' -> byte count (tuple shapes handled upstream)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Map computation name -> body text (entry included)."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = m.group(1), []
        elif line.startswith("}"):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name, cur_lines = None, []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _while_trip_counts(hlo: str, comps: Dict[str, str]) -> Dict[str, int]:
    """body-computation name -> trip count.

    XLA records ``backend_config={"known_trip_count":{"n":"36"}}`` on while
    ops after loop analysis; fall back to the condition's comparison
    constant, then 1."""
    trips: Dict[str, int] = {}
    for m in re.finditer(
            r"while\(%?[\w\.\-]+\), condition=%?([\w\.\-]+), "
            r"body=%?([\w\.\-]+)([^\n]*)", hlo):
        cond, body, rest = m.groups()
        count = None
        kt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
        if kt:
            count = int(kt.group(1))
        else:
            consts = re.findall(r"constant\((\d+)\)", comps.get(cond, ""))
            if consts:
                count = max(int(c) for c in consts)
        trips[body] = count or 1
    return trips


def collect_collectives(hlo: str) -> Tuple[float, List[dict]]:
    """Returns (total on-wire bytes per device, per-op detail list)."""
    comps = _split_computations(hlo)
    trips = _while_trip_counts(hlo, comps)

    ops: List[dict] = []
    total = 0.0
    for comp_name, body in comps.items():
        mult = trips.get(comp_name, 1)
        for line in body.splitlines():
            m = re.search(
                r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\(", line)
            if not m:
                continue
            shape_part, kind = m.groups()
            if shape_part.startswith("("):       # tuple shape
                shapes = re.findall(r"[a-z0-9]+\[[0-9,]*\]", shape_part)
            else:
                shapes = [shape_part]
            nbytes = sum(_shape_bytes(s) for s in shapes)
            wire = nbytes * _WIRE_FACTOR[kind] * mult
            total += wire
            ops.append({"kind": kind, "bytes": nbytes, "trips": mult,
                        "wire_bytes": wire, "computation": comp_name})
    return total, ops


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    hw: HardwareSpec = TPU_V5E,
) -> Dict[str, float]:
    """All inputs are PER-DEVICE quantities of the SPMD program (which is
    what cost_analysis / the partitioned HLO report), so the per-chip
    denominators apply directly."""
    compute_s = flops_per_device / hw.peak_flops
    memory_s = bytes_per_device / hw.hbm_bw
    collective_s = wire_bytes_per_device / hw.link_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }
