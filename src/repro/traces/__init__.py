"""Real-trace ingestion: public serving traces -> replayable scenarios.

``convert`` parses the public Azure-LLM-inference and BurstGPT CSV
schemas into our tagged JSONL records, ``transforms`` adapts them
(time-rescale, rate-normalize, clip, downsample) and ``stats`` audits
the result.  Two small checked-in excerpts under ``fixtures/`` make the
pipeline runnable offline; ``fixture_replay`` turns one into a
``TraceReplay`` the simulator drives directly, and the scenario factory
exposes them as the ``"trace:azure"`` / ``"trace:burstgpt"`` kinds.

CLI: ``python -m repro.traces <schema> <in.csv> <out.jsonl> [...]``.
"""
from __future__ import annotations

import pathlib
from typing import List, Optional

from repro.traces.convert import (BURSTGPT_CLASS_BY_MODEL, CONVERTERS,
                                  TraceDict, convert_azure,
                                  convert_burstgpt, records_to_jsonl,
                                  write_jsonl)
from repro.traces.stats import format_stats, trace_stats
from repro.traces.transforms import (clip_horizon, downsample,
                                     normalize_rate, rescale_time, span)

FIXTURE_DIR = pathlib.Path(__file__).parent / "fixtures"

# name -> (csv filename, converter schema): the two checked-in excerpts
FIXTURES = {
    "azure": ("azure_llm_excerpt.csv", "azure"),
    "burstgpt": ("burstgpt_excerpt.csv", "burstgpt"),
}


def load_fixture(name: str, **convert_kw) -> List[TraceDict]:
    """Convert a checked-in excerpt to trace records."""
    if name not in FIXTURES:
        raise KeyError(f"unknown trace fixture {name!r}; expected one of "
                       f"{tuple(FIXTURES)}")
    fname, schema = FIXTURES[name]
    with open(FIXTURE_DIR / fname) as f:
        return CONVERTERS[schema](f, **convert_kw)


def fixture_replay(name: str, rate: Optional[float] = None,
                   loop: bool = False, **convert_kw):
    """A ``TraceReplay`` over a checked-in excerpt, optionally
    rate-normalized to ``rate`` req/s — the object ``make_scenario``
    returns for the ``"trace:<name>"`` scenario kinds.  ``loop=True``
    tiles the excerpt to cover experiment windows longer than its
    (normalized) span; the scenario factory always asks for this, so a
    grid cell's whole horizon sees trace-shaped traffic."""
    # imported here: scenarios.make_scenario lazily imports *us* for
    # "trace:" kinds, so a module-level import would be a cycle
    from repro.simulator.scenarios import TraceReplay, _parse_trace
    records = load_fixture(name, **convert_kw)
    if rate is not None:
        records = normalize_rate(records, rate)
    return TraceReplay(f"trace:{name}",
                       _parse_trace(records_to_jsonl(records)), loop=loop)


__all__ = [
    "BURSTGPT_CLASS_BY_MODEL", "CONVERTERS", "FIXTURES", "FIXTURE_DIR",
    "TraceDict", "convert_azure", "convert_burstgpt", "records_to_jsonl",
    "write_jsonl", "trace_stats", "format_stats", "clip_horizon",
    "downsample", "normalize_rate", "rescale_time", "span",
    "load_fixture", "fixture_replay",
]
