"""Converters: public LLM-serving trace CSVs -> our tagged JSONL records.

Two public schemas, the ones DistServe/DynaServe-style evaluations use:

* **Azure LLM inference** (AzurePublicDataset, ``AzureLLMInferenceTrace_
  {code,conv}.csv``): ``TIMESTAMP,ContextTokens,GeneratedTokens`` with
  sub-second datetime stamps (up to 7 fractional digits);
* **BurstGPT** (``BurstGPT_*.csv``): ``Timestamp,Model,Request tokens,
  Response tokens,Total tokens,Log Type`` with numeric second stamps.

Both convert to the repo's trace-record dicts —
``{"arrival_time", "prompt_len", "output_len"[, "slo_class"][, "model"]}``
— with arrival times shifted so the first request lands at 0.0 and rows
sorted by arrival.  BurstGPT rows keep the raw upstream model name in
``"model"`` (the fleet router routes on it) independently of the
SLO-class mapping; records without the field serialize byte-identically
to the legacy three/four-key schema.  Records serialize to the same JSONL that
``TraceReplay.from_jsonl`` replays, so a converted trace drives any
simulation cell.  Rows with non-positive context tokens are dropped
(aborted requests); zero generated tokens clamp to 1 (the simulator
models at least the first output token).

Converters are pure line-iterators -> record-lists: no filesystem access
inside, so property tests can drive them with synthetic CSV text.
"""
from __future__ import annotations

import csv
import datetime
import json
from datetime import timezone
from typing import Dict, Iterable, List, Optional, Union

TraceDict = Dict[str, Union[float, int, str]]

AZURE_COLUMNS = ("TIMESTAMP", "ContextTokens", "GeneratedTokens")
BURSTGPT_COLUMNS = ("Timestamp", "Model", "Request tokens",
                    "Response tokens", "Total tokens", "Log Type")

# BurstGPT logs name the upstream model; map each to an SLO class so a
# converted trace can drive the multi-tenant stack (``class_by_model``).
BURSTGPT_CLASS_BY_MODEL = {"ChatGPT": "sharegpt", "GPT-4": "longbench"}


def parse_azure_timestamp(stamp: str) -> float:
    """Azure stamps carry up to 7 fractional digits; ``fromisoformat``
    (py3.10) takes at most 6, so normalize the fraction first.  The
    naive stamp is pinned to UTC — interpreting it in the converting
    machine's local zone would make the same CSV convert differently
    per machine, and a multi-day trace crossing a DST boundary would
    grow a spurious ±1 h gap mid-stream.  Returns POSIX seconds (the
    absolute epoch cancels when ``_finish`` rebases to t=0)."""
    stamp = stamp.strip()
    if "." in stamp:
        whole, frac = stamp.rsplit(".", 1)
        stamp = f"{whole}.{frac[:6].ljust(6, '0')}"
    dt = datetime.datetime.fromisoformat(stamp)
    return dt.replace(tzinfo=timezone.utc).timestamp()


def _finish(rows: List[TraceDict]) -> List[TraceDict]:
    """Sort by arrival and rebase so the first request lands at t=0."""
    rows.sort(key=lambda r: r["arrival_time"])
    if rows:
        t0 = rows[0]["arrival_time"]
        for r in rows:
            r["arrival_time"] = float(r["arrival_time"] - t0)
    return rows


def _require_columns(reader: csv.DictReader, expected, schema: str) -> None:
    have = tuple(reader.fieldnames or ())
    missing = [c for c in expected if c not in have]
    if missing:
        raise ValueError(f"{schema} CSV is missing column(s) {missing}; "
                         f"header was {have}")


def convert_azure(lines: Iterable[str],
                  slo_class: Optional[str] = None) -> List[TraceDict]:
    """Azure LLM-inference CSV lines -> trace records."""
    reader = csv.DictReader(lines)
    _require_columns(reader, AZURE_COLUMNS, "Azure LLM inference")
    rows: List[TraceDict] = []
    for rec in reader:
        try:
            t = parse_azure_timestamp(rec["TIMESTAMP"])
            prompt = int(float(rec["ContextTokens"]))
            out = int(float(rec["GeneratedTokens"]))
        except (TypeError, ValueError):
            continue                      # malformed row: skip, not crash
        if prompt <= 0:
            continue
        row: TraceDict = {"arrival_time": t, "prompt_len": prompt,
                          "output_len": max(1, out)}
        if slo_class:
            row["slo_class"] = slo_class
        rows.append(row)
    return _finish(rows)


def convert_burstgpt(lines: Iterable[str],
                     slo_class: Optional[str] = None,
                     class_by_model: bool = False) -> List[TraceDict]:
    """BurstGPT CSV lines -> trace records.  ``class_by_model`` tags each
    request with the SLO class mapped from its upstream model
    (``BURSTGPT_CLASS_BY_MODEL``); ``slo_class`` pins one tag for every
    row and wins over the mapping."""
    reader = csv.DictReader(lines)
    _require_columns(reader, BURSTGPT_COLUMNS, "BurstGPT")
    rows: List[TraceDict] = []
    for rec in reader:
        try:
            t = float(rec["Timestamp"])
            prompt = int(float(rec["Request tokens"]))
            out = int(float(rec["Response tokens"]))
        except (TypeError, ValueError):
            continue
        if prompt <= 0:
            continue
        row: TraceDict = {"arrival_time": t, "prompt_len": prompt,
                          "output_len": max(1, out)}
        tag = slo_class
        if tag is None and class_by_model:
            tag = BURSTGPT_CLASS_BY_MODEL.get((rec["Model"] or "").strip())
        if tag:
            row["slo_class"] = tag
        model = (rec["Model"] or "").strip()
        if model:
            # raw upstream model name, preserved independently of the
            # class mapping: the fleet router keys pools on it
            row["model"] = model
        rows.append(row)
    return _finish(rows)


CONVERTERS = {"azure": convert_azure, "burstgpt": convert_burstgpt}


def records_to_jsonl(records: Iterable[TraceDict]) -> List[str]:
    """One JSONL line per record, in the exact key order
    ``TraceReplay.from_jsonl`` documents (tag last, only when present)."""
    out = []
    for r in records:
        d = {"arrival_time": r["arrival_time"],
             "prompt_len": r["prompt_len"],
             "output_len": r["output_len"]}
        if r.get("slo_class"):
            d["slo_class"] = r["slo_class"]
        if r.get("model"):
            d["model"] = r["model"]
        out.append(json.dumps(d))
    return out


def write_jsonl(records: Iterable[TraceDict], path) -> None:
    with open(path, "w") as f:
        for line in records_to_jsonl(records):
            f.write(line + "\n")
