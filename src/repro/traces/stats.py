"""Trace audit summary: is a converted trace what you think it is?

``trace_stats`` reports the numbers that decide whether a trace exercises
the autoscaling claims — burstiness (CV of inter-arrival gaps; ~1 for
Poisson, >1 bursty), mean rate, and the length percentiles that size the
KV/prefill load — so a conversion or transform that silently mangled the
trace is visible before it burns a sweep.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.traces.convert import TraceDict


def trace_stats(records: List[TraceDict]) -> Dict[str, float]:
    out: Dict[str, float] = {"n_requests": float(len(records))}
    if not records:
        return out
    times = np.asarray([r["arrival_time"] for r in records], dtype=float)
    prompts = np.asarray([r["prompt_len"] for r in records], dtype=float)
    outs = np.asarray([r["output_len"] for r in records], dtype=float)
    span = float(times[-1] - times[0])
    out["span_s"] = span
    out["mean_rate"] = (len(records) - 1) / span if span > 0 else 0.0
    if len(times) >= 3:
        gaps = np.diff(times)
        mean_gap = gaps.mean()
        out["burstiness_cv"] = (float(gaps.std() / mean_gap)
                                if mean_gap > 0 else 0.0)
    for name, arr in (("prompt", prompts), ("output", outs)):
        out[f"{name}_mean"] = float(arr.mean())
        out[f"{name}_p50"] = float(np.percentile(arr, 50))
        out[f"{name}_p99"] = float(np.percentile(arr, 99))
    classes = sorted({str(r.get("slo_class", "")) for r in records
                      if r.get("slo_class")})
    if classes:
        out["slo_classes"] = ",".join(classes)   # type: ignore[assignment]
    return out


def format_stats(stats: Dict[str, float]) -> str:
    keys = ("n_requests", "span_s", "mean_rate", "burstiness_cv",
            "prompt_mean", "prompt_p50", "prompt_p99",
            "output_mean", "output_p50", "output_p99", "slo_classes")
    lines = []
    for k in keys:
        if k in stats:
            v = stats[k]
            sval = f"{v:.3f}" if isinstance(v, float) else str(v)
            lines.append(f"  {k:>14}: {sval}")
    return "\n".join(lines)
