"""Trace-converter CLI.

    PYTHONPATH=src python -m repro.traces azure in.csv out.jsonl \
        --rate 6.0 --horizon 120 --stats

    PYTHONPATH=src python -m repro.traces burstgpt in.csv out.jsonl \
        --class-by-model --sample 0.25 --seed 7

Converts a public-schema CSV (``azure`` | ``burstgpt``) to the repo's
tagged JSONL, applying transforms in the fixed order
downsample -> rescale/normalize -> clip, and prints the audit summary
(``--stats``) so the converted trace is reviewable before it drives a
sweep.  The output replays with ``TraceReplay.from_jsonl`` or the
``"replay"`` scenario kind (``trace=PATH``).
"""
from __future__ import annotations

import argparse
import sys

from repro.traces import (CONVERTERS, clip_horizon, downsample,
                          format_stats, normalize_rate, rescale_time,
                          trace_stats, write_jsonl)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.traces",
        description="Convert public LLM-serving trace CSVs to the "
                    "repo's tagged JSONL trace format.")
    ap.add_argument("schema", choices=sorted(CONVERTERS),
                    help="source CSV schema")
    ap.add_argument("csv_in", help="input CSV path")
    ap.add_argument("jsonl_out", help="output JSONL path")
    ap.add_argument("--slo-class", default=None,
                    help="tag every request with this SLO class")
    ap.add_argument("--class-by-model", action="store_true",
                    help="(burstgpt) tag requests by upstream model")
    ap.add_argument("--sample", type=float, default=None, metavar="F",
                    help="deterministically keep fraction F of rows")
    ap.add_argument("--seed", type=int, default=0,
                    help="downsampling seed")
    ap.add_argument("--rescale", type=float, default=None, metavar="X",
                    help="multiply arrival times by X (<1 compresses)")
    ap.add_argument("--rate", type=float, default=None, metavar="R",
                    help="normalize the mean arrival rate to R req/s "
                         "(overrides --rescale)")
    ap.add_argument("--horizon", type=float, default=None, metavar="T",
                    help="clip arrivals at/after T seconds (applied "
                         "after rate normalization)")
    ap.add_argument("--stats", action="store_true",
                    help="print the trace_stats audit summary")
    args = ap.parse_args(argv)

    kw = {}
    if args.slo_class:
        kw["slo_class"] = args.slo_class
    if args.class_by_model:
        if args.schema != "burstgpt":
            ap.error("--class-by-model applies to the burstgpt schema")
        kw["class_by_model"] = True
    with open(args.csv_in) as f:
        records = CONVERTERS[args.schema](f, **kw)
    if args.sample is not None:
        records = downsample(records, args.sample, seed=args.seed)
    if args.rate is not None:
        records = normalize_rate(records, args.rate)
    elif args.rescale is not None:
        records = rescale_time(records, args.rescale)
    if args.horizon is not None:
        records = clip_horizon(records, args.horizon)
    write_jsonl(records, args.jsonl_out)
    print(f"wrote {len(records)} records to {args.jsonl_out}")
    if args.stats:
        print(format_stats(trace_stats(records)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
