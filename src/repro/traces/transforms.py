"""Composable transforms over converted trace records.

Real traces never arrive at the rate or horizon an experiment wants:
Azure's production stream runs minutes between requests, BurstGPT spans
months.  These transforms adapt a converted record list to a simulation
cell while keeping it auditable (``repro.traces.stats.trace_stats``
before/after):

* ``rescale_time`` — multiply every arrival time (compress a day into a
  two-minute diurnal, the paper-style time compression);
* ``normalize_rate`` — rescale so the time-averaged rate hits a target
  req/s exactly (burstiness *shape* is preserved: a pure time dilation);
* ``clip_horizon`` — drop arrivals at/after a horizon;
* ``downsample`` — keep a fraction of rows, chosen by a seeded
  ``default_rng`` (deterministic: same seed, same excerpt), preserving
  arrival order.

All pure: input lists are never mutated, so transforms chain freely.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.traces.convert import TraceDict


def _copy_with_time(rec: TraceDict, t: float) -> TraceDict:
    out = dict(rec)
    out["arrival_time"] = float(t)
    return out


def span(records: List[TraceDict]) -> float:
    """Arrival span in seconds (first record is at 0 by construction)."""
    if not records:
        return 0.0
    return float(records[-1]["arrival_time"] - records[0]["arrival_time"])


def rescale_time(records: List[TraceDict],
                 factor: float) -> List[TraceDict]:
    """Multiply arrival times by ``factor`` (< 1 compresses)."""
    if factor <= 0:
        raise ValueError(f"time-rescale factor must be > 0, got {factor}")
    return [_copy_with_time(r, r["arrival_time"] * factor)
            for r in records]


def normalize_rate(records: List[TraceDict],
                   target_rate: float) -> List[TraceDict]:
    """Dilate time so the mean rate over the span is ``target_rate``
    req/s.  Needs >= 2 records (a 0/1-request trace has no rate)."""
    if target_rate <= 0:
        raise ValueError(f"target rate must be > 0, got {target_rate}")
    if len(records) < 2:
        return [dict(r) for r in records]
    current = (len(records) - 1) / span(records)
    return rescale_time(records, current / target_rate)


def clip_horizon(records: List[TraceDict],
                 horizon: float) -> List[TraceDict]:
    """Keep arrivals strictly before ``horizon`` seconds."""
    return [dict(r) for r in records if r["arrival_time"] < horizon]


def downsample(records: List[TraceDict], keep_fraction: float,
               seed: int = 0) -> List[TraceDict]:
    """Seeded uniform subsample, arrival order preserved."""
    if not 0.0 < keep_fraction <= 1.0:
        raise ValueError(
            f"keep_fraction must be in (0, 1], got {keep_fraction}")
    if keep_fraction == 1.0 or not records:
        return [dict(r) for r in records]
    rng = np.random.default_rng(seed)
    n_keep = max(1, int(round(keep_fraction * len(records))))
    idx = np.sort(rng.choice(len(records), size=n_keep, replace=False))
    return [dict(records[i]) for i in idx]
