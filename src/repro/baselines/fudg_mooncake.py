"""MoonCake-style inter-node FuDG baseline (paper §4.1 baseline 4).

Prefill and decode instances live on different nodes; KV caches travel
through a centralized pool: prefill node NIC -> pool -> decode node NIC,
i.e. ALWAYS two NIC traversals even when instances share a node (the
paper notes this explicitly).  Ethernet NICs are per-node FIFO links.
Same policy bundle as DistServe (immediate admission, prefill-partitioned
routing); only the ``_on_prefill_handoff`` transfer path differs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.core.system import PolicySystemBase
from repro.core.transport import POOL
from repro.simulator.cost_model import InstanceCostModel
from repro.simulator.engine import Link, SimulationEngine


class _PrefillInstance(Instance):
    decode_here = False


class MoonCakeSystem(PolicySystemBase):
    base_name = "mooncake"
    default_queue = "fifo"
    default_admission = "immediate"
    default_routing = "prefill-least-pending"

    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None,
                 prefill_ratio: float = 0.5,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, iid_base: int = 0):
        self.prefill_ratio = prefill_ratio
        super().__init__(cost, n_instances, slo,
                         queue_discipline=queue_discipline,
                         admission=admission, routing=routing,
                         failure=failure, iid_base=iid_base)

    def _build(self, n_instances: int) -> None:
        cost = self.cost
        n_prefill = max(1, round(n_instances * self.prefill_ratio))
        n_decode = max(1, n_instances - n_prefill)
        self.prefill_insts = [
            _PrefillInstance(self.iid_base + i, cost,
                             cost.kv_capacity_tokens())
            for i in range(n_prefill)
        ]
        # decode ids 1000 above the band base (see DistServe: disjoint
        # from prefill ids, inside the pool's fleet band)
        self.decode_insts = [
            Instance(self.iid_base + 1000 + i, cost,
                     cost.kv_capacity_tokens())
            for i in range(n_decode)
        ]
        self.instances = self.prefill_insts + self.decode_insts
        # one instance per node (the paper's deployment to ease bandwidth
        # contention); each node's NIC is a FIFO link
        self.nic: Dict[int, Link] = {
            inst.iid: Link(f"nic-{inst.iid}", cost.hw.inter_node_bw)
            for inst in self.instances
        }

    def scale_up(self, engine=None) -> Instance:
        inst = super().scale_up(engine)   # joins decode_insts via routing
        self.nic[inst.iid] = Link(f"nic-{inst.iid}",
                                  self.cost.hw.inter_node_bw)
        return inst

    # ------------------------------------------------------------------ #
    def _on_prefill_handoff(self, inst, reqs: List[Request], now,
                            engine: SimulationEngine) -> None:
        src_nic = self.nic[inst.iid]
        tr = self.transport
        for r in reqs:
            targets = [i for i in self.decode_insts if i.alive]
            if not targets:
                # every decode instance is dead: the FuDG cliff — the KV
                # cache has nowhere to land, so the request is lost
                self.fault_lost_requests([r], now, engine)
                continue
            reachable = tr.filter_reachable(targets, now)
            if reachable:
                # prefer reachable decoders; with every one unreachable
                # the pool upload still happens and the download's
                # retry/timeout machinery decides the request's fate
                targets = reachable
            target = min(targets, key=lambda i: i.kv_tokens_used())
            nbytes = self.cost.kv_transfer_bytes(r.prompt_len)

            def on_lost(r=r):
                # either NIC traversal exhausted its retry budget: the
                # KV never reached the decoder, the request flows
                # through the failure policy like any in-transit loss
                self.fault_lost_requests([r], engine.now, engine)

            def stage2(r=r, target=target, nbytes=nbytes, on_lost=on_lost):
                if not target.alive:
                    # decode target died while the KV sat in the pool
                    self.fault_lost_requests([r], engine.now, engine)
                    return
                dst_nic = self.nic[target.iid]

                def deliver(r=r, target=target):
                    if not target.alive:
                        self.fault_lost_requests([r], engine.now, engine)
                        return
                    r.state = RequestState.DECODING
                    if r.tokens_generated >= r.output_len:
                        r.state = RequestState.FINISHED
                        r.finish_time = engine.now
                        engine.finished.append(r)
                        return
                    target.add_decoding(r)
                    engine.activate(target)

                tr.transfer(engine, POOL, target.iid, nbytes, engine.now,
                            deliver, on_lost, link=dst_nic)  # pool -> decode

            tr.transfer(engine, inst.iid, POOL, nbytes, now,
                        stage2, on_lost, link=src_nic)       # prefill -> pool
