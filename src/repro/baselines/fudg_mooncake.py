"""MoonCake-style inter-node FuDG baseline (paper §4.1 baseline 4).

Prefill and decode instances live on different nodes; KV caches travel
through a centralized pool: prefill node NIC -> pool -> decode node NIC,
i.e. ALWAYS two NIC traversals even when instances share a node (the
paper notes this explicitly).  Ethernet NICs are per-node FIFO links.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.simulator.cost_model import InstanceCostModel
from repro.simulator.engine import Link, SimulationEngine


class _PrefillInstance(Instance):
    decode_here = False


class MoonCakeSystem:
    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None,
                 prefill_ratio: float = 0.5):
        self.cost = cost
        n_prefill = max(1, round(n_instances * prefill_ratio))
        n_decode = max(1, n_instances - n_prefill)
        self.prefill_insts = [
            _PrefillInstance(i, cost, cost.kv_capacity_tokens())
            for i in range(n_prefill)
        ]
        self.decode_insts = [
            Instance(1000 + i, cost, cost.kv_capacity_tokens())
            for i in range(n_decode)
        ]
        self.instances = self.prefill_insts + self.decode_insts
        # one instance per node (the paper's deployment to ease bandwidth
        # contention); each node's NIC is a FIFO link
        self.nic: Dict[int, Link] = {
            inst.iid: Link(f"nic-{inst.iid}", cost.hw.inter_node_bw)
            for inst in self.instances
        }

    def submit(self, req: Request, now: float,
               engine: SimulationEngine) -> None:
        inst = min(self.prefill_insts, key=lambda i: i.pending_tokens)
        inst.admit(req, now)
        engine.activate(inst)

    def on_slot_end(self, inst, kind, reqs: List[Request], now,
                    engine: SimulationEngine) -> None:
        if kind != "prefill_handoff":
            return
        src_nic = self.nic[inst.iid]
        for r in reqs:
            target = min(self.decode_insts, key=lambda i: i.kv_tokens_used())
            nbytes = self.cost.kv_transfer_bytes(r.prompt_len)
            t_up = src_nic.transfer(nbytes, now)           # prefill -> pool

            def stage2(r=r, target=target, nbytes=nbytes):
                dst_nic = self.nic[target.iid]
                t_down = dst_nic.transfer(nbytes, engine.now)  # pool -> decode

                def deliver(r=r, target=target):
                    r.state = RequestState.DECODING
                    if r.tokens_generated >= r.output_len:
                        r.state = RequestState.FINISHED
                        r.finish_time = engine.now
                        engine.finished.append(r)
                        return
                    target.add_decoding(r)
                    engine.activate(target)

                engine.push(t_down, deliver)

            engine.push(t_up, stage2)
