from repro.baselines.nodg_vllm import VLLMSystem          # noqa: F401
from repro.baselines.nodg_sarathi import SarathiSystem    # noqa: F401
from repro.baselines.fudg_distserve import DistServeSystem  # noqa: F401
from repro.baselines.fudg_mooncake import MoonCakeSystem  # noqa: F401
