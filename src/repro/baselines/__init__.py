"""Baseline serving systems + the uniform strategy factory.

``make_system`` is the single construction point for every
``ServingSystem`` variant (EcoServe/PaDG included) so the experiment
runner, benchmarks, and tests build them identically.
"""
from typing import Callable, Dict, Tuple

from repro.baselines.nodg_vllm import VLLMSystem          # noqa: F401
from repro.baselines.nodg_sarathi import SarathiSystem    # noqa: F401
from repro.baselines.fudg_distserve import DistServeSystem  # noqa: F401
from repro.baselines.fudg_mooncake import MoonCakeSystem  # noqa: F401


def _ecoserve(cost, n, slo, **kw):
    from repro.core.padg_system import EcoServeSystem
    return EcoServeSystem(cost, n, slo, **kw)


def _ecoserve_pp(cost, n, slo, **kw):
    from repro.core.padg_system import EcoServeSystem
    return EcoServeSystem(cost, n, slo, plus_plus=True, **kw)


_REGISTRY: Dict[str, Callable] = {
    # PaDG (the paper's system) and the beyond-paper admission variant
    "ecoserve": _ecoserve,
    "ecoserve++": _ecoserve_pp,
    # NoDG baselines (paper §4.1 baselines 1-2)
    "vllm": VLLMSystem,
    "sarathi": SarathiSystem,
    # FuDG baselines (paper §4.1 baselines 3-4)
    "distserve": DistServeSystem,
    "mooncake": MoonCakeSystem,
}

# default constructor kwargs matching the paper's Fig. 8 deployment
DEFAULT_KWARGS: Dict[str, Dict] = {
    "distserve": {"prefill_ratio": 0.25},
    "mooncake": {"prefill_ratio": 0.25},
}

STRATEGIES: Tuple[str, ...] = tuple(_REGISTRY)


def make_system(name: str, cost, n_instances: int, slo=None, **kw):
    """Construct a serving system by strategy name.

    ``slo`` may be a bare ``SLO`` or a multi-tenant ``SLOClassSet``
    (``repro.core.slo``): EcoServe routes each request against its own
    class budgets; the NoDG/FuDG baselines schedule SLO-blind either way
    (their policies never read it), but their results are still scored
    per class by the metrics layer.
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"expected one of {STRATEGIES}")
    merged = {**DEFAULT_KWARGS.get(name, {}), **kw}
    return _REGISTRY[name](cost, n_instances, slo, **merged)
