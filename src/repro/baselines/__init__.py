"""Declarative strategy layer: ``StrategySpec`` registry + the
``"base+policy"`` grammar + the uniform ``make_system`` factory.

Every serving strategy (EcoServe/PaDG included) is a ``StrategySpec``:
a named, paper-provenanced bundle of (system family, queue discipline,
admission policy, routing policy, constructor kwargs).  ``make_system``
is the single construction point the experiment runner, benchmarks, and
tests share; it resolves either a registered spec name (``"vllm"``,
``"ecoserve++"``) or a grammar composition ``"<base>+<modifier>"``
(``"vllm+priority"``, ``"mooncake+spf"``) — so new scheduling variants
are named in grid specs, not forked in code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.baselines.nodg_vllm import VLLMSystem          # noqa: F401
from repro.baselines.nodg_sarathi import SarathiSystem    # noqa: F401
from repro.baselines.fudg_distserve import DistServeSystem  # noqa: F401
from repro.baselines.fudg_mooncake import MoonCakeSystem  # noqa: F401


# family names are static so spec validation at registration time needs
# no imports; _families() resolves classes lazily (EcoServeSystem pulls
# in the full simulator package, which module-level register() calls
# must not trigger)
FAMILY_NAMES = ("ecoserve", "vllm", "sarathi", "distserve", "mooncake")


def _families() -> Dict[str, type]:
    from repro.core.padg_system import EcoServeSystem
    return {
        "ecoserve": EcoServeSystem,
        "vllm": VLLMSystem,
        "sarathi": SarathiSystem,
        "distserve": DistServeSystem,
        "mooncake": MoonCakeSystem,
    }


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """One named point in the policy design space.

    ``queue``/``admission``/``routing`` are declarative policy strings
    (``repro.core.policies``); None means "the family's default", so a
    spec only pins what it changes.  ``kwargs`` are frozen constructor
    kwargs for the family class; ``provenance`` records where the
    composition comes from (paper section, roadmap item).
    """

    name: str
    base: str                                  # family: ecoserve|vllm|...
    queue: Optional[str] = None
    admission: Optional[str] = None
    routing: Optional[str] = None
    failure: Optional[str] = None              # repro.faults FailurePolicy
    kwargs: Tuple[Tuple[str, Any], ...] = ()
    provenance: str = ""

    def __post_init__(self):
        if self.base not in FAMILY_NAMES:
            raise KeyError(f"unknown system family {self.base!r}")

    @property
    def ctor_kwargs(self) -> Dict[str, Any]:
        return dict(self.kwargs)

    def describe(self) -> Dict[str, Any]:
        """Self-documenting composition with None policy slots resolved
        to the family defaults and policy strings canonicalized through
        the policy constructors (so ``"backpressure"`` reads back with
        its effective parameter, exactly as a live system reports it);
        JSON/pickle-safe, threaded into runner rows and JSONL streams."""
        from repro.core.policies import (make_admission,
                                         make_queue_discipline,
                                         make_routing)
        cls = _families()[self.base]
        d = {
            "strategy": self.name,
            "base": self.base,
            "queue": make_queue_discipline(
                self.queue or cls.default_queue).describe(),
            "admission": make_admission(
                self.admission or cls.default_admission).describe(),
            "routing": make_routing(
                self.routing or cls.default_routing).describe(),
            "kwargs": self.ctor_kwargs,
            "provenance": self.provenance,
        }
        if self.failure is not None:
            # only when pinned, mirroring PolicySystemBase.describe():
            # pre-fault-layer golden rows keep their exact bundles
            from repro.faults import make_failure_policy
            d["failure"] = make_failure_policy(self.failure).describe()
        return d

    def build(self, cost, n_instances: int, slo=None, **overrides):
        """Construct the serving system.  ``overrides`` are caller
        constructor kwargs and win over the spec's frozen ``kwargs``
        (e.g. ``make_system("ecoserve", ..., queue_timeout_factor=2)``)."""
        cls = _families()[self.base]
        kw = {**self.ctor_kwargs, **overrides}
        if self.queue is not None:
            kw.setdefault("queue_discipline", self.queue)
        if self.admission is not None:
            kw.setdefault("admission", self.admission)
        if self.routing is not None:
            kw.setdefault("routing", self.routing)
        if self.failure is not None:
            kw.setdefault("failure", self.failure)
        system = cls(cost, n_instances, slo, **kw)
        system.spec_name = self.name
        system.provenance = self.provenance
        return system


# --------------------------------------------------------------------- #
# the registry (replaces the old ad-hoc name -> constructor dict)
# --------------------------------------------------------------------- #

REGISTRY: Dict[str, StrategySpec] = {}


def register(spec: StrategySpec) -> StrategySpec:
    REGISTRY[spec.name] = spec
    return spec


register(StrategySpec(
    name="ecoserve", base="ecoserve",
    provenance="EcoServe (arXiv:2504.18154) §3: PaDG temporal "
               "disaggregation, Alg. 1 rolling activation, Alg. 2 "
               "admission, mitosis scaling"))
register(StrategySpec(
    name="ecoserve++", base="ecoserve", kwargs=(("plus_plus", True),),
    provenance="beyond-paper EcoServe++: min-slack (conservative) "
               "admission protecting young decodes"))
register(StrategySpec(
    name="vllm", base="vllm",
    provenance="paper §4.1 baseline 1 (vLLM): NoDG replicas, "
               "prefill-priority continuous batching"))
register(StrategySpec(
    name="sarathi", base="sarathi",
    provenance="paper §4.1 baseline 2 (Sarathi-Serve): chunked-prefill "
               "hybrid batching, decode-priority"))
register(StrategySpec(
    name="distserve", base="distserve", kwargs=(("prefill_ratio", 0.25),),
    provenance="paper §4.1 baseline 3 (DistServe): intra-node FuDG, KV "
               "over the node's PCIe link"))
register(StrategySpec(
    name="mooncake", base="mooncake", kwargs=(("prefill_ratio", 0.25),),
    provenance="paper §4.1 baseline 4 (MoonCake): inter-node FuDG "
               "through a central KV pool (two NIC traversals)"))
# SLO-aware NoDG variants (ROADMAP: priority-queue baselines) — first
# clients of the composable policy API; also reachable via the grammar.
register(StrategySpec(
    name="vllm+priority", base="vllm",
    queue="slo-priority", admission="backpressure",
    provenance="ROADMAP SLO-aware NoDG: EDF queue over per-class TTFT "
               "deadlines + backpressure admission on vLLM machinery"))
register(StrategySpec(
    name="sarathi+priority", base="sarathi",
    queue="slo-priority", admission="backpressure",
    provenance="ROADMAP SLO-aware NoDG: EDF queue over per-class TTFT "
               "deadlines + backpressure admission on Sarathi machinery"))
# ROADMAP policy-composition slice (PR 5): a slack-guarded NoDG and a
# routing ablation, both also reachable through the grammar.
register(StrategySpec(
    name="vllm+slack", base="vllm", admission="kv-guard",
    provenance="ROADMAP policy composition: slack-guarded NoDG — "
               "admission holds KV headroom for each request's full "
               "footprint (the Algorithm 2 idea restated for a replica "
               "whose only hard constraint is KV memory)"))
register(StrategySpec(
    name="ecoserve+rr", base="ecoserve", routing="round-robin",
    provenance="ROADMAP policy composition: EcoServe machinery under "
               "blind round-robin placement — ablates Algorithm 1 "
               "inter-instance routing"))
# ROADMAP composition sweep (goodput grid): SLO-aware FuDG and a
# starvation-prone-but-fast PaDG queue.  Bundles mirror the grammar
# exactly (see test_registered_composition_and_grammar_agree): DistServe
# admits immediately, so a queue swap upgrades it to backpressure;
# EcoServe's timeout-forced admission survives, so only the queue moves.
register(StrategySpec(
    name="distserve+priority", base="distserve",
    queue="slo-priority", admission="backpressure",
    kwargs=(("prefill_ratio", 0.25),),
    provenance="ROADMAP composition sweep: EDF queue over per-class "
               "TTFT deadlines + backpressure admission on DistServe's "
               "intra-node FuDG machinery"))
register(StrategySpec(
    name="ecoserve+spf", base="ecoserve", queue="shortest-prompt",
    provenance="ROADMAP composition sweep: shortest-prompt-first queue "
               "on EcoServe PaDG machinery (TTFT-greedy, "
               "starvation-prone under mixed prompt lengths)"))

STRATEGIES: Tuple[str, ...] = tuple(REGISTRY)


# --------------------------------------------------------------------- #
# the "base+modifier" grammar
# --------------------------------------------------------------------- #

def _with_queue(queue: str) -> Callable[[StrategySpec], StrategySpec]:
    """Swap the queue discipline; if the base admits immediately (so its
    queue is always empty and a discipline could never act), upgrade to
    backpressure admission so the queue actually forms."""
    def apply(spec: StrategySpec) -> StrategySpec:
        cls = _families()[spec.base]
        effective = spec.admission or cls.default_admission
        admission = ("backpressure" if effective == "immediate"
                     else spec.admission)     # None keeps family default
        return dataclasses.replace(spec, queue=queue, admission=admission)
    return apply


def _with(field: str, value: str) -> Callable[[StrategySpec], StrategySpec]:
    """Swap one policy slot, other slots untouched.  (``_with_queue``
    stays separate: a queue swap also upgrades immediate admission.)"""
    def apply(spec: StrategySpec) -> StrategySpec:
        return dataclasses.replace(spec, **{field: value})
    return apply


MODIFIERS: Dict[str, Callable[[StrategySpec], StrategySpec]] = {
    "priority": _with_queue("slo-priority"),
    "spf": _with_queue("shortest-prompt"),
    "rr": _with("routing", "round-robin"),
    "slack": _with("admission", "kv-guard"),
    # fault-tolerance slot (repro.faults): fate of in-flight requests
    # when an instance crashes, is preempted, or retires
    "retry": _with("failure", "resubmit:2"),
    "migrate": _with("failure", "migrate"),
    "drop": _with("failure", "drop"),
}


def resolve_strategy(name: str) -> StrategySpec:
    """Registered name, or ``"<base>+<modifier>[+<modifier>...]"`` where
    ``<base>`` is any registered spec (longest match, so ``ecoserve++``
    composes too) and modifiers come from ``MODIFIERS``."""
    if name in REGISTRY:
        return REGISTRY[name]
    for base_name in sorted(REGISTRY, key=len, reverse=True):
        prefix = base_name + "+"
        if not name.startswith(prefix):
            continue
        mods = name[len(prefix):].split("+")
        if not all(m in MODIFIERS for m in mods):
            break
        spec = REGISTRY[base_name]
        for m in mods:
            spec = MODIFIERS[m](spec)
        # compositions must not carry the base's provenance verbatim —
        # a "+spf" variant is NOT the paper's baseline
        provenance = (f"{spec.provenance} — composed with "
                      f"+{'+'.join(mods)} via the strategy grammar")
        return dataclasses.replace(spec, name=name, provenance=provenance)
    raise KeyError(
        f"unknown strategy {name!r}; expected one of {STRATEGIES} or a "
        f"'<base>+<modifier>' composition with modifiers "
        f"{tuple(MODIFIERS)}")


def describe_strategy(name: str) -> Dict[str, Any]:
    """Resolve a strategy name and return its self-documenting policy
    bundle (worker-safe module-level function: the experiment runner
    attaches this to every result row, and the conformance tests map it
    across a spawn pool to prove the pickle round-trip)."""
    return resolve_strategy(name).describe()


def make_system(name: str, cost, n_instances: int, slo=None, **kw):
    """Construct a serving system by strategy name.

    ``slo`` may be a bare ``SLO`` or a multi-tenant ``SLOClassSet``
    (``repro.core.slo``): EcoServe routes each request against its own
    class budgets; the plain NoDG/FuDG baselines schedule SLO-blind
    either way, but SLO-aware compositions (``"vllm+priority"``) read it
    through their queue discipline — and every strategy's results are
    still scored per class by the metrics layer.
    """
    return resolve_strategy(name).build(cost, n_instances, slo, **kw)
