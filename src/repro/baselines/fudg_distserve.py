"""DistServe-style intra-node FuDG baseline (paper §4.1 baseline 3).

Each node hosts prefill instances and decode instances; the KV cache of
every request crosses the node's internal interconnect (PCIe on the
paper's L20 cluster — no NVLink) from prefill to decode instance.  TP
traffic and KV migration contend for that link; we model the contention
with a per-node FIFO link.  As a policy composition: immediate admission
over prefill-partitioned routing; the KV migration itself is the
family-specific ``_on_prefill_handoff`` hook.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.core.system import PolicySystemBase
from repro.simulator.cost_model import InstanceCostModel
from repro.simulator.engine import Link, SimulationEngine


class _PrefillInstance(Instance):
    decode_here = False


class DistServeSystem(PolicySystemBase):
    base_name = "distserve"
    default_queue = "fifo"
    default_admission = "immediate"
    default_routing = "prefill-least-pending"

    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None,
                 prefill_ratio: float = 0.5, n_nodes: int = None,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, iid_base: int = 0):
        """``n_instances`` total; a ``prefill_ratio`` fraction become
        prefill instances, the rest decode instances, colocated per node."""
        self.prefill_ratio = prefill_ratio
        self._n_nodes = n_nodes
        super().__init__(cost, n_instances, slo,
                         queue_discipline=queue_discipline,
                         admission=admission, routing=routing,
                         failure=failure, iid_base=iid_base)

    def _build(self, n_instances: int) -> None:
        cost = self.cost
        n_prefill = max(1, round(n_instances * self.prefill_ratio))
        n_decode = max(1, n_instances - n_prefill)
        self.prefill_insts: List[Instance] = [
            _PrefillInstance(self.iid_base + i, cost,
                             cost.kv_capacity_tokens())
            for i in range(n_prefill)
        ]
        # decode ids sit 1000 above the band base — far enough from any
        # realistic prefill count, and still inside the pool's band when
        # a fleet hands out bases in strides of 10000
        self.decode_insts: List[Instance] = [
            Instance(self.iid_base + 1000 + i, cost,
                     cost.kv_capacity_tokens())
            for i in range(n_decode)
        ]
        self.instances = self.prefill_insts + self.decode_insts
        # instances per node (both kinds share the node's PCIe link)
        per_node = max(1, cost.hw.devices_per_node // cost.devices)
        n_nodes = self._n_nodes or -(-n_instances // per_node)
        self.links: Dict[int, Link] = {
            n: Link(f"pcie-node{n}", cost.hw.intra_node_bw)
            for n in range(n_nodes)
        }
        self._per_node = per_node
        self._node_of: Dict[int, int] = {}
        for idx, inst in enumerate(self.instances):
            self._node_of[inst.iid] = (idx // per_node) % n_nodes

    def scale_up(self, engine=None) -> Instance:
        inst = super().scale_up(engine)   # joins decode_insts via routing
        idx = len(self.instances) - 1
        self._node_of[inst.iid] = (idx // self._per_node) % len(self.links)
        return inst

    # ------------------------------------------------------------------ #
    def _on_prefill_handoff(self, inst, reqs: List[Request], now,
                            engine: SimulationEngine) -> None:
        link = self.links[self._node_of[inst.iid]]
        tr = self.transport
        for r in reqs:
            targets = [i for i in self.decode_insts if i.alive]
            if not targets:
                # every decode instance is dead: the FuDG cliff — the KV
                # cache has nowhere to land, so the request is lost
                self.fault_lost_requests([r], now, engine)
                continue
            reachable = tr.filter_reachable(targets, now)
            if reachable:
                # prefer reachable decoders; with every one unreachable
                # the transfer goes out anyway and the retry/timeout
                # machinery decides its fate
                targets = reachable
            target = min(targets, key=lambda i: i.kv_tokens_used())
            nbytes = self.cost.kv_transfer_bytes(r.prompt_len)

            def deliver(r=r, target=target):
                if not target.alive:
                    # decode target died while the KV was in flight
                    self.fault_lost_requests([r], engine.now, engine)
                    return
                r.state = RequestState.DECODING
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = engine.now
                    engine.finished.append(r)
                    return
                target.add_decoding(r)
                engine.activate(target)

            def on_lost(r=r):
                # retry budget exhausted on the degraded interconnect:
                # the KV never landed, the request flows through the
                # failure policy like any other in-transit loss
                self.fault_lost_requests([r], engine.now, engine)

            tr.transfer(engine, inst.iid, target.iid, nbytes, now,
                        deliver, on_lost, link=link)
