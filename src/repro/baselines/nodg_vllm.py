"""vLLM-style NoDG baseline: independent replicas, separate batching,
prefill-priority scheduling (paper §4.1 baseline 1).

Each instance handles the full request lifecycle; requests are routed to
the least-loaded replica immediately on arrival, so prefills constantly
interrupt decodes on every replica — the interference PaDG removes.
"""
from __future__ import annotations

from typing import List

from repro.core.instance import Instance
from repro.core.request import Request
from repro.simulator.cost_model import InstanceCostModel
from repro.simulator.engine import SimulationEngine


class VLLMSystem:
    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None):
        self.cost = cost
        self.instances: List[Instance] = [
            Instance(i, cost, kv_capacity_tokens=cost.kv_capacity_tokens())
            for i in range(n_instances)
        ]

    def submit(self, req: Request, now: float,
               engine: SimulationEngine) -> None:
        # least outstanding KV tokens = least loaded
        inst = min(self.instances, key=lambda i: i.kv_tokens_used())
        inst.admit(req, now)
        engine.activate(inst)

    def on_slot_end(self, inst, kind, reqs, now, engine) -> None:
        pass
