"""vLLM-style NoDG baseline: independent replicas, separate batching,
prefill-priority scheduling (paper §4.1 baseline 1).

Each instance handles the full request lifecycle; as a policy
composition this is immediate admission over least-KV routing — requests
enter the least-loaded replica on arrival, so prefills constantly
interrupt decodes on every replica (the interference PaDG removes) and
the system-level queue stays empty.  Composing a different bundle turns
the same machinery SLO-aware: ``"vllm+priority"`` swaps in backpressure
admission + an EDF queue over per-class TTFT deadlines.
"""
from __future__ import annotations

from repro.core.system import PolicySystemBase
from repro.simulator.cost_model import InstanceCostModel


class VLLMSystem(PolicySystemBase):
    base_name = "vllm"
    default_queue = "fifo"
    default_admission = "immediate"
    default_routing = "least-kv"

    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, iid_base: int = 0):
        super().__init__(cost, n_instances, slo,
                         queue_discipline=queue_discipline,
                         admission=admission, routing=routing,
                         failure=failure, iid_base=iid_base)
