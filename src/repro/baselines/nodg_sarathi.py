"""Sarathi-style NoDG baseline: hybrid batching + chunked prefill,
decode-priority (paper §4.1 baseline 2).

Every iteration fuses the running decode batch with up to ``chunk_tokens``
of prefill work taken from the head of the prompt queue; a prompt's
prefill spreads over several iterations, re-reading its KV prefix each
time (the overhead the paper calls out).  The system layer is the same
immediate/least-KV policy bundle as vLLM — only the instance's intra-slot
rule differs — so ``"sarathi+priority"`` composes the SLO-aware queue
onto chunked prefill for free.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.instance import Instance
from repro.core.request import Request, RequestState
from repro.core.system import PolicySystemBase
from repro.simulator.cost_model import InstanceCostModel


class SarathiInstance(Instance):
    def __init__(self, iid, executor, kv_capacity_tokens,
                 chunk_tokens: int = 512, **kw):
        super().__init__(iid, executor, kv_capacity_tokens, **kw)
        self.chunk_tokens = chunk_tokens
        self._progress = {}        # rid -> prefilled tokens

    def next_slot(self, now: float):
        if not self.pending and not self.decoding:
            self.phase = "idle"
            return "idle", 0.0, []
        # build the chunk set from pending prompts (decode-priority: the
        # decode batch always rides along; chunks fill the leftover budget)
        chunks: List[Tuple[Request, int, int]] = []   # (req, chunk, prefix)
        budget = self.chunk_tokens
        for r in self.pending:
            if budget <= 0:
                break
            done = self._progress.get(r.rid, 0)
            take = min(budget, r.prompt_len - done)
            if take > 0:
                chunks.append((r, take, done))
                budget -= take
        decode_batch = self.decoding[: self.max_decode_batch]
        dur = self._hybrid_iter_time(
            [c[1] for c in chunks], [c[2] for c in chunks], decode_batch)
        self.phase = "hybrid"
        self._current_chunks = chunks
        return "hybrid", dur, decode_batch

    def complete_slot(self, kind: str, reqs, t_end: float):
        finished = []
        if kind != "hybrid":
            return super().complete_slot(kind, reqs, t_end)
        # decode side
        for r in reqs:
            self._gen_token(r)
            if r.tokens_generated == 2:
                r.second_token_time = t_end
            if r.tokens_generated >= r.output_len:
                r.state = RequestState.FINISHED
                r.finish_time = t_end
                self.remove_decoding(r)
                finished.append(r)
        self._touch()
        # prefill chunks
        for r, take, done in self._current_chunks:
            new_done = done + take
            self._progress[r.rid] = new_done
            if new_done >= r.prompt_len:
                self.remove_pending(r)
                del self._progress[r.rid]
                r.first_token_time = t_end
                r.tokens_generated = 1
                if r.tokens_generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    r.finish_time = t_end
                    finished.append(r)
                else:
                    r.state = RequestState.DECODING
                    self.add_decoding(r)
        self._current_chunks = []
        self._finished.extend(finished)
        return finished


class SarathiSystem(PolicySystemBase):
    base_name = "sarathi"
    default_queue = "fifo"
    default_admission = "immediate"
    default_routing = "least-kv"

    def __init__(self, cost: InstanceCostModel, n_instances: int, slo=None,
                 chunk_tokens: int = 512,
                 queue_discipline=None, admission=None, routing=None,
                 failure=None, iid_base: int = 0):
        self.chunk_tokens = chunk_tokens
        super().__init__(cost, n_instances, slo,
                         queue_discipline=queue_discipline,
                         admission=admission, routing=routing,
                         failure=failure, iid_base=iid_base)

    def _make_instance(self, iid: int) -> Instance:
        return SarathiInstance(iid, self.cost,
                               self.cost.kv_capacity_tokens(),
                               chunk_tokens=self.chunk_tokens)
