"""Simulator hot-path speed harness: simulated-seconds per wall-second.

Runs the canonical regression-grid spec (``regression_runner``) — or a
single tier-1-sized smoke cell — single-threaded and in-process, and
reports how many seconds of simulated cluster time one wall-clock second
buys.  The measured workload is exactly the golden-grid spec, so the
speed number tracks the same code path that ``tests/test_scenarios.py``
pins bit-exactly: optimizations that move the golden metrics are caught
there, optimizations that slow the simulator are caught here.

    PYTHONPATH=src python -m benchmarks.bench_simspeed            # grid
    PYTHONPATH=src python -m benchmarks.bench_simspeed --smoke    # CI gate
    PYTHONPATH=src python -m benchmarks.bench_simspeed --write-baseline

``--smoke`` compares one cell against the committed baseline in
``benchmarks/BENCH_simspeed.json`` and exits non-zero when the measured
speed regresses more than ``--max-regression`` (default 2x) — the CI
workflow runs this on every push.  The baseline JSON also records a
pure-Python *calibration* time measured on the machine that wrote it;
``--smoke`` re-measures the calibration locally and scales the expected
speed by the ratio, so the gate tracks the simulator's speed relative to
the host's interpreter speed rather than absolute wall clock — a slow CI
runner doesn't trip it, and a fast one doesn't mask regressions.
``--write-baseline`` re-measures and rewrites the baseline JSON (do this
after an intentional perf change, and commit the diff).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time

from repro.simulator.runner import _run_cell, regression_runner

BASELINE_PATH = pathlib.Path(__file__).resolve().parent / \
    "BENCH_simspeed.json"


def _calibration(repeats: int = 3) -> float:
    """Seconds for a fixed pure-Python workload (dict/heap/float churn,
    the same primitive mix as the event loop) — the host-speed yardstick
    that makes the committed baseline portable across machines."""
    import heapq
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        heap, acc, d = [], 0.0, {}
        for i in range(200_000):
            heapq.heappush(heap, ((i * 2654435761) % 1_000_003, i))
            acc += i * 1e-9
            d[i & 1023] = acc
            if i & 1:
                heapq.heappop(heap)
        best = min(best, time.perf_counter() - t0)
    return best


def _smoke_spec() -> dict:
    """One tier-1-sized cell: the golden grid's ecoserve/poisson corner."""
    for spec in regression_runner(n_workers=1).cells():
        if spec["strategy"] == "ecoserve" and spec["scenario"] == "poisson":
            return spec
    raise RuntimeError("regression grid lost its ecoserve/poisson cell")


def measure(specs, repeats: int = 1) -> dict:
    """Best-of-``repeats`` simulated-seconds-per-wall-second over specs."""
    sim_seconds = sum(s["duration"] for s in specs)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for spec in specs:
            _run_cell(spec)
        best = min(best, time.perf_counter() - t0)
    return {
        "cells": len(specs),
        "sim_seconds": sim_seconds,
        "wall_seconds": round(best, 4),
        "sim_s_per_wall_s": round(sim_seconds / best, 2),
    }


def run_grid(repeats: int) -> dict:
    return measure(regression_runner(n_workers=1).cells(), repeats)


def run_smoke(repeats: int) -> dict:
    return measure([_smoke_spec()], repeats)


def write_baseline(repeats: int) -> None:
    result = {
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        "calibration_seconds": round(_calibration(), 4),
        "smoke": run_smoke(repeats),
        "grid": run_grid(repeats),
    }
    BASELINE_PATH.write_text(json.dumps(result, indent=1, sort_keys=True)
                             + "\n")
    print(f"wrote {BASELINE_PATH}")
    print(json.dumps(result, indent=1, sort_keys=True))


def check_smoke(max_regression: float, repeats: int) -> int:
    if not BASELINE_PATH.exists():
        print(f"no baseline at {BASELINE_PATH}; run --write-baseline first",
              file=sys.stderr)
        return 2
    baseline = json.loads(BASELINE_PATH.read_text())
    # normalize for host speed: on a machine whose interpreter runs the
    # calibration workload k-x slower than the baseline machine, the
    # simulator is expected to run k-x slower too
    base_calib = baseline.get("calibration_seconds")
    host_factor = _calibration() / base_calib if base_calib else 1.0
    expected = baseline["smoke"]["sim_s_per_wall_s"] / host_factor
    now = run_smoke(repeats)
    ratio = expected / max(1e-9, now["sim_s_per_wall_s"])
    print(f"baseline: {baseline['smoke']['sim_s_per_wall_s']:.1f} "
          f"sim-s/wall-s, host-adjusted expectation: {expected:.1f} "
          f"(host x{host_factor:.2f}), now: {now['sim_s_per_wall_s']:.1f} "
          f"(slowdown x{ratio:.2f}, limit x{max_regression:.2f})")
    if ratio > max_regression:
        print("FAIL: simulator smoke cell regressed beyond the limit",
              file=sys.stderr)
        return 1
    print("OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one tier-1-sized cell vs the committed baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help=f"re-measure and rewrite {BASELINE_PATH.name}")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="--smoke fails beyond this slowdown factor")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N timing repeats")
    args = ap.parse_args(argv)
    if args.write_baseline:
        write_baseline(args.repeats)
        return 0
    if args.smoke:
        return check_smoke(args.max_regression, args.repeats)
    result = run_grid(args.repeats)
    print(json.dumps(result, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
