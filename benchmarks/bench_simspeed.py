"""Simulator hot-path speed harness: simulated-seconds per wall-second.

Runs the canonical regression-grid spec (``regression_runner``) — or a
single tier-1-sized smoke cell — single-threaded and in-process, and
reports how many seconds of simulated cluster time one wall-clock second
buys.  The measured workload is exactly the golden-grid spec, so the
speed number tracks the same code path that ``tests/test_scenarios.py``
pins bit-exactly: optimizations that move the golden metrics are caught
there, optimizations that slow the simulator are caught here.

    PYTHONPATH=src python -m benchmarks.bench_simspeed            # grid
    PYTHONPATH=src python -m benchmarks.bench_simspeed --smoke    # CI gate
    PYTHONPATH=src python -m benchmarks.bench_simspeed --write-baseline

``--smoke`` compares one cell against the committed baseline in
``benchmarks/BENCH_simspeed.json`` and exits non-zero when the measured
speed regresses more than ``--max-regression`` (default 2x) — the CI
workflow runs this on every push.  The baseline JSON also records a
pure-Python *calibration* time measured on the machine that wrote it;
``--smoke`` re-measures the calibration locally and scales the expected
speed by the ratio, so the gate tracks the simulator's speed relative to
the host's interpreter speed rather than absolute wall clock — a slow CI
runner doesn't trip it, and a fast one doesn't mask regressions.
``--write-baseline`` re-measures and rewrites the baseline JSON (do this
after an intentional perf change, and commit the diff).

``--smoke`` additionally gates the flight recorder's tracing overhead
(``repro.obs``): the smoke cell runs once plain and once traced in the
same process, and the run fails when tracing costs more than
``--max-tracing-overhead`` (default 1.15 = 15%).  The measured factor is
recorded under ``"tracing"`` in the baseline JSON for reference.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time

from repro.simulator.runner import _run_cell, regression_runner

BASELINE_PATH = pathlib.Path(__file__).resolve().parent / \
    "BENCH_simspeed.json"


def _calibration(repeats: int = 3) -> float:
    """Seconds for a fixed pure-Python workload (dict/heap/float churn,
    the same primitive mix as the event loop) — the host-speed yardstick
    that makes the committed baseline portable across machines."""
    import heapq
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        heap, acc, d = [], 0.0, {}
        for i in range(200_000):
            heapq.heappush(heap, ((i * 2654435761) % 1_000_003, i))
            acc += i * 1e-9
            d[i & 1023] = acc
            if i & 1:
                heapq.heappop(heap)
        best = min(best, time.perf_counter() - t0)
    return best


def _smoke_spec() -> dict:
    """One tier-1-sized cell: the golden grid's ecoserve/poisson corner."""
    for spec in regression_runner(n_workers=1).cells():
        if spec["strategy"] == "ecoserve" and spec["scenario"] == "poisson":
            return spec
    raise RuntimeError("regression grid lost its ecoserve/poisson cell")


def measure(specs, repeats: int = 1) -> dict:
    """Best-of-``repeats`` simulated-seconds-per-wall-second over specs."""
    sim_seconds = sum(s["duration"] for s in specs)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for spec in specs:
            _run_cell(spec)
        best = min(best, time.perf_counter() - t0)
    return {
        "cells": len(specs),
        "sim_seconds": sim_seconds,
        "wall_seconds": round(best, 4),
        "sim_s_per_wall_s": round(sim_seconds / best, 2),
    }


def run_grid(repeats: int) -> dict:
    return measure(regression_runner(n_workers=1).cells(), repeats)


def run_smoke(repeats: int) -> dict:
    return measure([_smoke_spec()], repeats)


def run_smoke_traced(repeats: int) -> dict:
    """The smoke cell with the flight recorder attached (in-memory
    capture) — the numerator of the tracing-overhead gate."""
    return measure([{**_smoke_spec(), "trace": True}], repeats)


def measure_tracing(repeats: int) -> dict:
    """Tracing overhead factor: plain vs traced smoke cell.  The two
    variants are timed in interleaved pairs (plain, traced, plain, ...)
    and each takes its best, so clock-speed drift between measurement
    blocks cancels instead of masquerading as overhead."""
    import gc
    plain_spec = _smoke_spec()
    traced_spec = {**plain_spec, "trace": True}
    sim_seconds = plain_spec["duration"]
    best = [float("inf"), float("inf")]
    # GC pauses land disproportionately on the traced variant (it
    # allocates the event list); collect between runs and disable the
    # collector inside the timed region so the gate measures the
    # recorder's algorithmic cost, not collector scheduling luck
    was_enabled = gc.isenabled()
    try:
        for _ in range(max(6, 2 * repeats)):
            for i, spec in enumerate((plain_spec, traced_spec)):
                gc.collect()
                gc.disable()
                t0 = time.perf_counter()
                _run_cell(spec)
                best[i] = min(best[i], time.perf_counter() - t0)
                gc.enable()
    finally:
        if was_enabled:
            gc.enable()
    plain, traced = (sim_seconds / b for b in best)
    return {
        "plain_sim_s_per_wall_s": round(plain, 2),
        "traced_sim_s_per_wall_s": round(traced, 2),
        "overhead_x": round(plain / traced, 4),
    }


def write_baseline(repeats: int) -> None:
    result = {
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        "calibration_seconds": round(_calibration(), 4),
        "smoke": run_smoke(repeats),
        "grid": run_grid(repeats),
        "tracing": measure_tracing(repeats),
    }
    BASELINE_PATH.write_text(json.dumps(result, indent=1, sort_keys=True)
                             + "\n")
    print(f"wrote {BASELINE_PATH}")
    print(json.dumps(result, indent=1, sort_keys=True))


def check_smoke(max_regression: float, repeats: int,
                max_tracing_overhead: float = 1.15) -> int:
    if not BASELINE_PATH.exists():
        print(f"no baseline at {BASELINE_PATH}; run --write-baseline first",
              file=sys.stderr)
        return 2
    baseline = json.loads(BASELINE_PATH.read_text())
    # normalize for host speed: on a machine whose interpreter runs the
    # calibration workload k-x slower than the baseline machine, the
    # simulator is expected to run k-x slower too
    base_calib = baseline.get("calibration_seconds")
    host_factor = _calibration() / base_calib if base_calib else 1.0
    expected = baseline["smoke"]["sim_s_per_wall_s"] / host_factor
    now = run_smoke(repeats)
    ratio = expected / max(1e-9, now["sim_s_per_wall_s"])
    print(f"baseline: {baseline['smoke']['sim_s_per_wall_s']:.1f} "
          f"sim-s/wall-s, host-adjusted expectation: {expected:.1f} "
          f"(host x{host_factor:.2f}), now: {now['sim_s_per_wall_s']:.1f} "
          f"(slowdown x{ratio:.2f}, limit x{max_regression:.2f})")
    if ratio > max_regression:
        print("FAIL: simulator smoke cell regressed beyond the limit",
              file=sys.stderr)
        return 1
    # tracing-overhead gate: the flight recorder's zero-overhead-when-off
    # contract is checked by the plain run above; this bounds the cost
    # when it is ON.  Measured live (plain vs traced, same process), so
    # no host normalization is needed.
    tr = measure_tracing(repeats)
    print(f"tracing: {tr['plain_sim_s_per_wall_s']:.1f} -> "
          f"{tr['traced_sim_s_per_wall_s']:.1f} sim-s/wall-s "
          f"(overhead x{tr['overhead_x']:.3f}, "
          f"limit x{max_tracing_overhead:.2f})")
    if tr["overhead_x"] > max_tracing_overhead:
        print("FAIL: flight-recorder tracing overhead beyond the limit",
              file=sys.stderr)
        return 1
    print("OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one tier-1-sized cell vs the committed baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help=f"re-measure and rewrite {BASELINE_PATH.name}")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="--smoke fails beyond this slowdown factor")
    ap.add_argument("--max-tracing-overhead", type=float, default=1.15,
                    help="--smoke fails when the traced smoke cell runs "
                         "more than this factor slower than the plain one")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N timing repeats")
    args = ap.parse_args(argv)
    if args.write_baseline:
        write_baseline(args.repeats)
        return 0
    if args.smoke:
        return check_smoke(args.max_regression, args.repeats,
                           args.max_tracing_overhead)
    result = run_grid(args.repeats)
    print(json.dumps(result, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
