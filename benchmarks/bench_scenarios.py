"""Scenario grid: every strategy under every traffic shape (beyond-paper).

Sweeps {EcoServe, vLLM, Sarathi, DistServe, MoonCake} x {poisson, bursty,
diurnal, trace-replay} with the unified ``ExperimentRunner`` and prints
one CSV row per cell.  ``--tenants`` switches to the multi-tenant grid
(two SLO classes mixed into every cell, per-class attainment columns).
``--stream PATH`` appends one JSONL row per finished cell (the CI
artifact).  ``--write-golden*`` regenerate the deterministic regression
fixtures consumed by the tier-1 tests:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden
    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden-tenants
"""
from __future__ import annotations

import pathlib
import time

from repro.simulator.runner import (ExperimentRunner, goodput_runner,
                                    regression_runner,
                                    static_scaling_runner, tenant_runner)

GOLDEN_DIR = (pathlib.Path(__file__).resolve().parent.parent
              / "tests" / "golden")
GOLDEN_PATH = GOLDEN_DIR / "scenario_grid.json"
GOODPUT_GOLDEN_PATH = GOLDEN_DIR / "goodput_frontier.json"
TENANT_GOLDEN_PATH = GOLDEN_DIR / "tenant_grid.json"
STATIC_GOLDEN_PATH = GOLDEN_DIR / "static_scaling.json"


def run(quick: bool = True, stream: str = None) -> dict:
    runner = regression_runner() if quick else ExperimentRunner(
        scenarios=("poisson", "bursty", "diurnal", "ramp", "replay"),
        rates=(8.0, 16.0, 24.0), duration=60.0, base_seed=0)
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    print("strategy,scenario,rate,attainment,completion,"
          "ttft_p50,ttft_p99")
    for cell in results["cells"]:
        m = cell["metrics"]
        print(f"{cell['strategy']},{cell['scenario']},{cell['rate']},"
              f"{m.get('attainment', 0):.4f},{m.get('completion', 0):.4f},"
              f"{m.get('ttft_p50', 0):.4f},{m.get('ttft_p99', 0):.4f}")
    n = len(results["cells"])
    print(f"\n{n} cells in {dt:.1f}s "
          f"({dt / max(1, n):.2f}s/cell wall-amortized)")
    return results


def run_goodput() -> dict:
    """The Fig. 8 goodput frontier: max rate meeting the SLO target,
    binary-searched inside each worker, per strategy x traffic shape."""
    t0 = time.time()
    results = goodput_runner().run()
    dt = time.time() - t0
    print("strategy,scenario,goodput,attainment,probes")
    for cell in results["cells"]:
        m = cell.get("metrics", {})
        print(f"{cell['strategy']},{cell['scenario']},"
              f"{m.get('goodput', 0):.3f},{m.get('attainment', 0):.4f},"
              f"{m.get('probes', 0):.0f}")
    print(f"\n{len(results['cells'])} frontier cells in {dt:.1f}s")
    return results


def run_tenants(stream: str = None) -> dict:
    """The multi-tenant grid: per-class attainment columns per cell."""
    runner = tenant_runner()
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    classes = results["meta"]["tenants"]
    print("strategy,scenario,rate,attainment,attainment_min,"
          + ",".join(f"att_{c}" for c in classes))
    for cell in results["cells"]:
        m = cell.get("metrics", {})
        by_class = m.get("attainment_by_class", {})
        print(f"{cell['strategy']},{cell['scenario']},{cell['rate']},"
              f"{m.get('attainment', 0):.4f},"
              f"{m.get('attainment_min', 0):.4f},"
              + ",".join(f"{by_class.get(c, 0):.4f}" for c in classes))
    print(f"\n{len(results['cells'])} tenant cells in {dt:.1f}s")
    return results


def write_golden() -> None:
    results = regression_runner().run()
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


def write_tenant_golden() -> None:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    results = tenant_runner().run()
    ExperimentRunner.save(results, TENANT_GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {TENANT_GOLDEN_PATH}")
    results = static_scaling_runner().run()
    ExperimentRunner.save(results, STATIC_GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {STATIC_GOLDEN_PATH}")


def write_goodput_golden() -> None:
    results = goodput_runner().run()
    GOODPUT_GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOODPUT_GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOODPUT_GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--goodput", action="store_true",
                    help="run the goodput-frontier grid instead of the "
                         "fixed-rate sweep")
    ap.add_argument("--tenants", action="store_true",
                    help="run the multi-tenant SLO-class grid "
                         "(per-class attainment columns)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append one JSONL row per finished cell "
                         "(interrupt recovery / CI artifact)")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/scenario_grid.json")
    ap.add_argument("--write-golden-goodput", action="store_true",
                    help="regenerate tests/golden/goodput_frontier.json")
    ap.add_argument("--write-golden-tenants", action="store_true",
                    help="regenerate tests/golden/tenant_grid.json and "
                         "tests/golden/static_scaling.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.write_golden_goodput:
        write_goodput_golden()
    elif args.write_golden_tenants:
        write_tenant_golden()
    elif args.tenants:
        run_tenants(stream=args.stream)
    elif args.goodput:
        run_goodput()
    else:
        run(quick=not args.full, stream=args.stream)
