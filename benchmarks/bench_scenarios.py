"""Scenario grid: every strategy under every traffic shape (beyond-paper).

Sweeps {EcoServe, vLLM, Sarathi, DistServe, MoonCake} x {poisson, bursty,
diurnal, trace-replay} with the unified ``ExperimentRunner`` and prints
one CSV row per cell.  ``--write-golden`` regenerates the deterministic
regression fixture consumed by ``tests/test_scenarios.py``:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden
"""
from __future__ import annotations

import pathlib
import time

from repro.simulator.runner import (ExperimentRunner, goodput_runner,
                                    regression_runner)

GOLDEN_DIR = (pathlib.Path(__file__).resolve().parent.parent
              / "tests" / "golden")
GOLDEN_PATH = GOLDEN_DIR / "scenario_grid.json"
GOODPUT_GOLDEN_PATH = GOLDEN_DIR / "goodput_frontier.json"


def run(quick: bool = True) -> dict:
    runner = regression_runner() if quick else ExperimentRunner(
        scenarios=("poisson", "bursty", "diurnal", "ramp", "replay"),
        rates=(8.0, 16.0, 24.0), duration=60.0, base_seed=0)
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    print("strategy,scenario,rate,attainment,completion,"
          "ttft_p50,ttft_p99")
    for cell in results["cells"]:
        m = cell["metrics"]
        print(f"{cell['strategy']},{cell['scenario']},{cell['rate']},"
              f"{m.get('attainment', 0):.4f},{m.get('completion', 0):.4f},"
              f"{m.get('ttft_p50', 0):.4f},{m.get('ttft_p99', 0):.4f}")
    n = len(results["cells"])
    print(f"\n{n} cells in {dt:.1f}s "
          f"({dt / max(1, n):.2f}s/cell wall-amortized)")
    return results


def run_goodput() -> dict:
    """The Fig. 8 goodput frontier: max rate meeting the SLO target,
    binary-searched inside each worker, per strategy x traffic shape."""
    t0 = time.time()
    results = goodput_runner().run()
    dt = time.time() - t0
    print("strategy,scenario,goodput,attainment,probes")
    for cell in results["cells"]:
        m = cell.get("metrics", {})
        print(f"{cell['strategy']},{cell['scenario']},"
              f"{m.get('goodput', 0):.3f},{m.get('attainment', 0):.4f},"
              f"{m.get('probes', 0):.0f}")
    print(f"\n{len(results['cells'])} frontier cells in {dt:.1f}s")
    return results


def write_golden() -> None:
    results = regression_runner().run()
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


def write_goodput_golden() -> None:
    results = goodput_runner().run()
    GOODPUT_GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOODPUT_GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOODPUT_GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--goodput", action="store_true",
                    help="run the goodput-frontier grid instead of the "
                         "fixed-rate sweep")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/scenario_grid.json")
    ap.add_argument("--write-golden-goodput", action="store_true",
                    help="regenerate tests/golden/goodput_frontier.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.write_golden_goodput:
        write_goodput_golden()
    elif args.goodput:
        run_goodput()
    else:
        run(quick=not args.full)
