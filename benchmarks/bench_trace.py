"""Flight-recorder smoke: traced EcoServe bursty cell, TTFT attribution,
Perfetto export.

Runs the regression grid's ecoserve/bursty cell (the same spec
``tests/test_scenarios.py`` pins bit-exactly) with the flight recorder
attached, then proves the observability contract end to end:

* the per-request TTFT attribution components
  (``queue_wait + prefill_wait + prefill_service + transfer``) sum
  *bit-exactly* to each request's measured TTFT — the invariant pinned
  by ``tests/golden/trace_attribution.json``;
* the JSONL trace round-trips through ``repro.obs.export`` and renders
  to Chrome-trace/Perfetto JSON (load it at https://ui.perfetto.dev);
* the trace axis is seed-neutral: the traced cell's metrics are
  compared against the untraced run of the identical spec.

    PYTHONPATH=src python -m benchmarks.bench_trace --smoke
    PYTHONPATH=src python -m benchmarks.bench_trace --smoke --out trace_out
    PYTHONPATH=src python -m benchmarks.bench_trace --write-golden
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from benchmarks.common import emit
from repro.obs.export import read_jsonl, write_chrome_trace
from repro.obs.metrics import attribution, interference, summarize
from repro.simulator.runner import _run_cell, regression_runner

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "trace_attribution.json")

# the golden pins this many leading attribution rows (full precision
# would bloat the fixture; the exactness invariant covers every row)
GOLDEN_ROWS = 12
_ROUND = 9


def smoke_spec(trace_path=None) -> dict:
    """The regression grid's ecoserve/bursty cell, optionally traced.
    Using the grid's own spec keeps the seed (``cell_seed``) and every
    parameter bit-identical to the golden-pinned cell."""
    for spec in regression_runner(n_workers=1).cells():
        if spec["strategy"] == "ecoserve" and spec["scenario"] == "bursty":
            if trace_path is not None:
                spec = {**spec, "trace": str(trace_path)}
            return spec
    raise RuntimeError("regression grid lost its ecoserve/bursty cell")


def _round(x):
    if isinstance(x, float):
        return round(x, _ROUND)
    if isinstance(x, dict):
        return {k: _round(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_round(v) for v in x]
    return x


def golden_payload(events, spec: dict) -> dict:
    """The worker-count-invariant digest the golden pins: cell identity,
    event counts, attribution totals + leading rows, interference.
    Built purely from the trace events, so a 1-worker in-process run and
    a 3-worker spawned grid must produce the identical payload."""
    attr = attribution(events)
    exact = all(
        r["queue_wait"] + r["prefill_wait"] + r["prefill_service"]
        + r["transfer"] == r["ttft"] for r in attr["rows"])
    digest = summarize(events)
    return {
        "cell": {k: spec[k] for k in (
            "strategy", "scenario", "rate", "seed", "duration", "warmup",
            "model", "hw", "tp", "pp", "n_instances", "workload")},
        "events": digest["by_type"],
        "attribution": {
            "exact": exact,
            "n": attr["totals"]["n"],
            "unattributed": attr["unattributed"],
            "totals": _round(attr["totals"]),
            "rows": _round(attr["rows"][:GOLDEN_ROWS])},
        "interference": _round(interference(events)),
        "tpot": _round(digest["tpot"]),
    }


def run_smoke(out_dir: str = "trace_out", stream: str = None) -> dict:
    """The CI cell: trace, attribute, export, and cross-check
    seed-neutrality against the untraced twin."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    trace_path = out / "ecoserve_bursty.trace.jsonl"

    t0 = time.time()
    spec = smoke_spec(trace_path)
    row = _run_cell(spec)
    events, _meta = read_jsonl(trace_path)
    payload = golden_payload(events, spec)

    # seed-neutrality: the traced cell's golden-visible metrics must be
    # bit-identical to the untraced run of the same spec
    untraced = _run_cell(smoke_spec(None))
    assert row["metrics"] == untraced["metrics"], (
        "tracing perturbed the metrics", row["metrics"],
        untraced["metrics"])

    assert payload["attribution"]["exact"], (
        "TTFT attribution components must sum bit-exactly per request")
    assert payload["attribution"]["n"] > 0, "no requests attributed"
    assert payload["attribution"]["unattributed"] == 0, payload

    perfetto_path = out / "ecoserve_bursty.perfetto.json"
    n_render = write_chrome_trace(events, perfetto_path)

    if GOLDEN_PATH.exists():
        golden = json.loads(GOLDEN_PATH.read_text())
        assert payload == golden, (
            "trace attribution drifted from the pinned golden; if the "
            "change is intentional re-run --write-golden and commit")

    dt = time.time() - t0
    print(f"\n== Flight-recorder smoke: {spec['strategy']}/"
          f"{spec['scenario']} @ {spec['rate']} req/s ==")
    print(f"  events: {len(events)} "
          f"({json.dumps(payload['events'], sort_keys=True)})")
    tot = payload["attribution"]["totals"]
    print(f"  attribution: {tot['n']} requests, per-row exact sums, "
          f"total ttft {tot['ttft']:.3f}s "
          f"(queue {tot['queue_wait']:.3f} + wait "
          f"{tot['prefill_wait']:.3f} + prefill "
          f"{tot['prefill_service']:.3f} + transfer "
          f"{tot['transfer']:.3f})")
    print(f"  interference score: {payload['interference']['score']:.4f} "
          f"(p99 stretch {payload['interference']['p99']:.3f}, "
          f"n={payload['interference']['n']})")
    print(f"  wrote {trace_path} ({len(events)} events) and "
          f"{perfetto_path} ({n_render} render events)")
    emit("trace_smoke", dt * 1e6, f"events={len(events)}")
    if stream:
        # one digest row into the shared CI artifact, same JSONL file
        # the grid benches stream their cells into
        with open(stream, "a") as fh:
            fh.write(json.dumps({
                "bench": "trace_smoke", "cell": payload["cell"],
                "events": payload["events"],
                "attribution": payload["attribution"]["totals"],
                "interference": payload["interference"],
            }, sort_keys=True) + "\n")
    return {"payload": payload, "trace": str(trace_path),
            "perfetto": str(perfetto_path)}


def write_golden() -> None:
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        trace_path = pathlib.Path(td) / "cell.trace.jsonl"
        spec = smoke_spec(trace_path)
        _run_cell(spec)
        events, _ = read_jsonl(trace_path)
        payload = golden_payload(events, spec)
    assert payload["attribution"]["exact"]
    GOLDEN_PATH.write_text(json.dumps(payload, indent=1, sort_keys=True)
                           + "\n")
    print(f"wrote {GOLDEN_PATH}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="traced CI cell + attribution + Perfetto export")
    ap.add_argument("--out", default="trace_out",
                    help="artifact directory for --smoke")
    ap.add_argument("--write-golden", action="store_true",
                    help=f"re-pin {GOLDEN_PATH.name}")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append the smoke digest row to this JSONL file")
    args = ap.parse_args(argv)
    if args.write_golden:
        write_golden()
        return 0
    run_smoke(args.out, stream=args.stream)
    return 0


if __name__ == "__main__":
    sys.exit(main())
