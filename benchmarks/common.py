"""Shared helpers for the benchmark harness (one module per paper
table/figure).  Every benchmark prints ``name,us_per_call,derived`` CSV
rows plus a human-readable block, and returns a dict for run.py."""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import (GPU_A800, GPU_L20, HardwareProfile,
                                        InstanceCostModel)

# quick mode keeps the full-suite wall time tractable on 1 CPU core
QUICK_DURATION = 30.0
FULL_DURATION = 120.0


def make_cost(model: str = "llama-30b", hw: HardwareProfile = GPU_L20,
              tp: int = 4, pp: int = 1) -> InstanceCostModel:
    return InstanceCostModel(cfg=get_config(model), hw=hw, tp=tp, pp=pp)


def system_factory(name: str, cost: InstanceCostModel, n_instances: int,
                   slo, **kw) -> Callable[[], object]:
    return functools.partial(make_system, name, cost, n_instances, slo, **kw)


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
