"""Multi-model fleet serving: cost-aware routing + budget-constrained
rebalancing (the fleet layer, ``repro.fleet``).

Runs ``fleet_grid_runner()`` — the canonical grid behind
``tests/golden/fleet_grid.json``: a qwen1.5-32b "chat" pool and a
llama-30b "code" pool (both EcoServe stacks, 4 GPUs/instance) sharing
a 24-GPU budget, fed by two model-tagged tenant streams whose mix
shifts mid-run in opposite directions (``shift:4,1`` vs ``shift:1,4``).
Every cell is {pinned, cheapest-feasible, quality-tiered} routing x
{static partition, budget-constrained rebalancing} over the IDENTICAL
arrival sequence (fleet cells seed under the constant "fleet" label),
so routing and rebalancing deltas isolate the policy.  The surging
tenant rides the smaller model, so quality-tiered routing may legally
spill its breaching requests up-tier into the draining qwen pool.

The headline assertions:

* **rebalancing beats the static partition** — under every routing
  policy, the rebalanced cell's min-over-pools attainment is STRICTLY
  above its static twin's: the static split strands capacity on the
  wrong side of the mix shift, the rebalancer moves it (donor-funded
  contractions + commissions through the mitosis/actuator path,
  provisioning delay and all);
* **routing alone also helps** — quality-tiered's static cell holds a
  strictly higher min-over-pools attainment than pinned's static cell:
  spillover absorbs part of the surge before any capacity moves;
* **the budget holds** — no recorded trajectory point ever commits more
  GPUs than the budget, and no pool's committed target drops below one
  instance (the structural invariants of ``FleetRebalanceHarness``).

    PYTHONPATH=src python -m benchmarks.bench_fleet
    PYTHONPATH=src python -m benchmarks.bench_fleet --smoke \
        --stream rows.jsonl             # the CI cell
    PYTHONPATH=src python -m benchmarks.bench_fleet --write-golden
"""
from __future__ import annotations

import pathlib
import time

from benchmarks.common import emit
from repro.simulator.runner import ExperimentRunner, fleet_grid_runner

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "fleet_grid.json")

CONTROL_LEVELS = ("static", "rebalance")


def _cell_table(results: dict) -> None:
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    rate = meta["rates"][0]
    scen = meta["scenarios"][0]
    print("router,control,att_pool_min,attainment,completion,"
          "pool_sizes,routed,moves+ups")
    for router in meta["strategies"]:
        for level in CONTROL_LEVELS:
            m = grid[router][scen][level][rate]
            fl = m["fleet"]
            tl = m.get("timeline", {})
            churn = "-" if not tl else (f"{tl.get('n_moves', 0)}+"
                                        f"{tl.get('n_ups', 0)}")
            print(f"{router},{level},{m['attainment_pool_min']:.4f},"
                  f"{m['attainment']:.4f},{m['completion']:.4f},"
                  f"{fl['n_instances']},{fl['routed']},{churn}")


def _assert_rebalance_beats_static(results: dict) -> dict:
    """Min-over-pools attainment: the rebalanced cell strictly above its
    static twin under every routing policy."""
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    rate = meta["rates"][0]
    scen = meta["scenarios"][0]
    margins = {}
    for router in meta["strategies"]:
        static = grid[router][scen]["static"][rate]["attainment_pool_min"]
        rebal = grid[router][scen]["rebalance"][rate]["attainment_pool_min"]
        margins[router] = {"static": static, "rebalance": rebal}
        assert rebal > static, (
            f"budget-constrained rebalancing must strictly beat the "
            f"static partition on min-over-pools attainment under "
            f"{router} routing: {rebal:.3f} vs {static:.3f}")
    assert (margins["quality-tiered"]["static"]
            > margins["pinned"]["static"]), (
        "quality-tiered spillover must lift the static floor above "
        "pinned routing's")
    return margins


def _assert_budget_and_floor(results: dict) -> None:
    """Every rebalanced cell's recorded trajectory honors the budget and
    the one-instance-per-pool floor at every control tick."""
    for cell in results["cells"]:
        if not cell.get("autoscale"):
            continue
        m = cell["metrics"]
        tl = m["timeline"]
        budget = tl["budget"]
        per_pool = tl["per_pool"]
        devices = {p["name"]: p["devices_per_instance"]
                   for p in cell["system"]["pools"]}
        trajs = {name: pool_tl["trajectory"]
                 for name, pool_tl in per_pool.items()}
        lengths = {len(t) for t in trajs.values()}
        assert len(lengths) == 1, "per-pool trajectories out of sync"
        for i in range(lengths.pop()):
            committed = sum(trajs[n][i]["n_target"] * devices[n]
                            for n in trajs)
            assert committed <= budget, (
                f"tick {i}: committed {committed} GPUs over the "
                f"budget of {budget}")
            for n in trajs:
                assert trajs[n][i]["n_target"] >= 1, (
                    f"tick {i}: pool {n} dropped below one instance")


def run(stream: str = None):
    runner = fleet_grid_runner()
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    assert not results.get("errors"), results.get("errors")
    print("\n== Fleet serving: routing x rebalancing under a mid-run "
          "mix shift ==")
    _cell_table(results)
    margins = _assert_rebalance_beats_static(results)
    _assert_budget_and_floor(results)
    print("\n  min-over-pools attainment, static vs rebalanced:")
    for router, v in margins.items():
        print(f"    {router}: {v['static']:.3f} -> {v['rebalance']:.3f}")
    print("  rebalancing strictly beat the static partition under every "
          "router; budget and one-instance floor held at every tick")
    emit("fleet_grid", dt * 1e6, f"cells={len(results['cells'])}")
    return {"results": results, "margins": margins}


def run_smoke(stream: str = None) -> dict:
    """The CI cell: one pinned-router fleet with the rebalancer on the
    shifting mix — proves routing, per-pool scoring, and donor-funded
    rebalancing end to end on a short clock."""
    runner = ExperimentRunner(
        strategies=("pinned",), scenarios=("poisson",), rates=(6.0,),
        tenants=(("sharegpt", 0.5, "shift:4,1", "qwen1.5-32b"),
                 ("longbench", None, "shift:1,4", "llama-30b")),
        fleet="chat=qwen1.5-32b/ecoserve/4,code=llama-30b/ecoserve/2"
              ";budget=24",
        autoscale=("rebalance",), phases=4,
        model="llama-30b", hw="L20", tp=4, pp=1,
        duration=20.0, warmup=3.0,
        base_seed=42, n_workers=1, stream_path=stream)
    results = runner.run()
    assert not results.get("errors"), results.get("errors")
    (cell,) = results["cells"]
    m = cell["metrics"]
    fl = m["fleet"]
    tl = m["timeline"]
    print(f"smoke: fleet pinned+rebalance attainment={m['attainment']:.3f} "
          f"pool_min={m['attainment_pool_min']:.3f} "
          f"sizes={fl['n_instances']} routed={fl['routed']} "
          f"churn={tl['n_moves']}+{tl['n_ups']}/{tl['n_downs']}")
    assert m["finished"] > 0, "smoke cell ran empty"
    assert fl["committed"] <= fl["budget"], "smoke cell blew the budget"
    assert all(v >= 1 for v in fl["n_instances"].values()), (
        "smoke cell emptied a pool")
    assert set(m["attainment_by_pool"]) == {"chat", "code"}, (
        "per-pool attainment grid missing a pool")
    assert tl["n_ups"] + tl["n_moves"] + tl["n_downs"] > 0, (
        "rebalancer never acted on the mix shift")
    return results


def write_golden() -> None:
    results = fleet_grid_runner().run()
    assert not results.get("errors"), results.get("errors")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one pinned+rebalance fleet cell (CI)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append one JSONL row per finished cell")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/fleet_grid.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.smoke:
        run_smoke(stream=args.stream)
    else:
        run(stream=args.stream)
