"""Paper Table 3: KV-cache generation rate of a full prefill node and the
theoretical interconnect bandwidth the FuDG strategy would need."""
from __future__ import annotations

from benchmarks.common import emit, make_cost, timed
from repro.configs import get_config
from repro.simulator.cost_model import GPU_A800, GPU_L20


PAPER = {  # model, hw, tp -> (paper tokens/s, paper GB/s)
    ("llama-30b", "L20", 4): (6584.6, 9.796),
    ("llama-30b", "A800", 2): (26189.2, 38.96),
    ("codellama2-34b", "L20", 4): (6838.92, 1.25),
    ("codellama2-34b", "A800", 2): (25978.88, 4.76),
}


def run(quick: bool = True):
    print("\n== Table 3: KV generation rate vs required bandwidth ==")
    print(f"{'model':18}{'hw':6}{'tok/s(sim)':>12}{'tok/s(paper)':>14}"
          f"{'GB/s(sim)':>11}{'GB/s(paper)':>12}")
    out = {}
    for (model, hwname, tp), (ptok, pbw) in PAPER.items():
        hw = GPU_L20 if hwname == "L20" else GPU_A800
        cost = make_cost(model, hw, tp)
        per_node = hw.devices_per_node // tp

        def node_rate():
            lens = [512] * 8
            return per_node * sum(lens) / cost.prefill_time(lens)

        rate, us = timed(node_rate)
        bw = rate * cost.cfg.kv_bytes_per_token(2) / 1e9
        print(f"{model:18}{hwname:6}{rate:12.0f}{ptok:14.1f}"
              f"{bw:11.2f}{pbw:12.2f}")
        emit(f"table3_{model}_{hwname}", us,
             f"tok/s={rate:.0f};GBps={bw:.2f}")
        out[f"{model}_{hwname}"] = {"tok_s": rate, "gbps": bw}
    # the qualitative claims of Table 3
    assert out["llama-30b_L20"]["gbps"] > 10e9 / 8 / 1e9, \
        "MHA KV stream must exceed 10GbE"
    assert out["codellama2-34b_L20"]["gbps"] < \
        out["llama-30b_L20"]["gbps"] / 4, "GQA compresses KV"
    return out


if __name__ == "__main__":
    run()
