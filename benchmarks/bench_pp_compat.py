"""Paper Fig. 11: pipeline-parallel compatibility — throughput vs the
TPOT SLO as it relaxes from 100 ms to 500 ms.  PaDG + PP (TP2 x PP2)
overtakes both its TP4 variant and vLLM + PP once the TPOT SLO is loose,
because PaDG's long phases remove the pipeline bubbles NoDG suffers.

Folded into the unified ``ExperimentRunner`` (mirroring the PR 3 fold of
``bench_scaling_static``): the parallelism degree is a grid axis
(``tp=((4, 1), (2, 2))``, each (tp, pp) pair gets its own CRC-derived
cell seed) and the relaxing TPOT budget rides on ``slo_override`` —
one goodput-mode grid per TPOT point instead of a standalone loop."""
from __future__ import annotations

import time

from benchmarks.common import QUICK_DURATION, emit
from repro.simulator.runner import ExperimentRunner

TP_PAIRS = ((4, 1), (2, 2))


def run(quick: bool = True):
    model = "codellama2-34b"
    tpots = [0.1, 0.3, 0.5] if quick else [0.1, 0.2, 0.3, 0.4, 0.5]
    # the full strategy x (tp, pp) product: vllm_tp4pp1 is the no-PP NoDG
    # anchor the figure's PP variants are read against
    combos = ("ecoserve_tp4pp1", "ecoserve_tp2pp2",
              "vllm_tp4pp1", "vllm_tp2pp2")
    print(f"\n== Fig 11: PP compatibility ({model}, ShareGPT) ==")
    print(f"  {'TPOT SLO':>9} " + "".join(f"{k:>18}" for k in combos))
    out = {}
    for tpot in tpots:
        runner = ExperimentRunner(
            strategies=("ecoserve", "vllm"), scenarios=("poisson",),
            mode="goodput", target_attainment=0.90,
            goodput_lo=1.0, goodput_hi=96.0, goodput_tol=0.25,
            model=model, hw="L20", tp=TP_PAIRS, n_instances=4,
            workload="sharegpt", slo_override=(5.0, tpot),
            duration=QUICK_DURATION, warmup=None, base_seed=0)
        t0 = time.perf_counter()
        grid = ExperimentRunner.grid(runner.run())
        # cells run pooled, so the timing is the grid wall clock
        # amortized per cell (not each combo's isolated runtime)
        us = (time.perf_counter() - t0) * 1e6 / len(combos)
        row = {f"{strat}_{tpkey}": grid[strat]["poisson"][tpkey]["goodput"]
               for strat in ("ecoserve", "vllm")
               for tpkey in ("tp4pp1", "tp2pp2")}
        out[tpot] = row
        for label in combos:
            emit(f"fig11_tpot{int(tpot * 1000)}ms_{label}", us,
                 f"goodput={row[label]:.2f}")
        print(f"  {tpot * 1000:7.0f}ms " +
              "".join(f"{row[k]:18.2f}" for k in combos))
    # the figure's qualitative claim: at relaxed TPOT, EcoServe+PP beats
    # both its own TP variant and vLLM+PP
    loose = out[max(tpots)]
    assert loose["ecoserve_tp2pp2"] >= loose["vllm_tp2pp2"], loose
    return out


if __name__ == "__main__":
    run()
