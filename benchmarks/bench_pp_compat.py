"""Paper Fig. 11: pipeline-parallel compatibility — throughput vs the
TPOT SLO as it relaxes from 100 ms to 500 ms.  PaDG + PP (TP2 x PP2)
overtakes both its TP4 variant and vLLM + PP once the TPOT SLO is loose,
because PaDG's long phases remove the pipeline bubbles NoDG suffers."""
from __future__ import annotations

import dataclasses

from benchmarks.common import QUICK_DURATION, emit, make_cost, \
    system_factory, timed
from repro.core.slo import SLO, DATASET_SLOS
from repro.simulator.cost_model import GPU_L20
from repro.simulator.metrics import goodput
from repro.simulator.workload import WORKLOADS


def run(quick: bool = True):
    model = "codellama2-34b"
    profile = WORKLOADS["sharegpt"]
    tpots = [0.1, 0.3, 0.5] if quick else [0.1, 0.2, 0.3, 0.4, 0.5]
    n_inst = 4
    combos = {
        "ecoserve_tp4": ("ecoserve", make_cost(model, GPU_L20, tp=4, pp=1)),
        "ecoserve_tp2pp2": ("ecoserve",
                            make_cost(model, GPU_L20, tp=2, pp=2)),
        "vllm_tp2pp2": ("vllm", make_cost(model, GPU_L20, tp=2, pp=2)),
    }
    print(f"\n== Fig 11: PP compatibility ({model}, ShareGPT) ==")
    print(f"  {'TPOT SLO':>9} " + "".join(f"{k:>18}" for k in combos))
    out = {}
    for tpot in tpots:
        slo = SLO(ttft=5.0, tpot=tpot)
        row = {}
        for label, (sysname, cost) in combos.items():
            fac = system_factory(sysname, cost, n_inst, slo)
            g, us = timed(goodput, fac, profile, slo, 0.90,
                          duration=QUICK_DURATION, hi=96.0)
            row[label] = g["goodput"]
            emit(f"fig11_tpot{int(tpot*1000)}ms_{label}", us,
                 f"goodput={g['goodput']:.2f}")
        out[tpot] = row
        print(f"  {tpot*1000:7.0f}ms " +
              "".join(f"{row[k]:18.2f}" for k in combos))
    # the figure's qualitative claim: at relaxed TPOT, EcoServe+PP beats
    # both its own TP variant and vLLM+PP
    loose = out[max(tpots)]
    assert loose["ecoserve_tp2pp2"] >= loose["vllm_tp2pp2"], loose
    return out


if __name__ == "__main__":
    run()
