"""Benchmark harness entry point: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]``

Each benchmark prints ``name,us_per_call,derived`` CSV rows plus a
human-readable block.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


BENCHES = [
    ("table2_arithmetic_intensity", "benchmarks.bench_arithmetic_intensity"),
    ("table3_kv_bandwidth", "benchmarks.bench_kv_bandwidth"),
    ("fig8_e2e_goodput", "benchmarks.bench_e2e_goodput"),
    ("scenario_grid", "benchmarks.bench_scenarios"),
    ("fig9_static_scaling", "benchmarks.bench_scaling_static"),
    ("fig10_dynamic_scaling", "benchmarks.bench_scaling_dynamic"),
    ("fig11_pp_compat", "benchmarks.bench_pp_compat"),
    ("table5_cost_effectiveness", "benchmarks.bench_cost_effectiveness"),
    ("ablation_macro_and_variants", "benchmarks.bench_ablation_macro"),
    ("roofline_table", "benchmarks.roofline_table"),
    ("kernel_microbench", "benchmarks.bench_kernels"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full sweeps (slow); default is quick mode")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    import importlib
    rc = 0
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            mod.run(quick=not args.full)
            print(f"[{name}] OK in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            rc = 1
            print(f"[{name}] FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
