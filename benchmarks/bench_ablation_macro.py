"""Ablation: macro-instance size and the EcoServe variants.

1. Rolling activation needs peers: a macro instance of size 1 degenerates
   PaDG to NoDG (paper §4.3.1: "Assuming a macro instance contains only a
   single instance, the PaDG strategy actually degrades to the NoDG
   strategy").  We measure attainment at fixed TOTAL capacity (8
   instances) while varying how many cooperate per macro instance.
2. Scheduler-variant ladder at a fixed overload rate: paper-faithful
   EcoServe (mean slack) -> EcoServe++ (min slack) -> EcoServe-CP
   (chunked fallback), the two beyond-paper increments.
"""
from __future__ import annotations

from benchmarks.common import emit, make_cost, timed
from repro.core.padg_system import EcoServeSystem
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20
from repro.simulator.metrics import run_once
from repro.simulator.workload import WORKLOADS


def run(quick: bool = True):
    cost = make_cost("llama-30b", GPU_L20, tp=4)
    slo = DATASET_SLOS["sharegpt"]
    profile = WORKLOADS["sharegpt"]
    rate = 30.0
    dur = 45.0 if quick else 120.0

    print("\n== ablation 1: macro-instance cooperation "
          f"(8 instances total, rate {rate}) ==")
    out = {}
    for n_u in (1, 2, 4, 8):
        # n_upper=n_u carves the 8 instances into 8/n_u macro instances
        fac = (lambda n_u=n_u: EcoServeSystem(cost, 8, slo, n_lower=1,
                                              n_upper=n_u))
        m, us = timed(run_once, fac, profile, rate, slo, duration=dur)
        out[n_u] = m["attainment"]
        print(f"  macro size <= {n_u}: attainment = {m['attainment']:.3f}")
        emit(f"ablation_macro_size_{n_u}", us, f"att={m['attainment']:.3f}")
    # rolling activation must help: cooperating instances beat isolated
    assert out[8] >= out[1] - 0.02, out

    print("\n== ablation 2: scheduler variant ladder (rate "
          f"{rate}, P90 SLO) ==")
    variants = {
        "ecoserve (paper, mean slack)":
            lambda: EcoServeSystem(cost, 8, slo),
        "ecoserve++ (min slack)":
            lambda: EcoServeSystem(cost, 8, slo, plus_plus=True),
        "ecoserve-cp (chunked fallback)":
            lambda: EcoServeSystem(cost, 8, slo, plus_plus=True,
                                   chunked_fallback=512),
    }
    lad = {}
    for name, fac in variants.items():
        m, us = timed(run_once, fac, profile, rate, slo, duration=dur)
        lad[name] = m["attainment"]
        print(f"  {name:34} attainment = {m['attainment']:.3f}  "
              f"ttft_p90={m.get('ttft_p90', 0):.2f}s")
        emit(f"ablation_variant_{name.split()[0]}", us,
             f"att={m['attainment']:.3f}")
    return {"macro": out, "ladder": lad}


if __name__ == "__main__":
    run()
