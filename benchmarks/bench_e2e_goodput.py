"""Paper Fig. 8: end-to-end goodput under P50/P90/P99 SLO attainment —
EcoServe vs vLLM / Sarathi / DistServe / MoonCake, per workload x model.

Quick mode runs the headline cell (Llama-30B MHA on the L20 cluster,
ShareGPT); full mode sweeps models x workloads like the figure.
"""
from __future__ import annotations

from benchmarks.common import (QUICK_DURATION, emit, make_cost,
                               system_factory, timed)
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20
from repro.simulator.metrics import goodput
from repro.simulator.workload import WORKLOADS

SYSTEMS = ["ecoserve", "ecoserve++", "vllm", "sarathi", "distserve",
           "mooncake"]


def run_cell(model: str, workload: str, tp: int, n_instances: int,
             percentiles=(0.90,), duration=QUICK_DURATION):
    cost = make_cost(model, GPU_L20, tp)
    slo = DATASET_SLOS[workload]
    profile = WORKLOADS[workload]
    results = {}
    for p in percentiles:
        for name in SYSTEMS:
            fac = system_factory(name, cost, n_instances, slo)
            g, us = timed(goodput, fac, profile, slo, p,
                          duration=duration, hi=96.0)
            results[(name, p)] = g["goodput"]
            emit(f"fig8_{model}_{workload}_p{int(p*100)}_{name}", us,
                 f"goodput={g['goodput']:.2f}req/s")
    return results


def run(quick: bool = True):
    cells = ([("llama-30b", "sharegpt"), ("llama-30b", "longbench")]
             if quick else
             [(m, w) for m in ("llama-30b", "codellama2-34b")
              for w in ("alpaca", "sharegpt", "longbench")])
    percentiles = (0.90,) if quick else (0.50, 0.90, 0.99)
    out = {}
    for model, workload in cells:
        print(f"\n== Fig 8 cell: {model} x {workload} (32 L20 GPUs, "
              f"8 instances TP=4) ==")
        res = run_cell(model, workload, tp=4, n_instances=8,
                       percentiles=percentiles)
        for (name, p), g in sorted(res.items()):
            print(f"  P{int(p*100)} {name:12} goodput = {g:6.2f} req/s")
        out[f"{model}_{workload}"] = {f"{n}_p{int(p*100)}": g
                                      for (n, p), g in res.items()}
        eco = res[("ecoserve", percentiles[-1])]
        for rival in ("distserve", "mooncake"):
            r = res[(rival, percentiles[-1])]
            if r > 0:
                print(f"  ecoserve/{rival} = {eco / r:.2f}x")
    return out


if __name__ == "__main__":
    run(quick=True)
