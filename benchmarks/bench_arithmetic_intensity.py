"""Paper Table 2: approximate arithmetic intensity of the six primary
matmul classes, prefill vs decode — computed from the actual model shapes
and validated against the paper's closed forms (AI_prefill ~ B*S for
projections, ~S for attention; AI_decode ~ B and ~1)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs import get_config


def op_table(cfg, B, S):
    H = cfg.d_model
    M = cfg.num_heads
    rows = []
    # QKV projection
    rows.append(("qkv_proj", "prefill", 6 * B * S * H * H,
                 2 * (6 * B * S * H) / 2 + 3 * H * H * 2))
    rows.append(("qkv_proj", "decode", 6 * B * H * H,
                 (6 * B * H + 3 * H * H) * 2))
    # attention QK^T and PV (per phase)
    rows.append(("attn_qk", "prefill", 2 * B * S * S * H,
                 (2 * B * S * H + B * S * S * M) * 2))
    rows.append(("attn_qk", "decode", 2 * B * S * H,
                 (2 * B * S * M + B * H * (S + 1)) * 2))
    rows.append(("attn_pv", "prefill", 2 * B * S * S * H,
                 (2 * B * S * H + B * S * S * M) * 2))
    rows.append(("attn_pv", "decode", 2 * B * S * H,
                 (2 * B * S * M + B * H * (S + 1)) * 2))
    # output projection
    rows.append(("out_proj", "prefill", 2 * B * S * H * H,
                 (2 * B * S * H + H * H) * 2))
    rows.append(("out_proj", "decode", 2 * B * H * H,
                 (2 * B * H + H * H) * 2))
    # FFN expand / reduce (4H intermediate as in the paper's Table 2)
    rows.append(("ffn_expand", "prefill", 8 * B * S * H * H,
                 (2 * B * S * H + 4 * H * H) * 2))
    rows.append(("ffn_expand", "decode", 8 * B * H * H,
                 (2 * B * H + 4 * H * H) * 2))
    rows.append(("ffn_reduce", "prefill", 8 * B * S * H * H,
                 (2 * B * S * H + 4 * H * H) * 2))
    rows.append(("ffn_reduce", "decode", 8 * B * H * H,
                 (2 * B * H + 4 * H * H) * 2))
    return rows


def run(quick: bool = True):
    cfg = get_config("llama-30b")
    B, S = 8, 512
    rows, us = timed(op_table, cfg, B, S)
    print(f"\n== Table 2: arithmetic intensity (Llama-30B, B={B}, S={S}) ==")
    print(f"{'op':12}{'phase':9}{'FLOPs':>12}{'bytes':>12}{'AI':>9}"
          f"{'paper-approx':>14}")
    approx = {"prefill": {"qkv_proj": B * S, "attn_qk": S, "attn_pv": S,
                          "out_proj": B * S, "ffn_expand": B * S,
                          "ffn_reduce": B * S},
              "decode": {"qkv_proj": B, "attn_qk": 1, "attn_pv": 1,
                         "out_proj": B, "ffn_expand": B, "ffn_reduce": B}}
    out = {}
    for name, phase, flops, byts in rows:
        ai = flops / byts
        expect = approx[phase][name]
        print(f"{name:12}{phase:9}{flops:12.3e}{byts:12.3e}{ai:9.1f}"
              f"{expect:14}")
        out[f"{name}_{phase}"] = ai
        # the paper's claim: prefill AI >> decode AI
    pf = sum(v for k, v in out.items() if "prefill" in k)
    dc = sum(v for k, v in out.items() if "decode" in k)
    emit("table2_ai_prefill_over_decode", us, f"{pf / dc:.1f}x")
    assert pf > 10 * dc
    return out


if __name__ == "__main__":
    run()
