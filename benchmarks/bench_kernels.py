"""Kernel micro-benchmarks: interpret-mode wall time (correctness-path
only on CPU — TPU timing is projected by the roofline, not measured) plus
the per-kernel VMEM working-set accounting that justifies the BlockSpec
choices."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(fn, *args, n=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def vmem_working_set(block_q, block_k, G, D, dtype_bytes=2):
    """flash kernel per-step VMEM bytes: q,k,v tiles + f32 scratch."""
    q = G * block_q * D * dtype_bytes
    kv = 2 * block_k * D * dtype_bytes
    scratch = (2 * G * block_q + G * block_q * D) * 4
    return q + kv + scratch


def run(quick: bool = True):
    from repro.kernels.decode_attention import decode_attention
    from repro.kernels.flash_prefill import flash_prefill
    from repro.kernels.rglru_scan import rglru_scan
    from repro.kernels.rwkv6_scan import rwkv6_scan

    rng = np.random.default_rng(0)
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)

    print("\n== kernel interpret-mode microbench + VMEM accounting ==")
    # flash prefill
    B, T, Hq, Hkv, D = 1, 256, 8, 2, 128
    q, k, v = r(B, T, Hq, D), r(B, T, Hkv, D), r(B, T, Hkv, D)
    f = jax.jit(lambda q, k, v: flash_prefill(
        q, k, v, causal=True, block_q=128, block_k=128, interpret=True))
    us = _time(f, q, k, v)
    ws = vmem_working_set(128, 128, Hq // Hkv, D)
    print(f"  flash_prefill  {us:10.0f} us/call   VMEM working set "
          f"{ws/1024:.0f} KiB (<16 MiB ok)")
    emit("kernel_flash_prefill", us, f"vmem_kib={ws/1024:.0f}")
    assert ws < 16 * 2 ** 20

    # decode attention
    S = 2048
    q1, kc, vc = r(B, Hq, D), r(B, S, Hkv, D), r(B, S, Hkv, D)
    lens = jnp.asarray([S], jnp.int32)
    f = jax.jit(lambda a, b, c, d: decode_attention(
        a, b, c, d, block_s=512, interpret=True))
    us = _time(f, q1, kc, vc, lens)
    ws = (512 * D * 2 * 2) + (Hq // Hkv) * (2 + D) * 4
    print(f"  decode_attn    {us:10.0f} us/call   VMEM working set "
          f"{ws/1024:.0f} KiB")
    emit("kernel_decode_attention", us, f"vmem_kib={ws/1024:.0f}")

    # rglru
    la, b_, h0 = -jnp.abs(r(2, 256, 256)) * 0.1, r(2, 256, 256), r(2, 256)
    f = jax.jit(lambda a, b, h: rglru_scan(a, b, h, interpret=True))
    us = _time(f, la, b_, h0)
    print(f"  rglru_scan     {us:10.0f} us/call")
    emit("kernel_rglru_scan", us, "ok")

    # rwkv6
    rr, kk, vv = r(1, 128, 2, 64), r(1, 128, 2, 64), r(1, 128, 2, 64)
    ww = jnp.asarray(rng.uniform(0.8, 0.999, (1, 128, 2, 64)), jnp.float32)
    uu = r(2, 64) * 0.1
    f = jax.jit(lambda a, b, c, d, e: rwkv6_scan(a, b, c, d, e,
                                                 interpret=True))
    us = _time(f, rr, kk, vv, ww, uu)
    print(f"  rwkv6_scan     {us:10.0f} us/call")
    emit("kernel_rwkv6_scan", us, "ok")
    return {}


if __name__ == "__main__":
    run()
