"""Paper Fig. 9: static coarse-grained scaling — goodput vs #instances.
The paper reports SUPERLINEAR P90 scaling (5.6x from 1 -> 4 instances for
CodeLlama-34B): more instances give rolling activation more room to
separate phases.

The sweep is one ``ExperimentRunner`` grid with ``n_instances`` as an
axis (mode="goodput": each cell binary-searches its own frontier rate in
the worker), replacing the old standalone per-count loop — the same
unified runner that drives the scenario/tenant grids, so cell seeds are
CRC-pinned and the sweep parallelizes across counts.  A fixed-rate
variant of this axis is pinned bit-exactly by
``tests/golden/static_scaling.json`` (see ``static_scaling_runner``).
"""
from __future__ import annotations

import time

from benchmarks.common import QUICK_DURATION
from repro.simulator.runner import ExperimentRunner


def scaling_runner(counts, duration: float) -> ExperimentRunner:
    return ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",),
        mode="goodput", target_attainment=0.9,
        goodput_lo=0.25, goodput_hi=128.0, goodput_tol=0.10,
        model="codellama2-34b", hw="L20", tp=4, pp=1,
        n_instances=tuple(counts),
        workload="sharegpt", duration=duration, base_seed=0)


def run(quick: bool = True):
    counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    model = "codellama2-34b"
    print(f"\n== Fig 9: static scaling ({model}, ShareGPT, P90) ==")
    t0 = time.time()
    results = scaling_runner(counts, QUICK_DURATION).run()
    dt = time.time() - t0
    assert "errors" not in results, results.get("errors")
    grid = ExperimentRunner.grid(results)["ecoserve"]["poisson"]
    out = {n: grid[n]["goodput"] for n in counts}
    base = out[counts[0]] or 1e-9
    for n in counts:
        ratio = out[n] / base
        print(f"  instances={n:2d}  goodput={out[n]:6.2f} req/s  "
              f"({ratio:.2f}x vs {counts[0]} instance, "
              f"linear would be {n / counts[0]:.1f}x)")
    if out.get(4) and out.get(1):
        print(f"  scaling 1->4: {out[4] / out[1]:.2f}x "
              f"(paper: superlinear, 5.6x)")
    print(f"  {len(results['cells'])} cells in {dt:.1f}s "
          f"(searches ran inside pool workers)")
    return out


if __name__ == "__main__":
    run()
