"""Paper Fig. 9: static coarse-grained scaling — goodput vs #instances.
The paper reports SUPERLINEAR P90 scaling (5.6x from 1 -> 4 instances for
CodeLlama-34B): more instances give rolling activation more room to
separate phases."""
from __future__ import annotations

from benchmarks.common import QUICK_DURATION, emit, make_cost, \
    system_factory, timed
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20
from repro.simulator.metrics import goodput
from repro.simulator.workload import WORKLOADS


def run(quick: bool = True):
    model = "codellama2-34b"
    cost = make_cost(model, GPU_L20, tp=4)
    slo = DATASET_SLOS["sharegpt"]
    profile = WORKLOADS["sharegpt"]
    counts = [1, 2, 4] if quick else [1, 2, 4, 8]
    print(f"\n== Fig 9: static scaling ({model}, ShareGPT, P90) ==")
    out = {}
    base = None
    for n in counts:
        fac = system_factory("ecoserve", cost, n, slo)
        g, us = timed(goodput, fac, profile, slo, 0.90,
                      duration=QUICK_DURATION, hi=128.0)
        out[n] = g["goodput"]
        base = base or (g["goodput"] or 1e-9)
        ratio = g["goodput"] / base
        print(f"  instances={n:2d}  goodput={g['goodput']:6.2f} req/s  "
              f"({ratio:.2f}x vs 1 instance, linear would be {n}.0x)")
        emit(f"fig9_scaling_n{n}", us, f"goodput={g['goodput']:.2f}")
    if out.get(4) and out.get(1):
        print(f"  scaling 1->4: {out[4] / out[1]:.2f}x "
              f"(paper: superlinear, 5.6x)")
    return out


if __name__ == "__main__":
    run()
