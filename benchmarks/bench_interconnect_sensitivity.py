"""Interconnect sensitivity: commodity-link degradation grades, EcoServe
and a NoDG baseline vs the FuDG baselines (the paper's
commodity-interconnect premise).

Runs ``interconnect_runner()`` — the canonical grid behind
``tests/golden/interconnect_sensitivity.json``: EcoServe, vLLM (NoDG),
DistServe, and MoonCake on the bursty shape, each cell swept over five
network grades — a clean fabric, then progressively oversubscribed /
lossy links expressed in the PR 7 network fault grammar
(``netdelay:ms`` / ``netdegrade:F`` / ``netloss:p``).  Every grade
replays the identical arrival sequence as the clean cell (the fault axis
is seed-neutral), so the attainment delta isolates the interconnect.

The headline assertions:

* **FuDG tracks the fabric** — DistServe's and MoonCake's min-phase
  attainment is monotonically non-increasing across the grades and
  collapses to zero at the worst one: every request's KV cache crosses
  the degraded link between prefill and decode, so divided bandwidth,
  added store-and-forward latency, and loss-driven retry/timeout churn
  compound directly into missed decodes;
* **EcoServe/NoDG hold the clean-link frontier** — both keep all phases
  of a request on one instance and exchange only control-plane
  messages, so their min-phase attainment stays within 10% of the
  clean-link value at every grade (EcoServe's transport counters pin
  the structural reason: zero cross-instance KV transfers sent).

    PYTHONPATH=src python -m benchmarks.bench_interconnect_sensitivity
    PYTHONPATH=src python -m benchmarks.bench_interconnect_sensitivity \
        --smoke --stream rows.jsonl     # the CI cell: saturated link
    PYTHONPATH=src python -m benchmarks.bench_interconnect_sensitivity \
        --write-golden                  # re-pin the golden fixture
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

from benchmarks.common import emit
from repro.simulator.runner import ExperimentRunner, interconnect_runner

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "interconnect_sensitivity.json")
BENCH_PATH = (pathlib.Path(__file__).resolve().parent
              / "BENCH_interconnect.json")

FUDG = ("distserve", "mooncake")
HOLDERS = ("ecoserve", "vllm")


def _grades(meta: dict) -> list:
    return ["none" if f is None else f for f in meta["faults"]]


def _pmin(grid, meta, strat, grade):
    scen = meta["scenarios"][0]
    rate = meta["rates"][0]
    return grid[strat][scen][grade][rate]["attainment_phase_min"]


def _cell_table(results: dict) -> None:
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    scen, rate = meta["scenarios"][0], meta["rates"][0]
    print("strategy,grade,att_phase_min,attainment,completion,"
          "kv_sent,kv_lost,retries,timeouts")
    for strat in meta["strategies"]:
        for grade in _grades(meta):
            m = grid[strat][scen][grade][rate]
            tr = m.get("faults", {}).get("transport", {})
            print(f"{strat},{grade},"
                  f"{m['attainment_phase_min']:.4f},"
                  f"{m['attainment']:.4f},{m['completion']:.4f},"
                  f"{tr.get('sent', 0)},{tr.get('lost', 0)},"
                  f"{tr.get('retries', 0)},{tr.get('timeouts', 0)}")


def _assert_fudg_collapse(results: dict) -> dict:
    """Both FuDG baselines' min-phase attainment must be monotonically
    non-increasing across the grades and zero at the worst one."""
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    out = {}
    for strat in FUDG:
        pmins = [_pmin(grid, meta, strat, g) for g in _grades(meta)]
        out[strat] = pmins
        for a, b in zip(pmins, pmins[1:]):
            assert b <= a + 1e-12, (
                f"{strat} min-phase attainment must degrade "
                f"monotonically across the grades, got {pmins}")
        assert pmins[-1] == 0.0, (
            f"{strat} must collapse at the worst grade, got {pmins}")
        assert pmins[0] > 0.9, (
            f"{strat} must be healthy on the clean fabric, got {pmins}")
    return out


def _assert_holders_flat(results: dict) -> dict:
    """EcoServe and the NoDG baseline must stay within 10% of their
    clean-link min-phase attainment at every grade; EcoServe's transport
    counters must show zero cross-instance KV transfers."""
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    scen, rate = meta["scenarios"][0], meta["rates"][0]
    out = {}
    for strat in HOLDERS:
        pmins = [_pmin(grid, meta, strat, g) for g in _grades(meta)]
        out[strat] = pmins
        clean = pmins[0]
        assert clean > 0.8, (strat, pmins)
        for g, p in zip(_grades(meta), pmins):
            assert p >= 0.9 * clean, (
                f"{strat} must hold within 10% of its clean-link "
                f"attainment at every grade; {g} gave {p:.4f} vs clean "
                f"{clean:.4f}")
    for strat in HOLDERS:
        for grade in _grades(meta)[1:]:
            tr = grid[strat][scen][grade][rate]["faults"]["transport"]
            assert tr["sent"] == 0, (
                f"{strat} must move no KV across the fabric, got "
                f"{tr['sent']} transfers at {grade}")
    return out


def run(stream: str = None):
    runner = interconnect_runner()
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    assert not results.get("errors"), results.get("errors")
    print("\n== Interconnect sensitivity: commodity-link degradation "
          "grades ==")
    _cell_table(results)
    collapse = _assert_fudg_collapse(results)
    flat = _assert_holders_flat(results)
    print("\n  min-phase attainment across the grades:")
    for strat, pmins in {**flat, **collapse}.items():
        print(f"    {strat}: " + ", ".join(f"{p:.3f}" for p in pmins))
    print("  FuDG collapses with the fabric; EcoServe/NoDG hold the "
          "clean-link frontier (zero KV bytes on the wire)")
    emit("interconnect_sensitivity", dt * 1e6,
         f"cells={len(results['cells'])}")
    return {"results": results, "collapse": collapse, "flat": flat}


def run_smoke(stream: str = None) -> dict:
    """The CI cell: MoonCake on the saturated lossy link — proves the
    network plane, transport retry/timeout machinery, and KV-loss
    accounting end to end in one cell."""
    base = interconnect_runner()
    worst = base.faults[-1]
    runner = ExperimentRunner(
        strategies=("mooncake",), scenarios=("bursty",),
        rates=base.rates, faults=(worst,), phases=base.phases,
        model=base.model, hw=base.hw, tp=base.tp, pp=base.pp,
        n_instances=base.n_instances, workload=base.workload,
        duration=base.duration, warmup=base.warmup,
        base_seed=base.base_seed, n_workers=1, stream_path=stream)
    results = runner.run()
    assert not results.get("errors"), results.get("errors")
    (cell,) = results["cells"]
    m = cell["metrics"]
    tr = m["faults"]["transport"]
    print(f"smoke: mooncake on '{worst}' "
          f"phase_min={m['attainment_phase_min']:.3f} "
          f"sent={tr['sent']} lost={tr['lost']} retries={tr['retries']} "
          f"timeouts={tr['timeouts']}")
    assert tr["sent"] > 0, "no KV transfers crossed the transport"
    assert tr["retries"] > 0 or tr["lost"] > 0, (
        "a saturated lossy link must force retries or losses")
    assert m["attainment_phase_min"] < 0.5, (
        "MoonCake must visibly degrade on the saturated link")
    assert m["completion"] < 1.0, (
        "lost KV transfers must surface as unfinished requests")
    return results


def write_bench() -> None:
    """Record the sweep's headline numbers — the per-strategy min-phase
    attainment frontier across the grades plus run cost — as a committed
    artifact (``benchmarks/BENCH_interconnect.json``), so a future
    change to the transport or the grades shows up as a reviewable
    diff, not just a golden blob."""
    out = run()
    results = out["results"]
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    scen, rate = meta["scenarios"][0], meta["rates"][0]
    frontier = {}
    for strat in meta["strategies"]:
        per_grade = {}
        for grade in _grades(meta):
            m = grid[strat][scen][grade][rate]
            tr = m.get("faults", {}).get("transport", {})
            per_grade[grade] = {
                "att_phase_min": round(m["attainment_phase_min"], 4),
                "completion": round(m["completion"], 4),
                "kv_sent": tr.get("sent", 0),
                "kv_lost": tr.get("lost", 0),
                "retries": tr.get("retries", 0),
            }
        frontier[strat] = per_grade
    doc = {
        "grades": _grades(meta),
        "frontier": frontier,
        "cells": len(results["cells"]),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
    }
    BENCH_PATH.write_text(json.dumps(doc, indent=1, sort_keys=True)
                          + "\n")
    print(f"wrote {BENCH_PATH}")


def write_golden() -> None:
    results = interconnect_runner().run()
    assert not results.get("errors"), results.get("errors")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one saturated-link MoonCake cell (CI)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append one JSONL row per finished cell")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/"
                         "interconnect_sensitivity.json")
    ap.add_argument("--write-bench", action="store_true",
                    help="rewrite benchmarks/BENCH_interconnect.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.write_bench:
        write_bench()
    elif args.smoke:
        run_smoke(stream=args.stream)
    else:
        run(stream=args.stream)
