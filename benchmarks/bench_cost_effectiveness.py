"""Paper Table 5 / §6: cost-effectiveness comparison.

The paper's thesis is not raw goodput but goodput per DOLLAR: FuDG's
performance depends on high-performance interconnects whose cost and
power rival the GPUs'.  We price three cluster builds (list-price-level
estimates, documented below) and normalize each strategy's P90 goodput by
the hardware cost of the cluster it needs:

  * commodity:   32x L20 + 10 GbE            — NoDG / PaDG run here
  * fudg-ready:  32x L20 + 400 Gb IB fabric  — what FuDG needs for Llama-30B
                 (Table 3: 38.96 GB/s ~ 400 Gbps per node at A800 rates;
                 at L20 rates 9.8 GB/s ~ 100 Gbps, priced accordingly)

Also emits the qualitative Table 5 row set (goodput, load balance,
hardware cost, parallelism compatibility, engineering complexity).
"""
from __future__ import annotations

from benchmarks.common import QUICK_DURATION, emit, make_cost, \
    system_factory, timed
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20
from repro.simulator.metrics import goodput
from repro.simulator.workload import WORKLOADS

# rough build costs (USD), documented assumptions:
GPU_COST = 8_000            # L20 48GB street price
NODE_BASE = 12_000          # chassis/CPU/RAM per 8-GPU node
ETH_10G_PER_NODE = 500      # commodity NIC+switch share
IB_100G_PER_NODE = 7_000    # HDR NIC + switch share + cables
N_NODES, GPUS = 4, 32

COMMODITY = N_NODES * (8 * GPU_COST + NODE_BASE + ETH_10G_PER_NODE)
FUDG_BUILD = N_NODES * (8 * GPU_COST + NODE_BASE + IB_100G_PER_NODE)


def run(quick: bool = True):
    cost = make_cost("llama-30b", GPU_L20, tp=4)
    slo = DATASET_SLOS["sharegpt"]
    profile = WORKLOADS["sharegpt"]
    systems = {
        "ecoserve": COMMODITY,
        "vllm": COMMODITY,
        "mooncake": FUDG_BUILD,   # priced WITH the fabric it needs
    }
    print(f"\n== Table 5 / §6: cost-effectiveness (goodput per $100k) ==")
    print(f"  commodity cluster ${COMMODITY/1e3:.0f}k | FuDG-ready "
          f"${FUDG_BUILD/1e3:.0f}k (+{FUDG_BUILD/COMMODITY-1:+.0%} for IB)")
    out = {}
    for name, build_cost in systems.items():
        fac = system_factory(name, cost, 8, slo)
        g, us = timed(goodput, fac, profile, slo, 0.90,
                      duration=QUICK_DURATION, hi=96.0)
        # FuDG on the IB fabric: transfers stop binding; approximate by
        # the no-transfer upper bound = its own goodput on infinite bw.
        gp = g["goodput"]
        per_100k = gp / (build_cost / 1e5)
        out[name] = {"goodput": gp, "cost": build_cost,
                     "per_100k": per_100k}
        print(f"  {name:12} goodput={gp:6.2f} req/s  build=${build_cost/1e3:5.0f}k"
              f"  -> {per_100k:5.2f} req/s per $100k")
        emit(f"table5_cost_eff_{name}", us, f"per100k={per_100k:.2f}")

    print("\n  qualitative (paper Table 5):")
    rows = [
        ("NoDG", "/", "Good", "Easy", "Low", "Low", "Low"),
        ("FuDG", "//", "Poor", "Hard", "High", "High", "High"),
        ("PaDG", "//", "Excellent", "Easy", "Low", "High", "Low"),
    ]
    hdr = ("strategy", "goodput", "cost-eff", "load-bal", "hw-cost",
           "par-compat", "eng-cmplx")
    print("  " + "".join(f"{h:>11}" for h in hdr))
    for r in rows:
        print("  " + "".join(f"{c:>11}" for c in r))
    if out["mooncake"]["per_100k"] > 0:
        ratio = out["ecoserve"]["per_100k"] / out["mooncake"]["per_100k"]
        print(f"\n  ecoserve is {ratio:.1f}x more cost-effective than "
              f"mooncake-on-IB-priced build")
    return out


if __name__ == "__main__":
    run()
