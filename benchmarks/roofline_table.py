"""Roofline table (deliverable g): reads the dry-run JSONs from
experiments/dryrun and prints the 3-term roofline per (arch x shape x
mesh) with the dominant bottleneck, MODEL_FLOPS ratio, and memory fit."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_results(variant: str = "baseline"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if r.get("variant", "baseline") == variant:
            rows.append(r)
    return rows


def run(quick: bool = True):
    rows = load_results()
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    if not rows:
        print("no dry-run results found; run "
              "`python -m repro.launch.dryrun --all` first")
        return {}
    print(f"\n== Roofline (bf16-projected, TPU v5e: 197TF/s, 819GB/s HBM, "
          f"50GB/s link) ==")
    print(f"{'arch':24}{'shape':13}{'mesh':12}{'compute_s':>10}"
          f"{'memory_s':>10}{'collect_s':>10} {'dominant':10}"
          f"{'useful':>7}{'fits':>6}")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        print(f"{r['arch']:24}{r['shape']:13}{r['mesh']:12}"
              f"{t['compute_s']:10.4f}{t['memory_s']:10.4f}"
              f"{t['collective_s']:10.4f} {t['dominant']:10}"
              f"{r['useful_flops_ratio']:7.3f}"
              f"{'  yes' if r['memory']['fits_hbm'] else '   NO'}")
    print(f"\nskips ({len(skipped)}):")
    for r in skipped:
        print(f"  {r['arch']:24}{r['shape']:13}{r['mesh']:12} {r['reason']}")
    n_ok = len(ok)
    emit("roofline_combos_ok", 0.0, f"{n_ok}")
    dominated = {}
    for r in ok:
        dominated.setdefault(r["roofline"]["dominant"], 0)
        dominated[r["roofline"]["dominant"]] += 1
    print(f"\ndominant-term histogram: {dominated}")
    return {"ok": n_ok, "skipped": len(skipped), "dominant": dominated}


if __name__ == "__main__":
    run()
