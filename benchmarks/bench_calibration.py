"""Sim-to-real calibration bench: replay the checked-in Azure/BurstGPT
trace excerpts through the PaDG server, record per-op step timings, fit
cost-model constants, and report prediction error before vs after.

Two backends:

* **fake** (default, deterministic): the replay runs on the
  ``FakeEngine`` under a ``VirtualClock``; 'measured' timings come from
  a ``SyntheticTruth`` — an affine warp of the analytic roofline model —
  so the fit has a known target and the resulting
  ``CalibrationReport`` is reproducible enough to pin with the
  tolerance-banded golden at ``tests/golden/calibration_report.json``.
  The bench asserts the acceptance claim: fitted constants reduce the
  median per-op prediction error vs the unfitted analytic model.
* **--real**: the same trace excerpt drives live jax ``ServingEngine``
  instances wall-clock on a tiny CPU config; timings are genuinely
  measured, so this row is NOT golden-pinned (CI runs it non-gating).

The saved report feeds the runner's calibrated-executor axis::

    ExperimentRunner(..., calibration=(None, "path/to/report.json"))

    PYTHONPATH=src python -m benchmarks.bench_calibration --smoke \
        --stream rows.jsonl             # deterministic CI cell
    PYTHONPATH=src python -m benchmarks.bench_calibration --real --smoke
    PYTHONPATH=src python -m benchmarks.bench_calibration --write-golden
"""
from __future__ import annotations

import json
import pathlib
import time

from benchmarks.common import emit
from repro.core.slo import SLO
from repro.serving.calibration import (CalibrationRecorder,
                                       CalibrationReport, SyntheticTruth)
from repro.serving.padg_server import PaDGServer
from repro.serving.replay import (SlotConfig, VirtualClock, WallClock,
                                  requests_from_trace)
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.traces import load_fixture, normalize_rate

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "calibration_report.json")

# the deterministic golden cell: both excerpts, rate-normalized so the
# replay finishes quickly under the virtual clock
FIXTURE_RATE = 10.0
PER_FIXTURE_LIMIT = 20
MAX_PROMPT, MAX_OUTPUT = 120, 12
SLOT = SlotConfig(max_batch=4, max_seq_len=160)
SERVE_SLO = SLO(ttft=2.0, tpot=0.2)

# the synthetic ground truth the fake backend 'measures': an affine warp
# of the analytic model (faster decode, slower prefill, small offsets)
TRUTH_WARP = dict(prefill_scale=1.4, prefill_offset=3e-4,
                  decode_scale=0.75, decode_offset=2e-4)


def trace_requests():
    records = []
    for name in ("azure", "burstgpt"):
        recs = normalize_rate(load_fixture(name), FIXTURE_RATE)
        records.extend(recs[:PER_FIXTURE_LIMIT])
    return requests_from_trace(records, max_prompt=MAX_PROMPT,
                               max_output=MAX_OUTPUT, seed=0)


def analytic_model() -> InstanceCostModel:
    from repro.configs import get_config
    return InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)


def build_report(backend: str = "fake") -> CalibrationReport:
    model = analytic_model()
    rec = CalibrationRecorder()
    if backend == "fake":
        truth = SyntheticTruth(base=model, **TRUTH_WARP)
        server = PaDGServer(None, n_instances=2, slo=SERVE_SLO, econf=SLOT,
                            backend="fake", executor=model, recorder=rec,
                            true_model=truth)
        reqs = trace_requests()
        stats = server.serve(reqs, clock=VirtualClock())
        server.shutdown()
        meta = {"backend": "fake", "truth": TRUTH_WARP,
                "fixtures": ["azure", "burstgpt"],
                "rate": FIXTURE_RATE, "n_requests": len(reqs),
                "finished": len(stats.finished)}
        return CalibrationReport.build(rec, model, like=model, meta=meta)

    # --real: tiny live engine, wall clock, measured timings
    import dataclasses as dc

    from repro.configs import get_smoke_config
    from repro.serving.engine import EngineConfig
    from repro.simulator.cost_model import TPU_V5E_SIM

    cfg = get_smoke_config("llama3-8b")
    cfg = dc.replace(cfg, num_layers=2, d_model=128, num_heads=2,
                     num_kv_heads=1, head_dim=64, d_ff=256, vocab_size=300)
    seed_model = InstanceCostModel(cfg=cfg, hw=TPU_V5E_SIM)
    econf = EngineConfig(max_batch=4, max_seq_len=160, eos_token=-1)
    server = PaDGServer(cfg, n_instances=1, slo=SLO(ttft=60.0, tpot=10.0),
                        econf=econf, backend="real")
    records = normalize_rate(load_fixture("azure"), 50.0)[:10]
    reqs = requests_from_trace(records, max_prompt=48, max_output=6,
                               vocab_size=cfg.vocab_size, seed=0)
    # warmup pass over the same prompt lengths, unrecorded: jax compiles
    # one decode kernel per batch shape and one prefill kernel per prompt
    # length, and those one-off compile times would otherwise dominate
    # every measurement
    warm = requests_from_trace(records, max_prompt=48, max_output=6,
                               vocab_size=cfg.vocab_size, seed=1)
    server.serve(warm, clock=WallClock(1.0))
    for inst in server.instances:
        inst.engine.engine.recorder = rec
    stats = server.serve(reqs, clock=WallClock(1.0))
    server.shutdown()
    meta = {"backend": "real", "fixtures": ["azure"],
            "n_requests": len(reqs), "finished": len(stats.finished)}
    return CalibrationReport.build(rec, seed_model, like=seed_model,
                                   meta=meta)


def _stream_row(stream: str, report: CalibrationReport) -> None:
    if not stream:
        return
    with open(stream, "a") as fh:
        fh.write(json.dumps({"bench": "calibration",
                             **report.to_dict()}, sort_keys=True) + "\n")
        fh.flush()


def run(backend: str = "fake", stream: str = None) -> CalibrationReport:
    t0 = time.time()
    report = build_report(backend)
    dt = time.time() - t0
    print(f"\n== sim-to-real calibration ({backend} backend) ==")
    print(f"  samples: {report.n_prefill} prefill ops, "
          f"{report.n_decode} decode ops "
          f"({report.meta.get('finished')} requests finished)")
    print("  per-op relative error (|pred - measured| / measured):")
    print(f"  {'':>10} {'unfitted':>10} {'fitted':>10}")
    for key in ("prefill_median", "prefill_p90", "decode_median",
                "decode_p90", "overall_median"):
        print(f"  {key:>16} {report.unfitted[key]:10.4f} "
              f"{report.fitted[key]:10.4f}")
    if backend == "fake":
        # the acceptance claim — measured constants must beat the
        # roofline model on its own replay (real rows are informational:
        # wall-clock noise on shared CI runners is not assertable)
        assert (report.fitted["overall_median"]
                < report.unfitted["overall_median"]), (
            "fitted constants did not reduce median per-op error: "
            f"{report.fitted} vs {report.unfitted}")
    _stream_row(stream, report)
    emit(f"calibration_{backend}", dt * 1e6,
         f"median_err {report.unfitted['overall_median']:.3f}"
         f"->{report.fitted['overall_median']:.3f}")
    return report


def write_golden() -> None:
    report = build_report("fake")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    report.save(GOLDEN_PATH)
    print(f"wrote calibration report "
          f"({report.n_prefill}+{report.n_decode} ops) to {GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true",
                    help="measure the live jax engine wall-clock "
                    "(non-deterministic; CI runs it non-gating)")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for the default single-cell run (CI)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append the report as one JSONL row")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/calibration_report.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    else:
        run(backend="real" if args.real else "fake", stream=args.stream)
