"""Fault degradation: crashes + spot preemptions under load, EcoServe
vs the FuDG baselines (the reliability claim behind the paper's
homogeneous-pool argument).

Runs ``fault_runner()`` — the canonical grid behind
``tests/golden/fault_scenarios.json``: EcoServe, DistServe, and MoonCake
(all with the ``migrate`` failure policy) on the bursty shape, each cell
four ways over the identical arrival sequence — {fault-free, "gentle"
interruption trace} x {static pool, closed-loop band controller}.  The
gentle trace injects one crash at t=14 and one spot preemption with a
2 s notice at t=26 (``repro.faults``; schedule seeded per cell, so the
grid is bit-reproducible across worker counts).

The headline assertions:

* **graceful degradation** — EcoServe's min-phase attainment under the
  interruption trace stays strictly above every FuDG baseline's: any
  EcoServe survivor serves both phases, notice-window migrations move
  decodes (KV intact) to peers, and the control loop's repair path
  re-provisions the lost capacity; FuDG's role-partitioned pools
  collapse when a fault lands on the scarce role — a dead lone prefill
  instance starves the whole pool, and KV caches in flight to a dead
  decoder are simply lost;
* **capacity repair** — after each injected fault, the autoscaled
  EcoServe cell's trajectory returns to ``n_live == n_target`` within a
  provisioning delay (the PR 5 control loop observes ``n_live`` dropping
  independently of its own decisions and commissions replacements).

    PYTHONPATH=src python -m benchmarks.bench_fault_degradation
    PYTHONPATH=src python -m benchmarks.bench_fault_degradation --smoke \
        --stream rows.jsonl             # the CI cell: crash + preemption
    PYTHONPATH=src python -m benchmarks.bench_fault_degradation \
        --write-golden                  # re-pin the golden fixture
"""
from __future__ import annotations

import pathlib
import time

from benchmarks.common import emit
from repro.simulator.runner import ExperimentRunner, fault_runner

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "fault_scenarios.json")

FAULT_LEVELS = ("none", "itrace:gentle")
CONTROL_LEVELS = ("static", "band")


def _cell_table(results: dict) -> None:
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    rate = meta["rates"][0]
    scen = meta["scenarios"][0]
    print("strategy,controller,faults,att_phase_min,attainment,completion,"
          "lost,migrated,repairs")
    for strat in meta["strategies"]:
        for level in CONTROL_LEVELS:
            for fv in FAULT_LEVELS:
                m = grid[strat][scen][level][fv][rate]
                stats = m.get("faults", {}).get("stats", {})
                tl = m.get("timeline", {})
                repairs = sum(1 for e in tl.get("events", [])
                              if e["action"] == "repair")
                print(f"{strat},{level},{fv},"
                      f"{m['attainment_phase_min']:.4f},"
                      f"{m['attainment']:.4f},{m['completion']:.4f},"
                      f"{stats.get('lost', 0)},{stats.get('migrated', 0)},"
                      f"{repairs}")


def _assert_graceful_degradation(results: dict) -> dict:
    """EcoServe's min-phase attainment under the interruption trace must
    be strictly above every FuDG baseline's, under both the static pool
    and the band controller."""
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    rate = meta["rates"][0]
    scen = meta["scenarios"][0]
    out = {}
    for level in CONTROL_LEVELS:
        eco = grid["ecoserve+migrate"][scen][level]["itrace:gentle"][rate]
        out[level] = {"ecoserve": eco["attainment_phase_min"]}
        for strat in meta["strategies"]:
            if strat.startswith("ecoserve"):
                continue
            fudg = grid[strat][scen][level]["itrace:gentle"][rate]
            out[level][strat] = fudg["attainment_phase_min"]
            assert (eco["attainment_phase_min"]
                    > fudg["attainment_phase_min"]), (
                f"EcoServe must degrade more gracefully than {strat} "
                f"under the interruption trace ({level} pool): "
                f"{eco['attainment_phase_min']:.3f} vs "
                f"{fudg['attainment_phase_min']:.3f}")
    return out


def _assert_capacity_repair(results: dict) -> None:
    """The autoscaled EcoServe cell must record a repair commission after
    each injected fault and its trajectory must return to
    ``n_live == n_target``."""
    cell = next(c for c in results["cells"]
                if c["strategy"] == "ecoserve+migrate"
                and c.get("autoscale") == "band" and c.get("faults"))
    m = cell["metrics"]
    tl = m["timeline"]
    fault_times = [e["t"] for e in m["faults"]["log"]]
    repairs = [e for e in tl["events"] if e["action"] == "repair"]
    assert repairs, "no repair commissions despite injected faults"
    for ft in fault_times:
        later = [p for p in tl["trajectory"] if p["t"] > ft]
        assert later and any(p["n"] == p["n_target"] for p in later), (
            f"control loop never restored n_live == n_target after the "
            f"fault at t={ft}")


def run(stream: str = None):
    runner = fault_runner()
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    assert not results.get("errors"), results.get("errors")
    print("\n== Fault degradation: crashes + spot preemption under "
          "bursty load ==")
    _cell_table(results)
    margins = _assert_graceful_degradation(results)
    _assert_capacity_repair(results)
    print("\n  min-phase attainment under the interruption trace:")
    for level, vals in margins.items():
        ranked = ", ".join(f"{k}={v:.3f}" for k, v in vals.items())
        print(f"    {level}: {ranked}")
    print("  EcoServe strictly above every FuDG baseline; repair "
          "commissions restored n_live == n_target after each fault")
    emit("fault_degradation", dt * 1e6,
         f"cells={len(results['cells'])}")
    return {"results": results, "margins": margins}


def run_smoke(stream: str = None) -> dict:
    """The CI cell: one crash + one spot preemption (the gentle trace)
    on the bursty shape with the band controller — proves the fault
    layer, failure policy, and control-loop repair path end to end."""
    runner = ExperimentRunner(
        strategies=("ecoserve+migrate",), scenarios=("bursty",),
        rates=(8.0,), autoscale=("band",), faults=("itrace:gentle",),
        phases=4,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        workload="sharegpt", duration=48.0, warmup=6.0,
        base_seed=42, n_workers=1, stream_path=stream)
    results = runner.run()
    assert not results.get("errors"), results.get("errors")
    (cell,) = results["cells"]
    m = cell["metrics"]
    applied = m["faults"]["applied"]
    repairs = sum(1 for e in m["timeline"]["events"]
                  if e["action"] == "repair")
    print(f"smoke: gentle trace under band controller attainment="
          f"{m['attainment']:.3f} phase_min={m['attainment_phase_min']:.3f} "
          f"applied={applied} repairs={repairs}")
    assert applied.get("crash") == 1 and applied.get("preempt") == 1, (
        f"gentle trace must land one crash + one preemption, got {applied}")
    assert repairs >= 1, "no repair commission after instance loss"
    assert m["finished"] > 0, "smoke cell ran empty"
    return results


def write_golden() -> None:
    results = fault_runner().run()
    assert not results.get("errors"), results.get("errors")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one crash + one preemption cell (CI)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append one JSONL row per finished cell")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/fault_scenarios.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.smoke:
        run_smoke(stream=args.stream)
    else:
        run(stream=args.stream)
