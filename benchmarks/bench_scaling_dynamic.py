"""Paper Fig. 10: dynamic fine-grained scaling — request rate rises in
steps; the mitosis approach adds instances one at a time; SLO attainment
dips and recovers.  Also measures the serializable-proxy migration
overhead (paper: <100 ms; re-init alternative: ~3 minutes)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_cost, timed
from repro.core.padg_system import EcoServeSystem
from repro.core.slo import DATASET_SLOS, request_meets_slo
from repro.simulator.cost_model import GPU_L20
from repro.simulator.engine import SimulationEngine
from repro.simulator.workload import WORKLOADS, WorkloadGen


def run(quick: bool = True):
    model = "codellama2-34b"
    cost = make_cost(model, GPU_L20, tp=4)
    slo = DATASET_SLOS["sharegpt"]
    profile = WORKLOADS["sharegpt"]

    # rising request rate: steps every `phase` seconds
    phase = 20.0 if quick else 120.0
    rates = [12, 18, 24, 30]
    reqs = []
    t_off, rid = 0.0, 0
    for rate in rates:
        gen = WorkloadGen(profile, rate, seed=rid)
        for r in gen.generate(phase):
            r.arrival_time += t_off
            r.rid = rid
            rid += 1
            reqs.append(r)
        t_off += phase
    reqs.sort(key=lambda r: r.arrival_time)

    system = EcoServeSystem(cost, 4, slo, n_lower=4, n_upper=16)
    engine = SimulationEngine(system)

    # autoscaler: every 5s, if recent attainment < 0.9, add an instance
    window, last_check = [], [0.0]
    scale_events = []

    def tick(now: float):
        if now - last_check[0] >= 5.0:
            last_check[0] = now
            recent = [r for r in engine.finished
                      if r.finish_time and r.finish_time > now - 10.0]
            if recent:
                att = float(np.mean(
                    [request_meets_slo(r, slo) for r in recent]))
                window.append((now, att, system.sched.total_instances))
                if att < 0.9 and system.sched.total_instances < 8:
                    system.scale_up(engine)
                    scale_events.append(now)

    engine.on_tick = tick
    _, us = timed(engine.run, reqs, t_off + phase)

    print(f"\n== Fig 10: dynamic scaling (rate {rates} req/s every "
          f"{phase:.0f}s) ==")
    print(f"  {'t(s)':>6} {'attainment':>11} {'#instances':>11}")
    for t, att, n in window:
        print(f"  {t:6.0f} {att:11.2f} {n:11d}")
    print(f"  scale-up events at t = "
          f"{[round(t, 1) for t in scale_events]}")
    mig = system.sched.migrations
    if mig:
        worst = max(m.seconds for m in mig) * 1e3
        print(f"  handler migrations: {len(mig)}, max {worst:.3f} ms "
              f"(paper: <100 ms; re-init alternative ~3 min)")
    final_att = np.mean([att for _, att, _ in window[-3:]]) if window else 0
    emit("fig10_dynamic_scaling", us,
         f"scaleups={len(scale_events)};final_att={final_att:.2f}")
    assert scale_events, "rising load must trigger mitosis expansion"
    return {"scale_events": scale_events, "window": window}


if __name__ == "__main__":
    run()
