"""Paper Fig. 10 under non-stationary traffic: closed-loop dynamic
scaling on the unified runner.

The original bench hand-rolled one rising-step workload and an inline
autoscaler lambda; this version runs ``dynamic_scaling_runner()`` — the
canonical grid behind ``tests/golden/dynamic_scaling.json`` — instead:
EcoServe under every load-shifting arrival shape (MMPP bursty, diurnal,
ramp) *and* the two converted real-trace excerpts (Azure LLM inference,
BurstGPT; ``repro.traces``), each over the identical arrival sequence
three ways: static 4-instance baseline, the closed-loop target-band
controller, and the trace-oblivious threshold ablation
(``repro.control``).

Beyond the grid, the bench reports two claims the golden can't:

* **offline-optimal tracking** — for the bursty cell, static sweeps at
  every pool size give the per-phase offline-optimal instance count
  (min n meeting the attainment target); the controller's time-weighted
  mean pool size must track it within one instance;
* **migration overhead** — autoscaled EcoServe scale-ups run through
  ``OverallScheduler.add_instance`` (mitosis expansion/split), so
  handler migrations happen live; the serializable-proxy move stays
  <100 ms (paper §3.5.2; re-init alternative ~3 minutes).

    PYTHONPATH=src python -m benchmarks.bench_scaling_dynamic
    PYTHONPATH=src python -m benchmarks.bench_scaling_dynamic --smoke \
        --stream rows.jsonl             # the CI cell: converted trace
    PYTHONPATH=src python -m benchmarks.bench_scaling_dynamic \
        --write-golden                  # re-pin the golden fixture
"""
from __future__ import annotations

import functools
import pathlib
import time

from benchmarks.common import emit, make_cost
from repro.baselines import make_system
from repro.core.slo import DATASET_SLOS
from repro.simulator.metrics import phase_edges, run_once
from repro.simulator.runner import ExperimentRunner, dynamic_scaling_runner
from repro.simulator.scenarios import make_scenario

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "tests" / "golden" / "dynamic_scaling.json")

CONTROL_LEVELS = ("static", "band", "threshold")


def _cell_table(results: dict) -> None:
    grid = ExperimentRunner.grid(results)
    meta = results["meta"]
    rate = meta["rates"][0]
    print("scenario,controller,attainment,att_phase_min,"
          "scale_ups,scale_downs,n_max,n_final")
    for scen in meta["scenarios"]:
        for level in CONTROL_LEVELS:
            m = grid["ecoserve"][scen][level][rate]
            tl = m.get("timeline", {})
            print(f"{scen},{level},{m['attainment']:.4f},"
                  f"{m['attainment_phase_min']:.4f},"
                  f"{tl.get('n_scale_ups', 0)},"
                  f"{tl.get('n_scale_downs', 0)},"
                  f"{tl.get('n_max', meta['n_instances'])},"
                  f"{tl.get('n_final', meta['n_instances'])}")


def _offline_optimal_tracking(results: dict) -> dict:
    """Per-phase offline-optimal pool size (min static count meeting the
    attainment target, from static sweeps at every size) vs the
    controller's time-weighted mean pool.  The tracking claim is
    asserted on the *diurnal* shape: its shifts are slower than the
    controller's cooldowns, so tracking is achievable in principle —
    MMPP bursts flip faster than any cooldown-honoring controller can
    follow, so bursty/ramp gaps are reported, not asserted."""
    from repro.control import ScalingTimeline

    meta = results["meta"]
    rate, duration, warmup = (meta["rates"][0], meta["duration"],
                              meta["warmup"])
    n_phases = meta["phases"]
    target = 0.9
    cost = make_cost(meta["model"], tp=meta["tp"], pp=meta["pp"])
    slo = DATASET_SLOS[meta["workload"]]
    counts = range(2, 9)
    out = {}
    for kind in ("diurnal", "ramp", "bursty"):
        cell = next(c for c in results["cells"]
                    if c["scenario"] == kind and c["autoscale"] == "band")
        phase_att = {}
        for n in counts:
            scen = make_scenario(kind, meta["workload"], rate,
                                 seed=cell["seed"])
            m = run_once(functools.partial(make_system, "ecoserve", cost,
                                           n, slo),
                         scen, rate, slo, duration=duration,
                         warmup=warmup, seed=cell["seed"],
                         phases=n_phases)
            phase_att[n] = m["attainment_by_phase"]
        optimal = [min((n for n in counts if phase_att[n][p] >= target),
                       default=max(counts))
                   for p in range(n_phases)]
        timeline = ScalingTimeline(
            trajectory=cell["metrics"]["timeline"]["trajectory"])
        edges = phase_edges(duration, warmup, n_phases)
        tracked = [timeline.mean_instances(lo, hi)
                   for lo, hi in zip(edges, edges[1:])]
        gaps = [abs(got - opt) for opt, got in zip(optimal, tracked)]
        mean_gap = sum(gaps) / len(gaps)
        print(f"\n  offline-optimal tracking ({kind}, band controller):")
        print(f"  {'phase':>6} {'n_optimal':>10} {'n_controller':>13}")
        for p, (opt, got) in enumerate(zip(optimal, tracked)):
            print(f"  {p:6d} {opt:10d} {got:13.2f}")
        print(f"  mean |controller - optimal| = {mean_gap:.2f} instances")
        out[kind] = {"optimal": optimal, "tracked": tracked,
                     "mean_gap": mean_gap}
    assert out["diurnal"]["mean_gap"] <= 1.0, (
        "closed-loop controller drifted more than one instance from the "
        "offline-optimal pool size on the diurnal shape: "
        f"{out['diurnal']['mean_gap']:.2f}")
    return out


def _migration_overhead() -> None:
    """Drive one in-process autoscaled burst so mitosis expansion (and
    its handler migrations) happen live, then report the proxy overhead."""
    from repro.control import ControlLoopHarness, make_controller
    from repro.simulator.engine import SimulationEngine

    cost = make_cost("llama-30b", tp=4)
    slo = DATASET_SLOS["sharegpt"]
    # N_u = 4 so closed-loop expansion past four instances forces a
    # macro split (Fig. 7 step 2) and therefore handler migrations
    system = make_system("ecoserve", cost, 2, slo, n_lower=2, n_upper=4)
    scen = make_scenario("bursty", "sharegpt", 20.0, seed=5)
    engine = SimulationEngine(system)
    ControlLoopHarness(system, engine,
                       make_controller("band:max=10")).attach()
    engine.run(scen.generate(40.0), horizon=100.0)
    mig = system.sched.migrations
    if mig:
        worst = max(m.seconds for m in mig) * 1e3
        print(f"\n  handler migrations under autoscaling: {len(mig)}, "
              f"max {worst:.3f} ms (paper: <100 ms; re-init ~3 min)")
        assert worst < 100.0, "serializable-proxy migration regressed"
    else:
        print("\n  (no macro split under this burst: no migrations)")


def run(quick: bool = True, stream: str = None):
    runner = dynamic_scaling_runner()
    runner.stream_path = stream
    t0 = time.time()
    results = runner.run()
    dt = time.time() - t0
    assert not results.get("errors"), results.get("errors")
    print("\n== Fig 10 (closed-loop): dynamic scaling under "
          "non-stationary traffic ==")
    _cell_table(results)
    grid = ExperimentRunner.grid(results)
    rate = results["meta"]["rates"][0]
    improved = [
        scen for scen in results["meta"]["scenarios"]
        if grid["ecoserve"][scen]["band"][rate]["attainment_phase_min"]
        > grid["ecoserve"][scen]["static"][rate]["attainment_phase_min"]]
    print(f"\n  closed-loop beats the static pool on min-phase "
          f"attainment for: {improved}")
    assert {"bursty", "trace:azure", "trace:burstgpt"} <= set(improved), \
        "closed-loop must beat static on the bursty + converted traces"
    tracking = None
    if not quick:
        tracking = _offline_optimal_tracking(results)
    _migration_overhead()
    emit("fig10_dynamic_scaling", dt * 1e6,
         f"improved={len(improved)}/{len(results['meta']['scenarios'])}")
    return {"results": results, "improved": improved,
            "tracking": tracking}


def run_smoke(stream: str = None) -> dict:
    """The CI cell: one converted-trace excerpt, quick horizon, closed
    loop on — proves trace ingestion + control plane end to end."""
    runner = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("trace:azure",),
        rates=(12.0,), autoscale=("band",), phases=4,
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=2,
        workload="sharegpt", duration=20.0, warmup=3.0,
        base_seed=42, n_workers=1, stream_path=stream)
    results = runner.run()
    assert not results.get("errors"), results.get("errors")
    (cell,) = results["cells"]
    m = cell["metrics"]
    tl = m["timeline"]
    print(f"smoke: trace:azure band controller attainment="
          f"{m['attainment']:.3f} phase_min={m['attainment_phase_min']:.3f} "
          f"ups={tl['n_scale_ups']} n_final={tl['n_final']}")
    assert m["finished"] > 0 and tl["trajectory"], "smoke cell ran empty"
    return results


def write_golden() -> None:
    results = dynamic_scaling_runner().run()
    assert not results.get("errors"), results.get("errors")
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    ExperimentRunner.save(results, GOLDEN_PATH)
    print(f"wrote {len(results['cells'])} cells to {GOLDEN_PATH}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="include the offline-optimal tracking sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="one converted-trace autoscaled cell (CI)")
    ap.add_argument("--stream", default=None, metavar="PATH",
                    help="append one JSONL row per finished cell")
    ap.add_argument("--write-golden", action="store_true",
                    help="regenerate tests/golden/dynamic_scaling.json")
    args = ap.parse_args()
    if args.write_golden:
        write_golden()
    elif args.smoke:
        run_smoke(stream=args.stream)
    else:
        run(quick=not args.full, stream=args.stream)
