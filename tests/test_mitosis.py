"""Mitosis scaling: expansion/split, contraction/merge (Fig. 7 semantics)
and the serializable InstanceHandler proxy."""
import pickle

import pytest

from repro.core.instance import Instance
from repro.core.mitosis import InstanceHandler, OverallScheduler, \
    StaleHandlerError, register_instance, registry_size
from repro.core.slo import SLO


class Exec:
    def prefill_time(self, lens):
        return 1e-4 * sum(lens)

    def decode_time(self, b, c):
        return 0.02


def make_inst(i):
    inst = Instance(i, Exec(), kv_capacity_tokens=10_000)
    register_instance(inst)
    return inst


def make_sched(n_l=3, n_u=6):
    return OverallScheduler(SLO(1.0, 0.1), lambda n: 1e-4 * n,
                            n_lower=n_l, n_upper=n_u)


def test_expansion_splits_at_upper_bound():
    """Fig. 7 steps 1-4 with N_l=3, N_u=6."""
    s = make_sched()
    for i in range(6):
        s.add_instance(make_inst(i))
    assert s.sizes() == [6]
    # 7th instance: split off a new macro with N_l instances
    s.add_instance(make_inst(6))
    assert s.sizes() == [3, 4]
    # further instances fill the fullest non-full macro first (step 3)
    s.add_instance(make_inst(7))
    assert s.sizes() == [3, 5]
    for i in range(8, 10):
        s.add_instance(make_inst(i))
    assert s.sizes() == [4, 6]


def test_contraction_merges_at_upper_bound():
    """Fig. 7 steps 5-8: shrink smallest to N_l, then a full one; merge
    when the two smallest jointly hold N_u."""
    s = make_sched()
    for i in range(10):
        s.add_instance(make_inst(i))
    assert s.sizes() == [4, 6]
    removed = s.remove_instance()       # smallest (4) -> 3 == N_l
    assert removed is not None
    assert s.sizes() == [3, 6]
    s.remove_instance()                 # smallest at N_l -> shrink the full
    assert s.sizes() == [3, 5]
    s.remove_instance()                 # 3 + 4 <= N_u == 6? no: 7 > 6
    assert s.sizes() == [3, 4]
    s.remove_instance()                 # now 3+3 = 6 <= N_u -> merge
    assert s.sizes() == [6]
    assert len(s.macros) == 1


def test_total_instances_preserved_through_split_and_merge():
    s = make_sched()
    for i in range(13):
        s.add_instance(make_inst(i))
    assert s.total_instances == 13
    for _ in range(5):
        s.remove_instance()
    assert s.total_instances == 8


def test_instance_handler_pickle_roundtrip_resolves_same_object():
    inst = make_inst(777)
    h = InstanceHandler.for_instance(inst, address="node3:7011", tp=4)
    blob = h.serialize()
    assert isinstance(blob, bytes)
    h2 = InstanceHandler.deserialize(blob)
    assert h2.actor_id == 777
    assert h2.worker_address == "node3:7011"
    assert h2.capabilities == {"tp": 4}
    # logical migration: the proxy resolves to the SAME running instance,
    # no re-initialization
    assert h2.resolve() is inst


def test_migration_records_fast():
    s = make_sched()
    for i in range(7):          # forces one split -> migrations recorded
        s.add_instance(make_inst(100 + i))
    assert s.migrations
    for m in s.migrations:
        assert m.seconds < 0.1   # paper: <100 ms; pickle is microseconds


def test_registry_does_not_leak_through_scale_churn():
    """Regression for the actor-registry leak: contraction/merge used to
    leave retired instances registered forever, so repeated scale churn
    grew ``_ACTOR_REGISTRY`` without bound.  Churn must return the
    registry exactly to its pre-churn size."""
    baseline = registry_size()
    s = make_sched()
    for cycle in range(3):
        for i in range(7):          # crosses the split threshold
            s.add_instance(make_inst(1000 + cycle * 10 + i))
        assert registry_size() == baseline + 7
        for _ in range(7):          # crosses the merge threshold back
            assert s.remove_instance() is not None
        assert registry_size() == baseline, f"leak on cycle {cycle}"
    assert s.total_instances == 0


def test_discard_instance_unregisters_named_victim():
    """Fault teardown removes a *specific* instance (not the contraction
    heuristic's pick) and must unregister it too."""
    baseline = registry_size()
    s = make_sched()
    insts = [make_inst(2000 + i) for i in range(4)]
    for inst in insts:
        s.add_instance(inst)
    victim = insts[2]
    assert s.discard_instance(victim)
    assert registry_size() == baseline + 3
    assert s.total_instances == 3
    assert not s.discard_instance(victim)    # already gone: no double-pop


def test_stale_handler_resolve_raises_clear_error():
    s = make_sched()
    inst = make_inst(3000)
    s.add_instance(inst)
    h = InstanceHandler.for_instance(inst)
    blob = h.serialize()
    s.discard_instance(inst)                 # unregisters the actor
    with pytest.raises(StaleHandlerError, match="3000"):
        InstanceHandler.deserialize(blob).resolve()


def test_dead_instance_handler_resolve_raises():
    """A handler to a registered-but-dead instance (crashed mid-decode)
    must not resolve: migrating work onto a corpse corrupts state."""
    inst = make_inst(3001)
    h = InstanceHandler.for_instance(inst)
    inst.alive = False
    with pytest.raises(StaleHandlerError):
        h.resolve()


def test_migration_does_not_interrupt_execution():
    """An instance keeps its in-flight work across a handler migration."""
    from repro.core.request import Request
    s = make_sched()
    insts = [make_inst(200 + i) for i in range(6)]
    for inst in insts:
        s.add_instance(inst)
    victim = insts[0]
    victim.admit(Request(rid=1, arrival_time=0, prompt_len=50,
                         output_len=5), 0.0)
    kind, dur, batch = victim.next_slot(0.0)
    assert kind == "prefill"
    s.add_instance(make_inst(299))          # triggers split + migration
    # the in-flight slot completes untouched
    victim.complete_slot(kind, batch, dur)
    assert victim.decoding or victim._finished
