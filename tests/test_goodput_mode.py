"""Goodput-frontier mode of the ExperimentRunner (paper Fig. 8): the
in-worker binary search over request rates, its golden regression
fixture, per-cell crash capture, and JSONL row streaming.

Regenerate the fixture (after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden-goodput
"""
import json
import pathlib

import pytest

from repro.simulator.runner import (ExperimentRunner, _run_cell_safe,
                                    goodput_runner)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "goodput_frontier.json"


# --------------------------------------------------------------------- #
# golden frontier
# --------------------------------------------------------------------- #
def test_goodput_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(GOLDEN)
    fresh = goodput_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "goodput grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "goodput frontier no longer reproduces the golden metrics; if "
        "intentional, regenerate with `python -m benchmarks."
        "bench_scenarios --write-golden-goodput` and review the diff")


def test_goodput_golden_is_a_sane_frontier():
    golden = ExperimentRunner.load(GOLDEN)
    grid = ExperimentRunner.grid(golden)
    # every (strategy, scenario) cell carries a searched rate + probes
    for strat in ("ecoserve", "vllm", "mooncake"):
        for scen in ("poisson", "bursty"):
            cell = grid[strat][scen]
            assert cell["goodput"] > 0.0, (strat, scen)
            assert cell["probes"] >= 2, (strat, scen)
    # headline claims at the frontier: PaDG beats NoDG under poisson,
    # and FuDG over commodity Ethernet trails both (paper Fig. 8)
    assert grid["ecoserve"]["poisson"]["goodput"] >= \
        0.8 * grid["vllm"]["poisson"]["goodput"]
    assert grid["mooncake"]["poisson"]["goodput"] < \
        grid["ecoserve"]["poisson"]["goodput"]


def test_goodput_cells_have_one_seed_per_strategy_scenario():
    specs = goodput_runner().cells()
    assert all(s["mode"] == "goodput" and "rate" not in s for s in specs)
    seeds = {s["seed"] for s in specs}
    assert len(seeds) == len(specs)


def test_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        ExperimentRunner(mode="bogus")


# --------------------------------------------------------------------- #
# crash capture + streaming
# --------------------------------------------------------------------- #
def _tiny_runner(**kw):
    return ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",), rates=(2.0,),
        model="llama-30b", hw="L20", tp=4, n_instances=2,
        duration=5.0, warmup=1.0, base_seed=7, n_workers=1, **kw)


def test_failed_cell_reports_spec_instead_of_poisoning_grid():
    idx, row = _run_cell_safe((3, {"strategy": "no-such-strategy",
                                   "scenario": "poisson", "rate": 1.0,
                                   "model": "llama-30b", "hw": "L20",
                                   "tp": 4, "pp": 1, "n_instances": 2,
                                   "workload": "sharegpt",
                                   "duration": 5.0, "warmup": 1.0,
                                   "seed": 1}))
    assert idx == 3
    assert "error" in row and "KeyError" in row["error"]
    assert row["strategy"] == "no-such-strategy"   # spec preserved
    assert "traceback" in row


def test_runner_surfaces_errors_and_keeps_good_cells():
    r = _tiny_runner()
    r.strategies = ("ecoserve", "no-such-strategy")
    results = r.run()
    assert len(results["cells"]) == 2
    good = [c for c in results["cells"] if "metrics" in c]
    bad = [c for c in results["cells"] if "error" in c]
    assert len(good) == 1 and len(bad) == 1
    assert results["errors"][0]["strategy"] == "no-such-strategy"
    assert "traceback" not in results["errors"][0]


def test_streaming_writes_one_jsonl_row_per_cell(tmp_path):
    path = tmp_path / "rows.jsonl"
    results = _tiny_runner(stream_path=str(path)).run()
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert len(lines) == len(results["cells"]) == 1
    assert lines[0]["cell_index"] == 0
    assert lines[0]["metrics"] == results["cells"][0]["metrics"]
    # append semantics: a second run extends the log (interrupt recovery)
    _tiny_runner(stream_path=str(path)).run()
    assert len(path.read_text().splitlines()) == 2
