"""Unit tests for the HLO cost parser (roofline derivation)."""
import textwrap

from repro.roofline.analysis import roofline_terms
from repro.roofline.hlo_costs import analyze_hlo

HLO = textwrap.dedent("""\
    HloModule jit_step

    %add (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %z = f32[] add(%x, %y)
    }

    %body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,128] get-tuple-element(%p), index=1
      %w = f32[128,128] constant({...})
      %dot.1 = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,128] all-reduce(%dot.1), replica_groups={}, to_apply=%add
      ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[8,128])) -> pred[] {
      %p = (s32[], f32[8,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(4)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,128]) -> f32[8,128] {
      %a = f32[8,128] parameter(0)
      %w0 = f32[128,128] constant({...})
      %dot.0 = f32[8,128] dot(%a, %w0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %init = (s32[], f32[8,128]) tuple(%c0, %dot.0)
      %while.1 = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
      ROOT %out = f32[8,128] get-tuple-element(%while.1), index=1
    }
    """)


def test_dot_flops_with_loop_trip_counts():
    hc = analyze_hlo(HLO)
    # dot flops: 2*8*128*128 once (entry) + 4x in the while body
    one_dot = 2 * 8 * 128 * 128
    assert hc.flops == one_dot * (1 + 4)


def test_collective_bytes_with_trip_and_ring_factor():
    hc = analyze_hlo(HLO)
    ar_bytes = 8 * 128 * 4
    assert hc.wire_bytes == ar_bytes * 2.0 * 4       # ring 2x, 4 trips
    assert hc.collectives["all-reduce"]["count"] == 4


def test_memory_counts_loop_body():
    hc = analyze_hlo(HLO)
    assert hc.hbm_bytes > 0
    # the body's dot reads x(4KiB)+w(64KiB)+writes 4KiB, 4 trips at least
    assert hc.hbm_bytes >= (8 * 128 * 4 * 2 + 128 * 128 * 4) * 4


def test_roofline_terms_dominant():
    t = roofline_terms(1e15, 1e9, 1e6)
    assert t["dominant"] == "compute"
    t = roofline_terms(1e12, 1e13, 1e6)
    assert t["dominant"] == "memory"
    t = roofline_terms(1e12, 1e9, 1e12)
    assert t["dominant"] == "collective"
