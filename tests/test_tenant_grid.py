"""Deterministic regression layer for the multi-tenant SLO-class stack.

``tests/golden/tenant_grid.json`` pins the per-class attainment grid for
2 SLO classes x 3 strategies x 2 traffic shapes bit-exactly;
``tests/golden/static_scaling.json`` pins the ``n_instances`` grid axis
(Fig. 9 folded into the unified runner).  Regenerate both (after an
*intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden-tenants

The single-tenant equivalence tests at the bottom are the no-RNG-drift
guarantee: a one-tenant ``MixedScenario`` + single-class ``SLOClassSet``
must reproduce the legacy ``scenario_grid.json`` rows bit-exactly.
"""
import functools
import json
import pathlib

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS, SLOClassSet
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import run_once
from repro.simulator.runner import (ExperimentRunner, cell_seed,
                                    static_scaling_runner, tenant_runner)
from repro.simulator.scenarios import make_mixed_scenario

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
TENANT_GOLDEN = GOLDEN_DIR / "tenant_grid.json"
STATIC_GOLDEN = GOLDEN_DIR / "static_scaling.json"
SCENARIO_GOLDEN = GOLDEN_DIR / "scenario_grid.json"


# --------------------------------------------------------------------- #
# golden grids
# --------------------------------------------------------------------- #
def test_tenant_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    fresh = tenant_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "tenant grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "tenant grid no longer reproduces the golden metrics; if the "
        "change is intentional, regenerate with `python -m benchmarks."
        "bench_scenarios --write-golden-tenants` and review the diff")


def test_tenant_golden_covers_classes_and_strategies():
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    strategies = {c["strategy"] for c in golden["cells"]}
    assert len(strategies) >= 2
    for cell in golden["cells"]:
        by_class = cell["metrics"]["attainment_by_class"]
        assert len(by_class) >= 2, cell["strategy"]
        assert set(by_class) == set(cell["tenants"])
        assert cell["metrics"]["attainment_min"] == \
            min(by_class.values())


def test_tenant_golden_shows_slo_aware_admission_helps_tight_class():
    """EcoServe's per-class admission must keep the tight-TTFT tenant
    (alpaca, 1.0 s budget) healthier than the SLO-blind baselines do —
    the headline claim of the mixed-tenant scenario family."""
    grid = ExperimentRunner.grid(ExperimentRunner.load(TENANT_GOLDEN))
    for scen in ("poisson", "bursty"):
        eco = grid["ecoserve"][scen][6.0]["attainment_by_class"]["alpaca"]
        for baseline in ("vllm", "mooncake"):
            other = grid[baseline][scen][6.0][
                "attainment_by_class"]["alpaca"]
            assert eco > other, (scen, baseline, eco, other)


def test_static_scaling_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(STATIC_GOLDEN)
    fresh = static_scaling_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"]
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "static-scaling grid no longer reproduces the golden metrics; "
        "regenerate with --write-golden-tenants if intentional")


# --------------------------------------------------------------------- #
# grid axes: seeds and pivot
# --------------------------------------------------------------------- #
def test_cell_seed_extra_preserves_legacy_and_separates_axes():
    legacy = cell_seed(42, "ecoserve", "poisson", 6.0)
    assert cell_seed(42, "ecoserve", "poisson", 6.0, extra="") == legacy
    tagged = cell_seed(42, "ecoserve", "poisson", 6.0,
                       extra="tenants=alpaca+longbench")
    n2 = cell_seed(42, "ecoserve", "poisson", 6.0, extra="n=2")
    assert len({legacy, tagged, n2}) == 3


def test_instance_count_axis_gives_distinct_specs_and_pivot():
    r = static_scaling_runner()
    specs = r.cells()
    assert [s["n_instances"] for s in specs] == [2, 4]
    assert len({s["seed"] for s in specs}) == 2
    grid = ExperimentRunner.grid(ExperimentRunner.load(STATIC_GOLDEN))
    assert set(grid["ecoserve"]["poisson"]) == {2, 4}
    assert set(grid["ecoserve"]["poisson"][2]) == {6.0}


def test_tenant_cells_carry_tenants_and_meta_roundtrip():
    r = tenant_runner()
    for spec in r.cells():
        assert spec["tenants"] == ["alpaca", "longbench"]
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    assert golden["meta"]["tenants"] == ["alpaca", "longbench"]
    # legacy single-class grids must NOT grow a tenants key
    legacy_meta = ExperimentRunner.load(SCENARIO_GOLDEN)["meta"]
    assert "tenants" not in legacy_meta


# --------------------------------------------------------------------- #
# no-RNG-drift acceptance: single-tenant MixedScenario == legacy rows
# --------------------------------------------------------------------- #
COST = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)


@pytest.mark.parametrize("strategy", ["ecoserve", "vllm"])
def test_single_tenant_mixed_scenario_reproduces_legacy_golden(strategy):
    golden = ExperimentRunner.load(SCENARIO_GOLDEN)
    cell = next(c for c in golden["cells"]
                if c["strategy"] == strategy and c["scenario"] == "poisson")
    slo = SLOClassSet.single(DATASET_SLOS[cell["workload"]],
                             name=cell["workload"])
    scen = make_mixed_scenario("poisson", [cell["workload"]],
                               cell["rate"], seed=cell["seed"])
    m = run_once(functools.partial(make_system, strategy, COST,
                                   cell["n_instances"], slo),
                 scen, cell["rate"], slo,
                 duration=cell["duration"], warmup=cell["warmup"],
                 seed=cell["seed"])
    got = {k: m[k] for k in cell["metrics"]}
    assert got == cell["metrics"], (
        "single-tenant MixedScenario drifted from the legacy golden row")
