"""Deterministic regression layer for the multi-tenant SLO-class stack.

``tests/golden/tenant_grid.json`` pins the per-class attainment grid for
2 SLO classes x 3 strategies x 2 traffic shapes bit-exactly;
``tests/golden/static_scaling.json`` pins the ``n_instances`` grid axis
(Fig. 9 folded into the unified runner).  Regenerate both (after an
*intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden-tenants

The single-tenant equivalence tests at the bottom are the no-RNG-drift
guarantee: a one-tenant ``MixedScenario`` + single-class ``SLOClassSet``
must reproduce the legacy ``scenario_grid.json`` rows bit-exactly.
"""
import functools
import json
import pathlib

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS, SLOClassSet
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import run_once
from repro.simulator.runner import (ExperimentRunner, cell_seed,
                                    static_scaling_runner, tenant_runner)
from repro.simulator.scenarios import make_mixed_scenario

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
TENANT_GOLDEN = GOLDEN_DIR / "tenant_grid.json"
STATIC_GOLDEN = GOLDEN_DIR / "static_scaling.json"
SCENARIO_GOLDEN = GOLDEN_DIR / "scenario_grid.json"


# --------------------------------------------------------------------- #
# golden grids
# --------------------------------------------------------------------- #
def test_tenant_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    fresh = tenant_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "tenant grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "tenant grid no longer reproduces the golden metrics; if the "
        "change is intentional, regenerate with `python -m benchmarks."
        "bench_scenarios --write-golden-tenants` and review the diff")


def test_tenant_golden_covers_classes_and_strategies():
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    strategies = {c["strategy"] for c in golden["cells"]}
    assert len(strategies) >= 2
    for cell in golden["cells"]:
        by_class = cell["metrics"]["attainment_by_class"]
        assert len(by_class) >= 2, cell["strategy"]
        assert set(by_class) == set(cell["tenants"])
        assert cell["metrics"]["attainment_min"] == \
            min(by_class.values())


def test_tenant_golden_shows_slo_aware_admission_helps_tight_class():
    """EcoServe's per-class admission must keep the tight-TTFT tenant
    (alpaca, 1.0 s budget) healthier than the SLO-blind baselines do —
    the headline claim of the mixed-tenant scenario family."""
    grid = ExperimentRunner.grid(ExperimentRunner.load(TENANT_GOLDEN))
    for scen in ("poisson", "bursty"):
        eco = grid["ecoserve"][scen][6.0]["attainment_by_class"]["alpaca"]
        for baseline in ("vllm", "mooncake"):
            other = grid[baseline][scen][6.0][
                "attainment_by_class"]["alpaca"]
            assert eco > other, (scen, baseline, eco, other)


def test_tenant_golden_priority_composition_beats_blind_vllm():
    """ISSUE acceptance (pinned in the golden, so it can never silently
    regress): the SLO-aware NoDG composition ``vllm+priority`` keeps the
    tight-TTFT alpaca class strictly healthier than blind vLLM on every
    traffic shape of the mixed-tenant smoke grid; same for sarathi's."""
    grid = ExperimentRunner.grid(ExperimentRunner.load(TENANT_GOLDEN))
    for scen in ("poisson", "bursty"):
        blind = grid["vllm"][scen][6.0]["attainment_by_class"]["alpaca"]
        for aware_name in ("vllm+priority", "sarathi+priority"):
            aware = grid[aware_name][scen][6.0][
                "attainment_by_class"]["alpaca"]
            assert aware > blind, (scen, aware_name, aware, blind)


def test_tenant_golden_rows_are_self_documenting():
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    for cell in golden["cells"]:
        desc = cell["system"]
        assert desc["strategy"] == cell["strategy"]
        assert {"base", "queue", "admission", "routing",
                "provenance"} <= set(desc)
    by_strat = {c["strategy"]: c["system"] for c in golden["cells"]}
    assert by_strat["vllm+priority"]["queue"] == "slo-priority"
    assert by_strat["vllm"]["queue"] == "fifo"


def test_static_scaling_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(STATIC_GOLDEN)
    fresh = static_scaling_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"]
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "static-scaling grid no longer reproduces the golden metrics; "
        "regenerate with --write-golden-tenants if intentional")


# --------------------------------------------------------------------- #
# grid axes: seeds and pivot
# --------------------------------------------------------------------- #
def test_cell_seed_extra_preserves_legacy_and_separates_axes():
    legacy = cell_seed(42, "ecoserve", "poisson", 6.0)
    assert cell_seed(42, "ecoserve", "poisson", 6.0, extra="") == legacy
    tagged = cell_seed(42, "ecoserve", "poisson", 6.0,
                       extra="tenants=alpaca+longbench")
    n2 = cell_seed(42, "ecoserve", "poisson", 6.0, extra="n=2")
    assert len({legacy, tagged, n2}) == 3


def test_instance_count_axis_gives_distinct_specs_and_pivot():
    r = static_scaling_runner()
    specs = r.cells()
    assert [s["n_instances"] for s in specs] == [2, 4]
    assert len({s["seed"] for s in specs}) == 2
    grid = ExperimentRunner.grid(ExperimentRunner.load(STATIC_GOLDEN))
    assert set(grid["ecoserve"]["poisson"]) == {2, 4}
    assert set(grid["ecoserve"]["poisson"][2]) == {6.0}


def test_tenant_shares_and_shapes_thread_through_runner():
    """Rich tenant entries: explicit shares and per-tenant arrival
    shapes flow from the grid spec into the scenario, and the seed-key
    encoding distinguishes them from (and preserves) the legacy
    equal-share cells."""
    rich = (("alpaca", 0.7, "bursty"), ("longbench", 0.3, "diurnal"))
    r = ExperimentRunner(
        strategies=("vllm",), scenarios=("poisson",), rates=(6.0,),
        tenants=rich, model="llama-30b", hw="L20", tp=4, pp=1,
        n_instances=4, duration=20.0, warmup=3.0, base_seed=42)
    spec = r.cells()[0]
    assert spec["tenants"] == [["alpaca", 0.7, "bursty"],
                               ["longbench", 0.3, "diurnal"]]
    # legacy plain-name tuples keep their PR 3 seed encoding...
    legacy = tenant_runner().cells()[0]
    assert legacy["seed"] == cell_seed(
        42, legacy["strategy"], "poisson", 6.0,
        extra="tenants=alpaca+longbench")
    # ...while share/shape-qualified entries get their own seeds
    assert spec["seed"] == cell_seed(
        42, "vllm", "poisson", 6.0,
        extra="tenants=alpaca:0.7:bursty+longbench:0.3:diurnal")
    assert spec["seed"] != cell_seed(
        42, "vllm", "poisson", 6.0, extra="tenants=alpaca+longbench")
    # the scenario the worker builds honours both knobs
    scen = make_mixed_scenario(spec["scenario"], spec["tenants"],
                               spec["rate"], seed=spec["seed"])
    by_class = {t.slo_class: t for t in scen.tenants}
    assert by_class["alpaca"].arrivals.rate == pytest.approx(0.7 * 6.0)
    assert by_class["longbench"].arrivals.rate == pytest.approx(0.3 * 6.0)
    assert type(by_class["alpaca"].arrivals).__name__ == "BurstyArrivals"
    assert type(by_class["longbench"].arrivals).__name__ == \
        "DiurnalArrivals"


def test_mixed_scenario_share_remainder_and_identity_seeding():
    """Entries without an explicit share split the unclaimed remainder;
    giving one tenant a share/shape never moves another tenant's RNG
    stream (identity seeding)."""
    base = make_mixed_scenario("poisson", ["alpaca", "longbench"], 8.0,
                               seed=5)
    rich = make_mixed_scenario("poisson",
                               [("alpaca", 0.5), "longbench"], 8.0, seed=5)
    assert {t.slo_class: t.arrivals.rate for t in rich.tenants} == \
        {"alpaca": 4.0, "longbench": 4.0}
    lb_base = [r for r in base.generate(60.0) if r.slo_class == "longbench"]
    shaped = make_mixed_scenario(
        "poisson", [("alpaca", 0.5, "bursty"), "longbench"], 8.0, seed=5)
    lb_shaped = [r for r in shaped.generate(60.0)
                 if r.slo_class == "longbench"]
    assert [(r.arrival_time, r.prompt_len, r.output_len)
            for r in lb_base] == \
        [(r.arrival_time, r.prompt_len, r.output_len) for r in lb_shaped]
    with pytest.raises(ValueError, match="shares sum"):
        make_mixed_scenario("poisson",
                            [("alpaca", 0.8), ("longbench", 0.8)], 8.0)
    # all-explicit shares must cover the rate — a silent shortfall would
    # mislabel the row's offered load
    with pytest.raises(ValueError, match="not 1"):
        make_mixed_scenario("poisson",
                            [("alpaca", 0.5), ("longbench", 0.3)], 8.0)


def test_tp_axis_gives_distinct_seeded_cells_and_pivot():
    """``tp=`` as a grid axis (Fig. 11 fold): ints or (tp, pp) pairs,
    each seed-disambiguated; the pivot grows a tp{T}pp{P} level."""
    r = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",), rates=(6.0,),
        tp=((4, 1), (2, 2)), n_instances=4,
        model="llama-30b", hw="L20", duration=10.0, warmup=2.0,
        base_seed=42)
    specs = r.cells()
    assert [(s["tp"], s["pp"]) for s in specs] == [(4, 1), (2, 2)]
    assert len({s["seed"] for s in specs}) == 2
    # a scalar tp keeps the legacy seed (empty extra)
    scalar = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",), rates=(6.0,),
        tp=4, pp=1, n_instances=4, model="llama-30b", hw="L20",
        duration=10.0, warmup=2.0, base_seed=42).cells()[0]
    assert scalar["seed"] == cell_seed(42, "ecoserve", "poisson", 6.0)
    fake = {"cells": [
        {"strategy": "ecoserve", "scenario": "poisson", "rate": 6.0,
         "n_instances": 4, "tp": t, "pp": p, "metrics": {"x": i}}
        for i, (t, p) in enumerate([(4, 1), (2, 2)])]}
    grid = ExperimentRunner.grid(fake)
    assert grid["ecoserve"]["poisson"]["tp4pp1"][6.0] == {"x": 0}
    assert grid["ecoserve"]["poisson"]["tp2pp2"][6.0] == {"x": 1}


def test_slo_override_is_single_class_only():
    with pytest.raises(ValueError, match="single-class"):
        ExperimentRunner(tenants=("alpaca", "longbench"),
                         slo_override=(5.0, 0.3))


def test_tenant_cells_carry_tenants_and_meta_roundtrip():
    r = tenant_runner()
    for spec in r.cells():
        assert spec["tenants"] == ["alpaca", "longbench"]
    golden = ExperimentRunner.load(TENANT_GOLDEN)
    assert golden["meta"]["tenants"] == ["alpaca", "longbench"]
    # legacy single-class grids must NOT grow a tenants key
    legacy_meta = ExperimentRunner.load(SCENARIO_GOLDEN)["meta"]
    assert "tenants" not in legacy_meta


# --------------------------------------------------------------------- #
# no-RNG-drift acceptance: single-tenant MixedScenario == legacy rows
# --------------------------------------------------------------------- #
COST = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)


@pytest.mark.parametrize("strategy", ["ecoserve", "vllm"])
def test_single_tenant_mixed_scenario_reproduces_legacy_golden(strategy):
    golden = ExperimentRunner.load(SCENARIO_GOLDEN)
    cell = next(c for c in golden["cells"]
                if c["strategy"] == strategy and c["scenario"] == "poisson")
    slo = SLOClassSet.single(DATASET_SLOS[cell["workload"]],
                             name=cell["workload"])
    scen = make_mixed_scenario("poisson", [cell["workload"]],
                               cell["rate"], seed=cell["seed"])
    m = run_once(functools.partial(make_system, strategy, COST,
                                   cell["n_instances"], slo),
                 scen, cell["rate"], slo,
                 duration=cell["duration"], warmup=cell["warmup"],
                 seed=cell["seed"])
    got = {k: m[k] for k in cell["metrics"]}
    assert got == cell["metrics"], (
        "single-tenant MixedScenario drifted from the legacy golden row")
