"""Property tests for the calibration layer.

Three invariants the conformance harness leans on:

* ``MeasuredExecutor``'s EWMA gain converges onto a constant-time
  executor's true step time — the measured model the scheduler sees
  tracks reality, not the analytic seed.
* ``FittedExecutor`` constants survive a JSON round trip exactly
  (``to_json`` -> ``json.dumps`` -> ``json.loads`` -> ``from_json``),
  so a report written by the bench reloads into the identical model.
* A ``CalibrationReport``'s error quantiles do not depend on the order
  ops were recorded in — permuting the sample stream changes nothing
  (unfitted exactly; fitted up to lstsq row-order float wiggle).
"""
import json

import numpy as np
import pytest

from repro.serving.calibration import (CalibrationRecorder,
                                       CalibrationReport)
from repro.serving.engine import MeasuredExecutor
from repro.simulator.cost_model import FittedExecutor

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


SEED_MODEL = FittedExecutor(prefill_base=1e-3, prefill_per_token=1e-4,
                            decode_base=5e-4, decode_per_seq=2e-4,
                            decode_per_ctx_token=1e-6)


# --------------------------------------------------------------------- #
# EWMA convergence
# --------------------------------------------------------------------- #
def check_ewma_converges(true_prefill: float, true_decode: float,
                         tokens: int, batch: int) -> None:
    """Feed a constant observed step time; after enough observations the
    executor's prediction for that shape must sit within 0.1% of it."""
    ex = MeasuredExecutor(seed_model=SEED_MODEL)
    for _ in range(60):
        ex.observe_prefill(tokens, true_prefill)
        ex.observe_decode(true_decode, batch=batch, ctx_sum=batch * 32)
    assert ex.prefill_time([tokens]) == pytest.approx(true_prefill,
                                                      rel=1e-3)
    assert ex.decode_time(batch, ctx_sum=batch * 32) == pytest.approx(
        true_decode, rel=1e-3)


def test_ewma_converges_seeded():
    rng = np.random.default_rng(3)
    for _ in range(8):
        check_ewma_converges(
            true_prefill=float(rng.uniform(1e-4, 5e-2)),
            true_decode=float(rng.uniform(1e-4, 5e-2)),
            tokens=int(rng.integers(1, 512)),
            batch=int(rng.integers(1, 16)))


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=40)
    @given(true_prefill=st.floats(1e-4, 5e-2),
           true_decode=st.floats(1e-4, 5e-2),
           tokens=st.integers(1, 512),
           batch=st.integers(1, 16))
    def test_ewma_converges_prop(true_prefill, true_decode, tokens, batch):
        check_ewma_converges(true_prefill, true_decode, tokens, batch)


# --------------------------------------------------------------------- #
# FittedExecutor JSON round trip
# --------------------------------------------------------------------- #
def check_fitted_roundtrip(kwargs) -> None:
    fitted = FittedExecutor(**kwargs)
    back = FittedExecutor.from_json(json.loads(json.dumps(
        fitted.to_json())))
    assert back == fitted        # dataclass equality: every field, exact


def test_fitted_roundtrip_seeded():
    rng = np.random.default_rng(5)
    for _ in range(16):
        check_fitted_roundtrip(dict(
            prefill_base=float(rng.uniform(0, 1e-2)),
            prefill_per_token=float(rng.uniform(1e-7, 1e-3)),
            decode_base=float(rng.uniform(0, 1e-2)),
            decode_per_seq=float(rng.uniform(0, 1e-3)),
            decode_per_ctx_token=float(rng.uniform(0, 1e-6)),
            kv_capacity=int(rng.integers(1, 10**8)),
            kv_bytes_per_token=int(rng.integers(0, 10**7)),
            ctx_clamp=int(rng.integers(0, 4096))))


def test_fitted_from_json_ignores_unknown_keys():
    blob = SEED_MODEL.to_json()
    blob["future_field"] = 123.0
    assert FittedExecutor.from_json(blob) == SEED_MODEL


if HAVE_HYPOTHESIS:
    finite = st.floats(0, 1e-2, allow_nan=False, allow_infinity=False)

    @needs_hypothesis
    @settings(max_examples=60)
    @given(prefill_base=finite, prefill_per_token=finite,
           decode_base=finite, decode_per_seq=finite,
           decode_per_ctx_token=finite,
           kv_capacity=st.integers(1, 10**9),
           kv_bytes_per_token=st.integers(0, 10**8),
           ctx_clamp=st.integers(0, 10**5))
    def test_fitted_roundtrip_prop(**kwargs):
        check_fitted_roundtrip(kwargs)


# --------------------------------------------------------------------- #
# report permutation invariance
# --------------------------------------------------------------------- #
def _recorder_from(samples) -> CalibrationRecorder:
    rec = CalibrationRecorder()
    for kind, a, b, dt in samples:
        if kind == "p":
            rec.record_prefill(a, dt)
        else:
            rec.record_decode(a, b, dt)
    return rec


def _sample_stream(rng, n=40):
    out = []
    for _ in range(n):
        if rng.random() < 0.5:
            toks = int(rng.integers(1, 256))
            out.append(("p", toks, 0,
                        1e-3 + 2e-4 * toks * float(rng.uniform(0.9, 1.1))))
        else:
            batch = int(rng.integers(1, 8))
            ctx = int(rng.integers(batch, batch * 200))
            out.append(("d", batch, ctx,
                        5e-4 + 1e-4 * batch
                        * float(rng.uniform(0.9, 1.1))))
    return out


def check_permutation_invariant(samples, perm_seed: int) -> None:
    rng = np.random.default_rng(perm_seed)
    shuffled = list(samples)
    rng.shuffle(shuffled)
    a = CalibrationReport.build(_recorder_from(samples), SEED_MODEL)
    b = CalibrationReport.build(_recorder_from(shuffled), SEED_MODEL)
    assert a.n_prefill == b.n_prefill and a.n_decode == b.n_decode
    # unfitted errors are per-op against a fixed model: the multiset is
    # identical, so every quantile matches exactly
    assert a.unfitted == b.unfitted
    # the lstsq fit sees the same rows in a different order; allow float
    # summation wiggle only
    for key, want in a.fitted.items():
        assert b.fitted[key] == pytest.approx(want, abs=1e-8)
    for key, want in a.constants.items():
        assert b.constants[key] == pytest.approx(
            want, rel=1e-6, abs=1e-12)


def test_report_permutation_invariant_seeded():
    rng = np.random.default_rng(9)
    for perm_seed in range(5):
        check_permutation_invariant(_sample_stream(rng), perm_seed)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=20)
    @given(stream_seed=st.integers(0, 2**31 - 1),
           perm_seed=st.integers(0, 2**31 - 1))
    def test_report_permutation_invariant_prop(stream_seed, perm_seed):
        rng = np.random.default_rng(stream_seed)
        check_permutation_invariant(_sample_stream(rng), perm_seed)
