"""Shared test configuration: deterministic property testing.

The golden grids demand bit-exact reproducibility, and flaky property
tests would undermine the same CI signal — so when hypothesis is
installed, every property test runs under a fixed-seed, non-randomized
profile (``derandomize=True`` makes example generation a pure function
of the test body; no ``-p no:randomly``-style plugin interference, no
per-run shrink lottery).  Without hypothesis the property-test modules
degrade to their seeded fallback drives, so the suite stays green on a
bare interpreter either way.
"""
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-ci",
        derandomize=True,          # examples derive from the test, not time
        deadline=None,             # simulator drives are slow but bounded
        print_blob=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro-ci")
except ImportError:                # seeded fallbacks cover the gap
    pass
