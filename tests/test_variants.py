"""Tests for the beyond-paper scheduler variants (EcoServe-CP) and the
serving API."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.instance import Instance
from repro.core.padg_system import EcoServeSystem
from repro.core.request import Request, RequestState
from repro.core.slo import DATASET_SLOS, SLO
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import run_once
from repro.simulator.workload import WORKLOADS


class Exec:
    def prefill_time(self, lens):
        return 1e-4 * sum(lens)

    def decode_time(self, b, c):
        return 0.02

    def hybrid_time(self, chunk_lens, prefix_lens, batch, ctxs):
        return 0.02 + 1e-4 * sum(chunk_lens)


def test_chunked_fallback_progresses_prefill_during_decode():
    """With thin slack, EcoServe-CP completes a prompt through hybrid
    iterations without a dedicated prefill slot."""
    inst = Instance(0, Exec(), kv_capacity_tokens=10**6,
                    slo_tpot=0.1, slo_ttft=10.0, chunked_fallback=256)
    # a long-running decode with ZERO slack (just started)
    running = Request(rid=1, arrival_time=0.0, prompt_len=10, output_len=400)
    inst.admit(running, 0.0)
    k, d, b = inst.next_slot(0.0)
    now = d
    inst.complete_slot(k, b, now)
    assert running.state == RequestState.DECODING

    newreq = Request(rid=2, arrival_time=now, prompt_len=5000, output_len=5)
    inst.admit(newreq, now)
    # the 0.5s prefill exceeds the running decode's ~0.1s slack -> full
    # prefill slot not allowed; slots must be hybrid until the prompt is
    # done chunk by chunk
    kinds = []
    for _ in range(25):
        k, d, batch = inst.next_slot(now)
        kinds.append(k)
        now += d
        inst.complete_slot(k, batch, now)
        if newreq.state == RequestState.DECODING:
            break
    assert "hybrid" in kinds
    assert "prefill" not in kinds[:4]
    assert newreq.state == RequestState.DECODING
    assert newreq.first_token_time is not None
    # the running decode kept generating every iteration meanwhile
    assert running.tokens_generated >= len(kinds)


def test_ecoserve_cp_system_runs_and_attains():
    cost = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
    slo = DATASET_SLOS["sharegpt"]
    m = run_once(
        lambda: EcoServeSystem(cost, 4, slo, plus_plus=True,
                               chunked_fallback=512),
        WORKLOADS["sharegpt"], rate=8.0, slo=slo, duration=45.0)
    assert m["completion"] > 0.95
    assert m["attainment"] > 0.9


def test_serving_api_generate_streaming():
    from repro.serving.api import EcoServeAPI
    from repro.serving.engine import EngineConfig

    cfg = get_smoke_config("llama3-8b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, num_heads=2,
                              num_kv_heads=1, head_dim=64, d_ff=256,
                              vocab_size=300)
    api = EcoServeAPI(cfg, n_instances=2,
                      econf=EngineConfig(max_batch=2, max_seq_len=64,
                                         eos_token=-1))
    streamed = []
    res = api.generate(["hello world", "padg serving"],
                       max_new_tokens=4,
                       stream=lambda rid, tok: streamed.append((rid, tok)))
    assert len(res) == 2
    for r in res:
        assert len(r.tokens) == 4
        assert r.ttft_s >= 0
        assert isinstance(r.text, str)
    assert len(streamed) == 8
