"""Protocol-conformance suite for the composable serving-policy API.

Three layers:

* every registered ``StrategySpec`` built via ``make_system`` satisfies
  the formal ``ServingSystem`` protocol, and its ``describe()`` bundle
  round-trips through a worker pickle (a real spawn pool, the same
  boundary the experiment runner crosses);
* the ``"base+modifier"`` grammar resolves compositions and rejects
  junk;
* the FIFO ``QueueDiscipline`` drain is property-tested (hypothesis +
  seeded fallbacks) to be bit-identical to the pre-redesign deque loop
  — the no-drift guarantee behind the golden grids.
"""
import functools
import multiprocessing
import pickle
import random
from collections import deque

import pytest

from repro.baselines import (REGISTRY, STRATEGIES, StrategySpec,
                             describe_strategy, make_system,
                             resolve_strategy)
from repro.configs import get_config
from repro.core.policies import AdmissionPolicy
from repro.core.request import Request
from repro.core.slo import DATASET_SLOS, SLOClassSet
from repro.core.system import PolicySystemBase, ServingSystem
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import run_once
from repro.simulator.runner import ExperimentRunner
from repro.simulator.scenarios import make_mixed_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

COST = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
MIX = SLOClassSet.make(
    {w: DATASET_SLOS[w] for w in ("alpaca", "longbench")})
DESCRIBE_KEYS = {"strategy", "base", "queue", "admission", "routing",
                 "provenance"}


# --------------------------------------------------------------------- #
# protocol conformance over every registered spec
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", STRATEGIES)
def test_registered_spec_builds_a_serving_system(name):
    system = make_system(name, COST, 2, MIX)
    assert isinstance(system, ServingSystem)
    assert isinstance(system, PolicySystemBase)
    assert system.instances and all(
        hasattr(i, "next_slot") for i in system.instances)
    for hook in ("submit", "on_slot_end", "scale_up", "scale_down",
                 "describe"):
        assert callable(getattr(system, hook)), (name, hook)


@pytest.mark.parametrize("name", STRATEGIES)
def test_describe_is_self_documenting_and_pickle_stable(name):
    system = make_system(name, COST, 2, MIX)
    d = system.describe()
    assert DESCRIBE_KEYS <= set(d)
    assert d["strategy"] == name
    assert pickle.loads(pickle.dumps(d)) == d
    # the spec-level describe (what runner rows carry) agrees on the
    # policy bundle the live system actually composed
    spec_d = describe_strategy(name)
    for key in ("strategy", "base", "queue", "admission", "routing",
                "provenance"):
        assert spec_d[key] == d[key], (name, key)


def test_describe_round_trips_through_a_worker_pickle():
    """The same spawn-pool boundary ``ExperimentRunner`` uses: describe
    bundles computed in worker processes must arrive identical to the
    parent-side ones."""
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(2) as pool:
        remote = pool.map(describe_strategy, STRATEGIES)
    assert remote == [describe_strategy(n) for n in STRATEGIES]


@pytest.mark.parametrize("name", ["ecoserve", "vllm", "mooncake"])
def test_scale_up_down_protocol(name):
    system = make_system(name, COST, 4, MIX)
    n0 = len(system.instances)
    inst = system.scale_up()
    assert inst in system.instances and len(system.instances) == n0 + 1
    gone = system.scale_down()
    assert gone is not None and gone not in system.instances
    assert len(system.instances) == n0


# --------------------------------------------------------------------- #
# the "base+modifier" grammar
# --------------------------------------------------------------------- #
def test_registered_composition_and_grammar_agree():
    reg = REGISTRY["vllm+priority"]
    assert reg.queue == "slo-priority"
    assert reg.admission == "backpressure"
    assert reg.base == "vllm"
    # ROADMAP composition sweep: the registered bundles must be exactly
    # what the grammar would compose (policy slots and frozen kwargs)
    reg = REGISTRY["distserve+priority"]
    assert (reg.base, reg.queue, reg.admission) == \
        ("distserve", "slo-priority", "backpressure")
    assert reg.ctor_kwargs == {"prefill_ratio": 0.25}
    reg = REGISTRY["ecoserve+spf"]
    assert (reg.base, reg.queue, reg.admission) == \
        ("ecoserve", "shortest-prompt", None)
    assert describe_strategy("ecoserve+spf")["admission"] == \
        "timeout-forced:4"


def test_grammar_composes_unregistered_variants():
    spec = resolve_strategy("mooncake+spf")
    assert spec.name == "mooncake+spf"
    assert spec.base == "mooncake"
    assert spec.queue == "shortest-prompt"
    assert spec.admission == "backpressure"     # immediate -> upgraded
    assert spec.ctor_kwargs == {"prefill_ratio": 0.25}
    # a composition is NOT the paper's baseline — provenance must say so
    assert "composed with +spf" in spec.provenance
    # double-plus bases parse via longest-prefix match
    spec = resolve_strategy("ecoserve+++priority")
    assert spec.base == "ecoserve" and spec.ctor_kwargs["plus_plus"]


def test_grammar_keeps_non_immediate_admission():
    """EcoServe's timeout-forced admission must survive a queue swap —
    only immediate admission is upgraded to backpressure (a discipline
    can never act on an always-empty queue)."""
    spec = resolve_strategy("ecoserve+priority")
    assert spec.queue == "slo-priority"
    assert spec.admission is None     # family default: timeout-forced
    assert describe_strategy("ecoserve+priority")["admission"] == \
        "timeout-forced:4"


def test_registered_slack_and_rr_compositions_round_trip():
    """ISSUE satellite: the ``rr`` (round-robin routing) and ``slack``
    (KV-guarded NoDG admission) modifiers are registered as
    ``vllm+slack`` / ``ecoserve+rr`` and their ``describe()`` bundles
    round-trip — spec-level describe == live-system describe, and the
    registered spec agrees with what the grammar would compose."""
    for name, want in (("vllm+slack", {"admission": "kv-guard:0.9",
                                       "queue": "fifo",
                                       "routing": "least-kv"}),
                       ("ecoserve+rr", {"admission": "timeout-forced:4",
                                        "queue": "fifo",
                                        "routing": "round-robin"})):
        assert name in REGISTRY
        spec_d = describe_strategy(name)
        live_d = make_system(name, COST, 2, MIX).describe()
        for key in ("strategy", "base", "queue", "admission", "routing"):
            assert spec_d[key] == live_d[key], (name, key)
        for key, val in want.items():
            assert spec_d[key] == val, (name, key, spec_d[key])
    # the grammar composes the same policy bundles for other bases
    spec = resolve_strategy("sarathi+slack")
    assert spec.admission == "kv-guard"
    spec = resolve_strategy("mooncake+rr")
    assert spec.routing == "round-robin"


def test_slack_and_rr_compositions_serve_to_completion():
    from repro.simulator.scenarios import make_scenario
    slo = DATASET_SLOS["sharegpt"]
    for name in ("vllm+slack", "ecoserve+rr"):
        m = run_once(functools.partial(make_system, name, COST, 4, slo),
                     make_scenario("poisson", "sharegpt", 4.0, seed=5),
                     4.0, slo, duration=15.0, warmup=2.0, seed=5)
        assert m["completion"] > 0.9, (name, m)


def test_unknown_strategy_and_modifier_raise():
    with pytest.raises(KeyError, match="unknown strategy"):
        resolve_strategy("no-such-system")
    with pytest.raises(KeyError, match="unknown strategy"):
        resolve_strategy("vllm+turbo")
    with pytest.raises(KeyError, match="unknown system family"):
        StrategySpec(name="x", base="no-such-family")


def test_spec_build_overrides_win_over_frozen_kwargs():
    system = make_system("distserve", COST, 4, MIX, prefill_ratio=0.5)
    assert len(system.prefill_insts) == 2       # 0.5, not the spec's 0.25


# --------------------------------------------------------------------- #
# FIFO drain == pre-redesign deque loop (property)
# --------------------------------------------------------------------- #
class _ScriptedAdmission(AdmissionPolicy):
    """Replays a scripted admit/deny sequence in try order."""

    name = "scripted"

    def __init__(self, decisions):
        self.decisions = list(decisions)
        self.dummy = object()

    def try_admit(self, system, req, now):
        ok = self.decisions.pop(0) if self.decisions else False
        return self.dummy if ok else None


class _EngineStub:
    def activate(self, inst):
        pass


def _legacy_drain(reqs, decisions, max_tries=64):
    """The pre-policy-API EcoServeSystem._drain_queue, verbatim."""
    queue = deque(reqs)
    decisions = list(decisions)
    admitted = []
    tries = 0
    fails = 0
    still = deque()
    while queue and tries < max_tries and fails < 4:
        req = queue.popleft()
        tries += 1
        ok = decisions.pop(0) if decisions else False
        if ok:
            admitted.append(req.rid)
            fails = 0
        else:
            still.append(req)
            fails += 1
    still.extend(queue)
    return admitted, [r.rid for r in still]


def _policy_drain(reqs, decisions, max_tries=64):
    system = PolicySystemBase(None, 0, None,
                              admission=_ScriptedAdmission(decisions))
    admitted_order = []
    orig = system.admission.try_admit

    def spy(sys_, req, now):
        inst = orig(sys_, req, now)
        if inst is not None:
            admitted_order.append(req.rid)
        return inst

    system.admission.try_admit = spy
    system.queue.extend(reqs)
    system._drain_queue(0.0, _EngineStub(), max_tries=max_tries)
    return admitted_order, [r.rid for r in system.queue]


def check_fifo_drain_matches_legacy(n_reqs, decisions, max_tries=64):
    reqs = [Request(rid=i, arrival_time=float(i), prompt_len=8,
                    output_len=4) for i in range(n_reqs)]
    want = _legacy_drain(reqs, decisions, max_tries)
    got = _policy_drain(reqs, decisions, max_tries)
    assert got == want, (n_reqs, decisions[:12], max_tries)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=200)
    @given(n_reqs=st.integers(0, 120),
           decisions=st.lists(st.booleans(), max_size=120),
           max_tries=st.sampled_from([1, 4, 64]))
    def test_fifo_drain_bit_identical_property(n_reqs, decisions,
                                               max_tries):
        check_fifo_drain_matches_legacy(n_reqs, decisions, max_tries)


@pytest.mark.parametrize("seed", range(8))
def test_fifo_drain_bit_identical_seeded(seed):
    rng = random.Random(seed)
    for _ in range(40):
        n = rng.randrange(0, 120)
        decisions = [rng.random() < rng.choice((0.1, 0.5, 0.9))
                     for _ in range(rng.randrange(0, 120))]
        check_fifo_drain_matches_legacy(
            n, decisions, rng.choice((1, 4, 64)))


def test_fifo_drain_gives_up_after_four_consecutive_failures():
    admitted, left = _policy_drain(
        [Request(rid=i, arrival_time=0.0, prompt_len=1, output_len=1)
         for i in range(10)],
        [True, False, False, False, False, True])
    assert admitted == [0]
    assert left == list(range(1, 10))   # untouched tail keeps order


# --------------------------------------------------------------------- #
# acceptance: composed strategies end-to-end through the runner
# --------------------------------------------------------------------- #
def test_runner_end_to_end_priority_beats_blind_vllm_on_alpaca():
    """ISSUE acceptance: ``ExperimentRunner(strategies=("vllm",
    "vllm+priority"), tenants=...)`` runs end-to-end and the priority
    variant achieves strictly higher alpaca-class attainment."""
    runner = ExperimentRunner(
        strategies=("vllm", "vllm+priority"), scenarios=("poisson",),
        rates=(6.0,), tenants=("alpaca", "longbench"),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=4,
        duration=20.0, warmup=3.0, base_seed=42, n_workers=1)
    grid = ExperimentRunner.grid(runner.run())
    blind = grid["vllm"]["poisson"][6.0]["attainment_by_class"]["alpaca"]
    aware = grid["vllm+priority"]["poisson"][6.0][
        "attainment_by_class"]["alpaca"]
    assert aware > blind, (aware, blind)


def test_runner_rows_carry_describe_bundle():
    runner = ExperimentRunner(
        strategies=("sarathi+priority",), scenarios=("poisson",),
        rates=(2.0,), model="llama-30b", hw="L20", tp=4, pp=1,
        n_instances=2, duration=5.0, warmup=1.0, base_seed=7, n_workers=1)
    cell = runner.run()["cells"][0]
    assert cell["system"]["strategy"] == "sarathi+priority"
    assert cell["system"]["queue"] == "slo-priority"
    assert cell["system"]["base"] == "sarathi"


def test_single_class_priority_composition_is_well_behaved():
    """Under one SLO class the EDF queue degrades to FIFO order; the
    composed system must still serve to completion."""
    slo = DATASET_SLOS["sharegpt"]
    m = run_once(functools.partial(make_system, "vllm+priority", COST, 4,
                                   slo),
                 make_mixed_scenario("poisson", ["sharegpt"], 4.0, seed=3),
                 4.0, slo, duration=20.0, warmup=3.0, seed=3)
    assert m["completion"] > 0.9
