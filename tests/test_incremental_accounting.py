"""The instance's O(1) running aggregates must equal the from-scratch
sums after ANY sequence of admit / slot-complete / chunk / hand-off /
external-sync operations — this is the safety net under the simulator
hot-path optimization (kv_tokens_used, status, decode fast path all read
the aggregates instead of re-summing)."""
import random

import pytest

from repro.core.instance import Instance
from repro.core.request import Request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # degrade to the seeded fallback drive below
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


class Exec:
    """Cheap executor WITH the ctx_sum fast path (mirrors the cost model's
    interface so the clamped-sum bookkeeping is exercised)."""

    def __init__(self, ctx_clamp=0):
        self.ctx_clamp = ctx_clamp

    def prefill_time(self, lens):
        return 1e-4 * sum(lens)

    def decode_time(self, b, ctx_lens=None, *, ctx_sum=None):
        if ctx_sum is None:
            sw = self.ctx_clamp
            ctx_sum = sum(min(c, sw) if sw else c for c in ctx_lens)
        return 0.01 + 1e-7 * ctx_sum

    def hybrid_time(self, chunks, prefixes, b, decode_ctxs=None,
                    *, decode_ctx_sum=None):
        if decode_ctx_sum is None:
            sw = self.ctx_clamp
            decode_ctx_sum = sum(
                min(c, sw) if sw else c for c in decode_ctxs)
        return 0.01 + 1e-4 * sum(chunks) + 1e-7 * decode_ctx_sum


def _assert_consistent(inst):
    for name, (fast, slow) in inst.audit_aggregates().items():
        assert fast == slow, (name, fast, slow)


def _drive_instance(reqs, chunked, clamp, slo_tpot):
    """Drive the full slot loop (prefill / decode / hybrid chunks) with
    the given requests; after every step the incremental aggregates must
    equal the recomputed sums."""
    inst = Instance(0, Exec(ctx_clamp=clamp), kv_capacity_tokens=10**9,
                    slo_tpot=slo_tpot, slo_ttft=1.0,
                    chunked_fallback=chunked)
    queue = [Request(rid=i, arrival_time=0.05 * i, prompt_len=p,
                     output_len=o) for i, (p, o) in enumerate(reqs)]
    now, idx = 0.0, 0
    for _ in range(20_000):
        while idx < len(queue) and queue[idx].arrival_time <= now:
            inst.admit(queue[idx], now)
            _assert_consistent(inst)
            idx += 1
        kind, dur, batch = inst.next_slot(now)
        if kind == "idle":
            if idx >= len(queue):
                break
            now = queue[idx].arrival_time
            continue
        now += dur
        inst.complete_slot(kind, batch, now)
        _assert_consistent(inst)
    assert len(inst._finished) == len(queue)
    assert inst.kv_tokens_used() == 0


def _handoff_and_sync(reqs, clamp):
    """The FuDG hand-off path (remove_pending + add_decoding on another
    instance) and the real-exec sync_tokens path keep both instances'
    aggregates exact."""
    src = Instance(0, Exec(ctx_clamp=clamp), kv_capacity_tokens=10**9)
    dst = Instance(1, Exec(ctx_clamp=clamp), kv_capacity_tokens=10**9)
    rs = [Request(rid=i, arrival_time=0.0, prompt_len=p, output_len=o + 1)
          for i, (p, o) in enumerate(reqs)]
    for r in rs:
        src.admit(r, 0.0)
        _assert_consistent(src)
    src.handoff_prefilled(list(src.pending), 0.5)
    _assert_consistent(src)
    assert src.kv_tokens_used() == 0
    for r in rs:
        dst.add_decoding(r)
        _assert_consistent(dst)
    # external engine advances token counts out-of-band (padg_server path)
    for step, r in enumerate(rs):
        dst.sync_tokens(r, r.tokens_generated + 1 + step % 3)
        _assert_consistent(dst)
    for r in list(dst.decoding):
        dst.remove_decoding(r)
        _assert_consistent(dst)
    assert dst.kv_tokens_used() == 0


if HAVE_HYPOTHESIS:
    REQ = st.tuples(st.integers(1, 600),      # prompt_len
                    st.integers(1, 12))       # output_len

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(reqs=st.lists(REQ, min_size=1, max_size=25),
           chunked=st.sampled_from([0, 64]),
           clamp=st.sampled_from([0, 128]),
           slo_tpot=st.sampled_from([None, 0.1]))
    def test_aggregates_match_recomputation_under_random_drive(
            reqs, chunked, clamp, slo_tpot):
        _drive_instance(reqs, chunked, clamp, slo_tpot)

    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(reqs=st.lists(REQ, min_size=1, max_size=12),
           clamp=st.sampled_from([0, 100]))
    def test_aggregates_survive_handoff_and_external_sync(reqs, clamp):
        _handoff_and_sync(reqs, clamp)


@pytest.mark.parametrize("chunked,clamp,slo_tpot", [
    (0, 0, None), (0, 0, 0.1), (64, 0, 0.1),
    (64, 128, 0.1), (0, 128, None),
])
def test_aggregates_match_recomputation_seeded(chunked, clamp, slo_tpot):
    """Seeded fallback drive (always runs, even without hypothesis)."""
    rng = random.Random(1234 + chunked + clamp)
    for _ in range(8):
        reqs = [(rng.randint(1, 600), rng.randint(1, 12))
                for _ in range(rng.randint(1, 25))]
        _drive_instance(reqs, chunked, clamp, slo_tpot)


@pytest.mark.parametrize("clamp", [0, 100])
def test_handoff_and_sync_seeded(clamp):
    rng = random.Random(99 + clamp)
    for _ in range(8):
        reqs = [(rng.randint(1, 600), rng.randint(1, 12))
                for _ in range(rng.randint(1, 12))]
        _handoff_and_sync(reqs, clamp)


def test_kv_tokens_used_matches_legacy_definition():
    """kv_tokens_used == sum(kv_tokens over decoding) + sum(prompt_len
    over pending), exactly as the pre-optimization code computed it."""
    inst = Instance(0, Exec(), kv_capacity_tokens=10**9)
    a = Request(rid=1, arrival_time=0.0, prompt_len=100, output_len=5)
    b = Request(rid=2, arrival_time=0.0, prompt_len=40, output_len=5)
    inst.admit(a, 0.0)
    inst.admit(b, 0.0)
    assert inst.kv_tokens_used() == 140
    kind, dur, batch = inst.next_slot(0.0)
    inst.complete_slot(kind, batch, dur)
    want = sum(r.kv_tokens() for r in inst.decoding) + \
        sum(r.prompt_len for r in inst.pending)
    assert inst.kv_tokens_used() == want == 142   # 100+1 and 40+1


def test_status_cache_invalidated_by_mutation_at_same_timestamp():
    """The old (now, slo, len, len) cache key went stale when a mutation
    preserved list lengths; the version-keyed cache must not."""
    inst = Instance(0, Exec(), kv_capacity_tokens=10**9)
    r = Request(rid=1, arrival_time=0.0, prompt_len=100, output_len=50)
    inst.admit(r, 0.0)
    kind, dur, batch = inst.next_slot(0.0)
    inst.complete_slot(kind, batch, dur)       # r now decoding
    st1 = inst.status(1.0, 0.1)
    # a decode iteration changes tokens_generated but not len(decoding)
    kind, dur, batch = inst.next_slot(1.0)
    inst.complete_slot(kind, batch, 1.0 + dur)
    st2 = inst.status(1.0, 0.1)
    assert st2.kv_tokens_used == st1.kv_tokens_used + 1
    assert st2.saved_tpots != st1.saved_tpots


def test_ctx_sum_fast_path_matches_list_path():
    """decode_time / status must be identical whether the executor takes
    the incremental ctx sum or the per-request list (sliding-window clamp
    included)."""
    from repro.configs import get_config
    from repro.simulator.cost_model import GPU_L20, InstanceCostModel
    import dataclasses as dc
    base = get_config("llama-30b")
    for cfg in (base, dc.replace(base, sliding_window=256,
                                 block_pattern=("local",))):
        cm = InstanceCostModel(cfg=cfg, hw=GPU_L20, tp=4)
        ctxs = [100, 300, 700, 5, 256, 257]
        sw = cm.ctx_clamp
        eff = sum(min(c, sw) if sw else c for c in ctxs)
        assert cm.decode_time(len(ctxs), ctxs) == \
            cm.decode_time(len(ctxs), ctx_sum=eff)
        assert cm.hybrid_time([64], [32], len(ctxs), ctxs) == \
            cm.hybrid_time([64], [32], len(ctxs), decode_ctx_sum=eff)
