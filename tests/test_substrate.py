"""Substrate tests: data pipeline, optimizer, checkpointing, workload
generator, training loop convergence on a tiny model."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import ByteTokenizer, TokenDataset, synthetic_corpus
from repro.simulator.workload import WORKLOADS, WorkloadGen
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamW
from repro.training.train_loop import train


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "hello EcoServe 123!"
    ids = tok.encode(s)
    assert ids[0] == tok.bos and ids[-1] == tok.eos
    assert tok.decode(ids) == s


def test_dataset_batches_are_next_token_shifted():
    ds = TokenDataset.from_texts(["abcdefgh" * 20])
    b = next(ds.batches(4, 16, seed=1))
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 100


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": (jnp.ones(4), {"c": jnp.zeros((1, 2))})}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=17)
    restored, step = load_checkpoint(path, tree)
    assert step == 17
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jnp.zeros((3, 2))})


def test_workload_statistics_match_table4():
    for name, prof in WORKLOADS.items():
        gen = WorkloadGen(prof, rate=50.0, seed=0)
        reqs = gen.generate(100.0)
        ins = np.array([r.prompt_len for r in reqs])
        outs = np.array([r.output_len for r in reqs])
        assert abs(np.median(ins) - prof.input_dist.median) \
            < 0.35 * prof.input_dist.median
        assert abs(np.median(outs) - prof.output_dist.median) \
            < 0.35 * max(20, prof.output_dist.median)
        assert ins.max() <= 4096
        # Poisson arrivals: rate within 15%
        assert abs(len(reqs) / 100.0 - 50.0) < 7.5


def test_training_loss_decreases():
    cfg = get_smoke_config("llama3-8b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=128, num_heads=2,
                              num_kv_heads=1, head_dim=64, d_ff=256,
                              vocab_size=300)
    ds = TokenDataset.from_texts(synthetic_corpus(64),
                                 ByteTokenizer(cfg.vocab_size))
    _, losses = train(cfg, ds.batches(4, 64), steps=30,
                      optimizer=AdamW(lr=3e-3), log_fn=lambda s: None)
    assert losses[-1] < losses[0] - 0.3
