"""Golden pin for the TTFT attribution contract
(``tests/golden/trace_attribution.json``).

The traced regression-grid ecoserve/bursty cell must reproduce the
pinned attribution payload bit-exactly — per-request components that sum
exactly to the measured TTFT, event counts, interference score — at
every runner worker count (1 = in-process, 2/3 = spawned pools), via the
same ``golden_payload`` builder ``benchmarks/bench_trace.py
--write-golden`` used to pin it.
"""
import json

import pytest

from benchmarks.bench_trace import GOLDEN_PATH, golden_payload, smoke_spec
from repro.obs.export import read_jsonl
from repro.simulator.runner import ExperimentRunner, regression_runner


def _golden():
    assert GOLDEN_PATH.exists(), (
        "missing golden; run PYTHONPATH=src python -m "
        "benchmarks.bench_trace --write-golden")
    return json.loads(GOLDEN_PATH.read_text())


def test_golden_pins_the_exactness_invariant():
    golden = _golden()
    assert golden["attribution"]["exact"] is True
    assert golden["attribution"]["n"] > 0
    assert golden["attribution"]["unattributed"] == 0
    tot = golden["attribution"]["totals"]
    # the per-row invariant survives the golden's 9-dp rounding at the
    # aggregate level too (rounded totals agree within the last digit)
    assert tot["ttft"] == pytest.approx(
        tot["queue_wait"] + tot["prefill_wait"] + tot["prefill_service"]
        + tot["transfer"], abs=1e-6)
    assert golden["cell"]["strategy"] == "ecoserve"
    assert golden["cell"]["scenario"] == "bursty"


@pytest.mark.parametrize("n_workers", [1, 2, 3])
def test_traced_cell_matches_golden_at_every_worker_count(n_workers,
                                                          tmp_path):
    base = regression_runner(n_workers=n_workers)
    tdir = tmp_path / "traces"
    # two cells so the multi-worker modes actually exercise the pool;
    # every other grid parameter (and hence the CRC cell seed) is the
    # regression grid's own
    runner = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson", "bursty"),
        rates=base.rates, model=base.model, hw=base.hw, tp=base.tp,
        pp=base.pp, n_instances=base.n_instances, workload=base.workload,
        duration=base.duration, warmup=base.warmup,
        base_seed=base.base_seed, n_workers=n_workers, trace=str(tdir))
    results = runner.run()
    assert not results.get("errors"), results.get("errors")

    cell = next(c for c in results["cells"] if c["scenario"] == "bursty")
    assert cell["seed"] == smoke_spec()["seed"]
    events, _meta = read_jsonl(cell["trace"])
    payload = golden_payload(events, cell)
    golden = _golden()
    assert json.dumps(payload, sort_keys=True) \
        == json.dumps(golden, sort_keys=True), (
        f"trace attribution drifted from the golden at "
        f"n_workers={n_workers}")
