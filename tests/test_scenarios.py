"""Deterministic regression layer for the scenario subsystem.

The golden grid pins every strategy x scenario cell's summary metrics
bit-exactly under fixed per-cell seeds; any behavioural change to the
simulator, schedulers, or workload generation shows up here first.
Regenerate the fixture (after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_scenarios --write-golden
"""
import functools
import json
import pathlib

import pytest

from repro.baselines import STRATEGIES, make_system
from repro.configs import get_config
from repro.core.slo import DATASET_SLOS
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.metrics import goodput, run_once
from repro.simulator.runner import (ExperimentRunner, cell_seed,
                                    regression_runner)
from repro.simulator.scenarios import (SCENARIO_KINDS, TraceReplay,
                                       make_scenario, write_trace)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "scenario_grid.json"


# --------------------------------------------------------------------- #
# golden-metrics regression
# --------------------------------------------------------------------- #
def test_golden_grid_reproduced_bit_exactly():
    golden = ExperimentRunner.load(GOLDEN)
    fresh = regression_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "regression grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "scenario grid no longer reproduces the golden metrics; if the "
        "change is intentional, regenerate with `python -m "
        "benchmarks.bench_scenarios --write-golden` and review the diff")


def test_golden_grid_covers_all_strategies_and_scenarios():
    golden = ExperimentRunner.load(GOLDEN)
    strategies = {c["strategy"] for c in golden["cells"]}
    scenarios = {c["scenario"] for c in golden["cells"]}
    assert strategies == {"ecoserve", "vllm", "sarathi", "distserve",
                          "mooncake"}
    assert scenarios == {"poisson", "bursty", "diurnal", "replay"}


def test_cell_seed_is_stable_and_distinct():
    # pinned values: cell_seed must never depend on PYTHONHASHSEED
    assert cell_seed(42, "ecoserve", "poisson", 6.0) == \
        cell_seed(42, "ecoserve", "poisson", 6.0)
    seeds = {cell_seed(42, s, sc, 6.0)
             for s in STRATEGIES for sc in SCENARIO_KINDS}
    assert len(seeds) == len(STRATEGIES) * len(SCENARIO_KINDS)


# --------------------------------------------------------------------- #
# trace round-trip
# --------------------------------------------------------------------- #
def test_trace_round_trip_is_identical(tmp_path):
    sc = make_scenario("bursty", "sharegpt", 6.0, seed=3)
    reqs = sc.generate(60.0)
    assert reqs, "bursty scenario generated no requests"
    path = tmp_path / "trace.jsonl"
    write_trace(reqs, path)
    replay = TraceReplay.from_jsonl(path)
    reqs2 = replay.generate(60.0)
    assert [(r.rid, r.arrival_time, r.prompt_len, r.output_len)
            for r in reqs] == \
           [(r.rid, r.arrival_time, r.prompt_len, r.output_len)
            for r in reqs2]


def test_trace_replay_respects_duration(tmp_path):
    sc = make_scenario("poisson", "alpaca", 8.0, seed=1)
    path = tmp_path / "trace.jsonl"
    write_trace(sc.generate(40.0), path)
    replay = TraceReplay.from_jsonl(path)
    short = replay.generate(10.0)
    assert short and all(r.arrival_time < 10.0 for r in short)
    assert len(short) < len(replay.generate(40.0))


# --------------------------------------------------------------------- #
# scenario generators
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("kind", SCENARIO_KINDS)
def test_scenario_seeded_determinism(kind):
    a = make_scenario(kind, "sharegpt", 8.0, seed=5).generate(30.0)
    b = make_scenario(kind, "sharegpt", 8.0, seed=5).generate(30.0)
    assert [(r.arrival_time, r.prompt_len, r.output_len) for r in a] == \
           [(r.arrival_time, r.prompt_len, r.output_len) for r in b]
    c = make_scenario(kind, "sharegpt", 8.0, seed=6).generate(30.0)
    assert [(r.arrival_time, r.prompt_len, r.output_len) for r in a] != \
           [(r.arrival_time, r.prompt_len, r.output_len) for r in c]


@pytest.mark.parametrize("kind", SCENARIO_KINDS)
def test_scenario_mean_rate_matches_nominal(kind):
    """Every shape is rate-parameterized by its time-averaged rate."""
    rate, duration = 10.0, 2400.0   # long horizon: bursty has high variance
    n = len(make_scenario(kind, "alpaca", rate, seed=0).generate(duration))
    assert n == pytest.approx(rate * duration, rel=0.10), kind


def test_bursty_is_burstier_than_poisson():
    """Index of dispersion over 5s bins: MMPP >> Poisson (~1)."""
    import numpy as np

    def dispersion(reqs, duration, bin_s=5.0):
        counts, _ = np.histogram(
            [r.arrival_time for r in reqs],
            bins=int(duration / bin_s), range=(0, duration))
        return counts.var() / counts.mean()

    duration = 600.0
    pois = make_scenario("poisson", "alpaca", 10.0, seed=2)
    burst = make_scenario("bursty", "alpaca", 10.0, seed=2)
    d_p = dispersion(pois.generate(duration), duration)
    d_b = dispersion(burst.generate(duration), duration)
    assert d_b > 2.0 * d_p, (d_p, d_b)


# --------------------------------------------------------------------- #
# metrics integration: run_once / goodput accept any workload form
# --------------------------------------------------------------------- #
COST = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
SLO = DATASET_SLOS["sharegpt"]


def test_run_once_accepts_scenario_object():
    sc = make_scenario("diurnal", "sharegpt", 2.0, seed=0)
    m = run_once(functools.partial(make_system, "ecoserve", COST, 4, SLO),
                 sc, 2.0, SLO, duration=20.0, warmup=3.0)
    assert m["completion"] > 0.9 and m["finished"] > 5


def test_goodput_rejects_fixed_scenario():
    """A fixed scenario ignores the probed rate — goodput must refuse it
    rather than bisect a dead knob and report an arbitrary rate."""
    sc = make_scenario("poisson", "sharegpt", 6.0)
    with pytest.raises(TypeError, match="factory"):
        goodput(functools.partial(make_system, "vllm", COST, 4, SLO),
                sc, SLO, target_attainment=0.5, duration=10.0)


def test_goodput_accepts_scenario_factory():
    factory = functools.partial(make_scenario, "poisson", "sharegpt")
    g = goodput(functools.partial(make_system, "vllm", COST, 4, SLO),
                factory, SLO, target_attainment=0.5,
                lo=0.5, hi=4.0, tol=0.5, duration=15.0)
    assert g["goodput"] > 0.0
