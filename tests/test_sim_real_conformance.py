"""Sim-to-real conformance: the simulator and the PaDG server must make
IDENTICAL scheduling decisions for the same trace.

Both stacks literally share the scheduling code (``EcoServeSystem`` +
``SimulationEngine``; the server's ``ReplayEngine`` subclasses the
simulator's event loop), so with a deterministic executor model and the
virtual clock, a served run and a simulated run of one request list must
produce the same totally ordered decision sequence — every admission
outcome (Algorithm 2), every routing choice (Algorithm 1), every slot
start (kind, duration, batch) — and the same per-request finish times.

Also here: the tolerance-banded calibration golden
(``tests/golden/calibration_report.json``; regenerate with
``python -m benchmarks.bench_calibration --write-golden``) and the
runner's calibrated-executor axis.
"""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core.padg_system import EcoServeSystem
from repro.core.request import Request
from repro.core.slo import SLO
from repro.serving.padg_server import PaDGServer
from repro.serving.replay import (SlotConfig, VirtualClock,
                                  requests_from_trace)
from repro.simulator.cost_model import FittedExecutor
from repro.simulator.engine import SimulationEngine
from repro.traces import load_fixture, normalize_rate

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.bench_calibration import GOLDEN_PATH, build_report  # noqa: E402

B, S = 4, 160
SLO_SET = SLO(ttft=0.5, tpot=0.05)


def model() -> FittedExecutor:
    return FittedExecutor(prefill_base=1e-3, prefill_per_token=1e-4,
                          decode_base=5e-4, decode_per_seq=2e-4,
                          decode_per_ctx_token=1e-6, kv_capacity=B * S)


def poisson_requests(n=30, seed=7, mean_gap=0.02):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        reqs.append(Request(rid=i, arrival_time=t,
                            prompt_len=int(rng.integers(3, 60)),
                            output_len=int(rng.integers(1, 12))))
        t += float(rng.exponential(mean_gap))
    return reqs


def trace_requests():
    records = []
    for name in ("azure", "burstgpt"):
        records.extend(normalize_rate(load_fixture(name), 12.0)[:15])
    return requests_from_trace(records, max_prompt=S - 40, max_output=10,
                               seed=0)


def run_sim(reqs):
    system = EcoServeSystem(model(), 2, SLO_SET,
                            instance_kwargs={"max_decode_batch": B,
                                             "max_prefill_batch": B})
    engine = SimulationEngine(system)
    log = []
    engine.decision_log = log
    system.decision_log = log
    finished = engine.run(reqs, horizon=1e9)
    return log, finished, len(system.queue)


def run_server(reqs):
    server = PaDGServer(None, n_instances=2, slo=SLO_SET,
                        econf=SlotConfig(max_batch=B, max_seq_len=S),
                        backend="fake", executor=model())
    try:
        stats = server.serve(reqs, clock=VirtualClock(),
                             record_decisions=True)
    finally:
        server.shutdown()
    return stats.decisions, stats.finished


def finish_key(reqs):
    return sorted((r.rid, round(r.finish_time, 12), r.tokens_generated)
                  for r in reqs)


# --------------------------------------------------------------------- #
# decision-sequence conformance
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("make_reqs", [poisson_requests, trace_requests],
                         ids=["poisson", "tagged-traces"])
def test_identical_scheduling_decisions(make_reqs):
    log_sim, fin_sim, queue_left = run_sim(make_reqs())
    log_srv, fin_srv = run_server(make_reqs())
    # precondition for apples-to-apples: the simulator run drained its
    # queue through ordinary slot boundaries (the server additionally
    # pumps end-of-trace stragglers, which a queue-stuck sim can't mirror)
    assert queue_left == 0
    assert len(fin_sim) == len(make_reqs())
    assert log_sim == log_srv
    assert finish_key(fin_sim) == finish_key(fin_srv)


def test_conformance_exercises_queueing():
    """The equality above must not be vacuous: under a burst on a tight
    config the shared admission path queues and later drains requests,
    and those decisions must also match event-for-event."""
    b, s = 2, 80
    tight = SLO(ttft=0.02, tpot=0.01)
    tight_model = FittedExecutor(prefill_base=1e-3, prefill_per_token=1e-4,
                                 decode_base=5e-4, decode_per_seq=2e-4,
                                 decode_per_ctx_token=1e-6,
                                 kv_capacity=b * s)

    def burst():
        rng = np.random.default_rng(11)
        reqs, t = [], 0.0
        for i in range(60):
            # prompt + output stays under the engine's per-slot seq cap
            # (max_seq_len - 2): the cap is physical engine behaviour the
            # pure simulator deliberately does not model
            reqs.append(Request(rid=i, arrival_time=t,
                                prompt_len=int(rng.integers(3, 60)),
                                output_len=int(rng.integers(1, 15))))
            t += float(rng.exponential(0.002))
        return reqs

    system = EcoServeSystem(tight_model, 2, tight,
                            instance_kwargs={"max_decode_batch": b,
                                             "max_prefill_batch": b})
    engine = SimulationEngine(system)
    log_sim = []
    engine.decision_log = log_sim
    system.decision_log = log_sim
    fin_sim = engine.run(burst(), horizon=1e9)
    kinds = {e[0] for e in log_sim}
    assert {"admit", "slot", "queue", "drain"} <= kinds, (
        f"burst run only produced {kinds}; raise the rate so the "
        "conformance check covers the queue/drain path")
    assert len(system.queue) == 0 and len(fin_sim) == 60

    server = PaDGServer(None, n_instances=2, slo=tight,
                        econf=SlotConfig(max_batch=b, max_seq_len=s),
                        backend="fake", executor=tight_model)
    try:
        stats = server.serve(burst(), clock=VirtualClock(),
                             record_decisions=True)
    finally:
        server.shutdown()
    assert log_sim == stats.decisions
    assert finish_key(fin_sim) == finish_key(stats.finished)


def test_decision_log_off_by_default():
    system = EcoServeSystem(model(), 2, SLO_SET)
    engine = SimulationEngine(system)
    engine.run(poisson_requests(n=5), horizon=1e9)
    assert system.decision_log is None and engine.decision_log is None


# --------------------------------------------------------------------- #
# calibration golden (tolerance-banded: the fake replay is deterministic
# but the lstsq fit may wiggle in the last ulps across BLAS builds)
# --------------------------------------------------------------------- #
REL_TOL = 0.02        # fitted constants: 2% band
ERR_TOL = 0.02        # error quantiles: absolute band


def test_calibration_golden_within_bands():
    golden = json.loads(GOLDEN_PATH.read_text())
    fresh = build_report("fake").to_dict()
    assert fresh["n_prefill"] == golden["n_prefill"]
    assert fresh["n_decode"] == golden["n_decode"]
    assert fresh["meta"] == golden["meta"]
    for side in ("unfitted", "fitted"):
        for key, want in golden[side].items():
            assert abs(fresh[side][key] - want) <= ERR_TOL, (
                f"{side}.{key} moved: {fresh[side][key]} vs {want}; if "
                "intentional, regenerate with `python -m benchmarks."
                "bench_calibration --write-golden`")
    for key, want in golden["constants"].items():
        got = fresh["constants"][key]
        band = REL_TOL * max(abs(want), 1e-12)
        assert abs(got - want) <= band, (
            f"fitted constant {key} moved: {got} vs {want}")


def test_calibration_fit_beats_roofline():
    """The acceptance claim: fitted constants reduce median per-op
    prediction error vs the unfitted analytic model on the checked-in
    trace excerpts."""
    golden = json.loads(GOLDEN_PATH.read_text())
    assert (golden["fitted"]["overall_median"]
            < golden["unfitted"]["overall_median"])
    assert golden["n_prefill"] > 0 and golden["n_decode"] > 0


# --------------------------------------------------------------------- #
# runner write-back axis
# --------------------------------------------------------------------- #
def test_runner_calibration_axis():
    from repro.simulator.runner import ExperimentRunner

    runner = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",), rates=(4.0,),
        calibration=(None, str(GOLDEN_PATH)),
        model="llama-30b", hw="L20", tp=4, pp=1, n_instances=2,
        workload="sharegpt", duration=8.0, warmup=1.0,
        base_seed=42, n_workers=1)
    cells = runner.cells()
    assert [c.get("calibration") for c in cells] == [None,
                                                     str(GOLDEN_PATH)]
    # seed-neutral axis: calibrated and analytic cells replay the
    # identical arrival sequence
    assert cells[0]["seed"] == cells[1]["seed"]
    results = runner.run()
    assert not results.get("errors"), results.get("errors")
    assert results["meta"]["calibration"] == [None, str(GOLDEN_PATH)]
    grid = ExperimentRunner.grid(results)
    node = grid["ecoserve"]["poisson"]
    assert set(node) == {"analytic", str(GOLDEN_PATH)}
    for level in node.values():
        assert level[4.0]["finished"] > 0


def test_fitted_executor_loads_geometry_from_report():
    from repro.serving.calibration import load_fitted_executor
    from repro.simulator.cost_model import InstanceCostModel
    from repro.configs import get_config
    from repro.simulator.cost_model import GPU_L20

    like = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
    fitted = load_fitted_executor(GOLDEN_PATH, like=like)
    # timing constants come from the report; capacity/transfer geometry
    # was inherited from the analytic model at report time
    golden = json.loads(GOLDEN_PATH.read_text())
    assert fitted.prefill_per_token == golden["constants"][
        "prefill_per_token"]
    assert fitted.kv_capacity_tokens() == like.kv_capacity_tokens()
    assert fitted.kv_transfer_bytes(100) == like.kv_transfer_bytes(100)
    # the scheduler-facing surface is complete and consistent
    assert fitted.predict_prefill(64) == fitted.prefill_time([64])
    assert fitted.decode_time(0) == 0.0
    assert fitted.decode_time(2, [10, 20]) == pytest.approx(
        fitted.decode_time(2, ctx_sum=30))
