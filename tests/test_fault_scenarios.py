"""Deterministic regression layer for the fault-injection stack.

``tests/golden/fault_scenarios.json`` pins the fault-degradation grid
bit-exactly — EcoServe vs the FuDG baselines (all on the ``migrate``
failure policy) on the bursty shape, {clean, "gentle" interruption
trace} x {static, band controller} over identical arrivals — including
each faulted cell's injector log and the control loop's repair
timeline.  Regenerate (after an *intentional* change) with:

    PYTHONPATH=src python -m benchmarks.bench_fault_degradation \
        --write-golden
"""
import json
import pathlib

import pytest

from repro.simulator.runner import ExperimentRunner, fault_runner

GOLDEN = pathlib.Path(__file__).parent / "golden" / "fault_scenarios.json"

FUDG = ("distserve+migrate", "mooncake+migrate")


def _grid():
    return ExperimentRunner.grid(ExperimentRunner.load(GOLDEN))


def _rate():
    return ExperimentRunner.load(GOLDEN)["meta"]["rates"][0]


# --------------------------------------------------------------------- #
# golden reproduction across worker counts: fault schedules are seeded
# per cell, so the grid must land identically no matter how the pool
# interleaves the cells
# --------------------------------------------------------------------- #
def test_fault_golden_reproduced_bit_exactly():
    golden = ExperimentRunner.load(GOLDEN)
    fresh = fault_runner(n_workers=2).run()
    assert fresh["meta"] == golden["meta"], \
        "fault grid spec drifted from the golden fixture"
    want = json.dumps(golden["cells"], sort_keys=True)
    got = json.dumps(fresh["cells"], sort_keys=True)
    assert got == want, (
        "fault grid no longer reproduces the golden metrics (attainment, "
        "injector log, or repair timeline moved); if intentional, "
        "regenerate with `python -m benchmarks.bench_fault_degradation "
        "--write-golden` and review the diff")


@pytest.mark.parametrize("n_workers", [1, 3])
def test_fault_cells_worker_count_invariant(n_workers):
    """The headline faulted EcoServe cell, re-run under a different
    worker count, must equal the golden cell byte for byte (cell seeds
    and fault-schedule seeds depend only on the cell spec, never on
    scheduling order)."""
    golden = ExperimentRunner.load(GOLDEN)
    base = fault_runner()
    runner = ExperimentRunner(
        strategies=("ecoserve+migrate",), scenarios=base.scenarios,
        rates=base.rates, autoscale=("band",), faults=("itrace:gentle",),
        phases=base.phases, model=base.model, hw=base.hw, tp=base.tp,
        pp=base.pp, n_instances=base.n_instances, workload=base.workload,
        duration=base.duration, warmup=base.warmup,
        base_seed=base.base_seed, n_workers=n_workers)
    (fresh_cell,) = runner.run()["cells"]
    want = next(c for c in golden["cells"]
                if c["strategy"] == "ecoserve+migrate"
                and c["autoscale"] == "band"
                and c["faults"] == "itrace:gentle")
    assert json.dumps(fresh_cell, sort_keys=True) == \
        json.dumps(want, sort_keys=True), (
            f"faulted cell is not bit-exact at n_workers={n_workers}")


def test_fault_golden_covers_the_axes():
    golden = ExperimentRunner.load(GOLDEN)
    cells = golden["cells"]
    assert {c["strategy"] for c in cells} == \
        {"ecoserve+migrate"} | set(FUDG)
    assert {c["autoscale"] for c in cells} == {None, "band"}
    assert {c["faults"] for c in cells} == {None, "itrace:gentle"}
    assert golden["meta"]["faults"] == [None, "itrace:gentle"]
    # the faults axis is seed-neutral: within a strategy, clean and
    # faulted cells replay the identical arrival sequence, so the fault
    # delta isolates the injected events
    by_strat = {}
    for c in cells:
        by_strat.setdefault(c["strategy"], set()).add(c["seed"])
    for strat, seeds in by_strat.items():
        assert len(seeds) == 1, (strat, seeds)


def test_faulted_cells_carry_injector_accounting():
    """Every faulted cell reports its injector summary — 2 scheduled
    events (the gentle trace: one crash, one spot preemption), each
    either applied or explicitly skipped — and clean cells carry no
    fault key at all."""
    for cell in ExperimentRunner.load(GOLDEN)["cells"]:
        m = cell["metrics"]
        if cell["faults"] is None:
            assert "faults" not in m
            continue
        f = m["faults"]
        from repro.simulator.scenarios import INTERRUPTION_TRACES
        assert f["spec"] == INTERRUPTION_TRACES["gentle"]
        assert f["n_scheduled"] == 2
        assert f["n_skipped"] + sum(f["applied"].values()) == 2
        assert len(f["log"]) == 2


# --------------------------------------------------------------------- #
# the headline claims, pinned in the golden so they cannot silently rot
# --------------------------------------------------------------------- #
def test_ecoserve_degrades_gracefully_fudg_collapses():
    """ISSUE acceptance: EcoServe's min-phase attainment under the
    interruption trace stays strictly above every FuDG baseline's —
    under both the static pool and the band controller.  The structural
    reason is pinned alongside: MoonCake's faulted cell loses most of
    its completions outright (the crash starves its role-partitioned
    pool), while EcoServe's survivors keep serving both phases."""
    grid, rate = _grid(), _rate()
    for level in ("static", "band"):
        eco = grid["ecoserve+migrate"]["bursty"][level][
            "itrace:gentle"][rate]
        for strat in FUDG:
            fudg = grid[strat]["bursty"][level]["itrace:gentle"][rate]
            assert eco["attainment_phase_min"] > \
                fudg["attainment_phase_min"], (level, strat)
        assert eco["completion"] > 0.9
    mc = grid["mooncake+migrate"]["bursty"]["band"]["itrace:gentle"][rate]
    assert mc["completion"] < 0.2           # the FuDG cliff


def test_control_loop_restores_capacity_after_faults():
    """ISSUE acceptance: after each injected fault the band-controlled
    EcoServe cell records a repair commission (t_effective one
    provisioning delay after the decision) and its trajectory returns
    to ``n_live == n_target``; clean band cells never repair."""
    from repro.control import ControllerConfig
    cfg = ControllerConfig()
    golden = ExperimentRunner.load(GOLDEN)
    cell = next(c for c in golden["cells"]
                if c["strategy"] == "ecoserve+migrate"
                and c["autoscale"] == "band" and c["faults"])
    m = cell["metrics"]
    repairs = [e for e in m["timeline"]["events"]
               if e["action"] == "repair"]
    assert repairs, "no repair commissions despite injected faults"
    for e in repairs:
        assert e["t_effective"] == pytest.approx(
            e["t_decision"] + cfg.provision_delay)
    for ft in (e["t"] for e in m["faults"]["log"]):
        later = [p for p in m["timeline"]["trajectory"] if p["t"] > ft]
        assert later and any(p["n"] == p["n_target"] for p in later), (
            f"n_live never returned to n_target after the fault at "
            f"t={ft}")
    # repairs exist only where faults do
    for cell in golden["cells"]:
        if cell["autoscale"] == "band" and cell["faults"] is None:
            assert not any(e["action"] == "repair"
                           for e in cell["metrics"]["timeline"]["events"])


# --------------------------------------------------------------------- #
# runner plumbing for the faults axis
# --------------------------------------------------------------------- #
def test_faults_axis_is_rejected_in_goodput_mode():
    with pytest.raises(ValueError, match="fault"):
        ExperimentRunner(mode="goodput", faults=("itrace:gentle",))


def test_itrace_names_resolve_and_unknown_rejected():
    from repro.simulator.scenarios import INTERRUPTION_TRACES
    assert "gentle" in INTERRUPTION_TRACES
    assert "stormy" in INTERRUPTION_TRACES
    runner = ExperimentRunner(
        strategies=("vllm",), scenarios=("steady",), rates=(4.0,),
        faults=("itrace:nope",), duration=6.0, warmup=1.0, n_workers=1)
    out = runner.run()
    assert out["errors"], "unknown interruption trace must surface"
