"""Distribution tests on a small multi-device host mesh (subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8, so the main test
process keeps 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", py], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("arch,shape", [
    ("llama3-8b", "train_4k"),
    ("phi3.5-moe-42b-a6.6b", "decode_32k"),
    ("rwkv6-3b", "prefill_32k"),
    ("recurrentgemma-2b", "long_500k"),
])
def test_dryrun_lowers_on_small_mesh(arch, shape):
    py = f"""
import json
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun_lib import run_dryrun
mesh = make_test_mesh(data=2, model=4)
res = run_dryrun({arch!r}, {shape!r}, mesh=mesh)
print(json.dumps({{"status": res["status"],
                   "err": res.get("error", ""),
                   "dom": res.get("roofline", {{}}).get("dominant", "")}}))
"""
    out = json.loads(_run(py).strip().splitlines()[-1])
    assert out["status"] == "ok", out


def test_multipod_mesh_axes():
    py = """
from repro.launch.mesh import make_test_mesh, mesh_info
mesh = make_test_mesh(data=2, model=2, pod=2)
mi = mesh_info(mesh, global_batch=8)
assert mi.batch_axes == ("pod", "data"), mi.batch_axes
mi1 = mesh_info(mesh, global_batch=1)   # non-divisible -> replicate
assert mi1.batch_axes == ()
print("ok")
"""
    assert "ok" in _run(py)


def test_sharded_train_step_matches_single_device():
    """One train step on a (2,4) mesh must equal the single-device step."""
    py = """
import jax, jax.numpy as jnp, numpy as np
import dataclasses
from repro.configs import get_smoke_config
from repro.launch.mesh import make_test_mesh, mesh_info
from repro.models import init_params, make_loss_fn
from repro.models.layers import MeshInfo

cfg = get_smoke_config("llama3-8b")
cfg = dataclasses.replace(cfg, num_layers=2, d_model=256, num_heads=4,
                          num_kv_heads=2, head_dim=64, d_ff=512,
                          vocab_size=512)
params = init_params(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 512, (8, 32)), jnp.int32)}

loss_single = jax.jit(make_loss_fn(cfg))(params, batch)

mesh = make_test_mesh(data=2, model=4)
mi = mesh_info(mesh, global_batch=8)
with mesh:
    loss_sharded = jax.jit(make_loss_fn(cfg, mi))(params, batch)
np.testing.assert_allclose(float(loss_single), float(loss_sharded),
                           rtol=2e-4)
print("ok", float(loss_single))
"""
    assert "ok" in _run(py)


def test_moe_expert_parallel_matches_local():
    """shard_map expert-parallel MoE == single-device MoE math."""
    py = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_test_mesh, mesh_info
from repro.models.layers import moe_block, init_moe, MeshInfo
import dataclasses

cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
cfg = dataclasses.replace(cfg, d_model=128, d_ff=256, num_experts=4,
                          top_k=2, capacity_factor=8.0)
params = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8, 128)),
                jnp.float32)
y_local = moe_block(params, cfg, x, MeshInfo())

mesh = make_test_mesh(data=2, model=4)
mi = mesh_info(mesh, global_batch=4)
with mesh:
    y_ep = jax.jit(lambda p, x: moe_block(p, cfg, x, mi))(params, x)
np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-4)
print("ok")
"""
    assert "ok" in _run(py)
