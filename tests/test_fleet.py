"""Fleet layer (``repro.fleet``): spec parsing, $/token pricing,
routing policies, ServingSystem conformance, and the rebalancer's
budget/floor invariants.

The conformance anchor: a degenerate single-pool pinned fleet must
reproduce rows of ``tests/golden/scenario_grid.json`` BIT-exactly —
the fleet wrapper adds routing and accounting, never behaviour.
"""
import json
import pathlib
import random

import pytest

from repro.configs import get_config
from repro.core.request import Request
from repro.core.slo import DATASET_SLOS, SLOClassSet
from repro.fleet import (BAND, DEFAULT_GPU_PRICES, FleetRebalanceHarness,
                         FleetSystem, dollars_per_token, make_router,
                         parse_fleet)
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.engine import SimulationEngine
from repro.simulator.metrics import run_once
from repro.simulator.runner import (ExperimentRunner, cell_seed,
                                    fleet_grid_runner)
from repro.simulator.scenarios import make_mixed_scenario, make_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")

SCENARIO_GOLDEN = (pathlib.Path(__file__).parent / "golden"
                   / "scenario_grid.json")

TWO_POOL = "chat=qwen1.5-32b/ecoserve/2,code=llama-30b/ecoserve/2;budget=24"


def _req(rid, model=None, slo_class="default", prompt_len=512):
    return Request(rid=rid, arrival_time=0.0, prompt_len=prompt_len,
                   output_len=64, slo_class=slo_class, model=model)


def _two_pool_fleet(router="pinned"):
    slo = SLOClassSet.make({w: DATASET_SLOS[w]
                            for w in ("sharegpt", "longbench")})
    return FleetSystem(TWO_POOL, slo, hw="L20", tp=4, pp=1, router=router)


# --------------------------------------------------------------------- #
# spec parsing + pricing
# --------------------------------------------------------------------- #
def test_parse_fleet_reads_pools_and_budget():
    spec = parse_fleet(TWO_POOL, devices_per_instance=4)
    assert [p.name for p in spec.pools] == ["chat", "code"]
    assert [p.model for p in spec.pools] == ["qwen1.5-32b", "llama-30b"]
    assert all(p.strategy == "ecoserve" for p in spec.pools)
    assert [p.n_instances for p in spec.pools] == [2, 2]
    assert spec.budget == 24
    assert spec.committed_devices(4) == 16


def test_parse_fleet_budget_defaults_to_committed():
    spec = parse_fleet("a=llama-30b/vllm/3,b=qwen1.5-32b/mooncake/1",
                       devices_per_instance=4)
    assert spec.budget == 16  # fully packed: growth needs a donor


@pytest.mark.parametrize("bad", [
    "",
    "a=llama-30b/vllm",             # missing the count field
    "llama-30b/vllm/2",             # no name= prefix
    "a=llama-30b/vllm/0",           # zero instances
    "a=llama-30b/vllm/2,a=llama-30b/vllm/2",   # duplicate name
    "a=llama-30b/vllm/2;budget=4",  # budget below committed (at 4 dev/inst)
    "a=llama-30b/vllm/2;cap=9",     # unknown option
])
def test_parse_fleet_rejects_malformed_specs(bad):
    with pytest.raises(ValueError):
        parse_fleet(bad, devices_per_instance=4)


def test_dollars_per_token_tracks_size_and_devices():
    llama = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20,
                              tp=4, pp=1)
    qwen = InstanceCostModel(cfg=get_config("qwen1.5-32b"), hw=GPU_L20,
                             tp=4, pp=1)
    d_llama = dollars_per_token(llama, "L20")
    d_qwen = dollars_per_token(qwen, "L20")
    assert 0 < d_llama < d_qwen  # bigger model decodes slower per dollar
    doubled = dict(DEFAULT_GPU_PRICES, L20=2 * DEFAULT_GPU_PRICES["L20"])
    assert dollars_per_token(llama, "L20", doubled) == \
        pytest.approx(2 * d_llama)
    with pytest.raises(KeyError):
        dollars_per_token(llama, "H999")


# --------------------------------------------------------------------- #
# routing policies
# --------------------------------------------------------------------- #
def test_pinned_router_maps_model_tags_and_defaults_to_pool_zero():
    fleet = _two_pool_fleet("pinned")
    r = fleet.router
    assert r.route(_req(1, model="qwen1.5-32b"), fleet, 0.0) == 0
    assert r.route(_req(2, model="llama-30b"), fleet, 0.0) == 1
    assert r.route(_req(3, model=None), fleet, 0.0) == 0
    assert r.route(_req(4, model="unknown-model"), fleet, 0.0) == 0


def test_cheapest_feasible_respects_capability_then_price():
    fleet = _two_pool_fleet("cheapest-feasible")
    r = fleet.router
    # llama-tagged: both pools feasible (qwen is larger), llama is cheaper
    assert fleet.cost_per_token[1] < fleet.cost_per_token[0]
    assert r.route(_req(1, model="llama-30b"), fleet, 0.0) == 1
    # qwen-tagged: only the qwen pool is large enough
    assert r.route(_req(2, model="qwen1.5-32b"), fleet, 0.0) == 0
    # untagged: no capability claim, lands on the cheapest pool
    assert r.route(_req(3, model=None), fleet, 0.0) == 1


def test_quality_tiered_spills_only_when_preferred_pool_breaches():
    fleet = _two_pool_fleet("quality-tiered")
    r = fleet.router
    req = _req(1, model="llama-30b", slo_class="sharegpt", prompt_len=2048)
    # calm pools: stay on the pinned pool
    assert r.route(req, fleet, 0.0) == 1
    # drown the llama pool far past the sharegpt TTFT budget
    fleet.pools[1].queue.extend(_req(100 + i, prompt_len=2048)
                                for i in range(400))
    assert r.route(req, fleet, 0.0) == 0
    # drown the spill target too: don't shuffle, stay pinned
    fleet.pools[0].queue.extend(_req(600 + i, prompt_len=2048)
                                for i in range(400))
    assert r.route(req, fleet, 0.0) == 1


def test_make_router_rejects_unknown_names():
    with pytest.raises(KeyError):
        make_router("round-robin")


# --------------------------------------------------------------------- #
# ServingSystem conformance
# --------------------------------------------------------------------- #
def test_fleet_pools_live_in_disjoint_iid_bands():
    fleet = _two_pool_fleet()
    for k, pool in enumerate(fleet.pools):
        for inst in pool.instances:
            assert k * BAND <= inst.iid < (k + 1) * BAND
            assert fleet.pool_index_of_iid(inst.iid) == k
            assert fleet.owner_of(inst) is pool
    assert len({i.iid for i in fleet.instances}) == len(fleet.instances)


def test_fleet_over_budget_spec_is_rejected():
    slo = DATASET_SLOS["sharegpt"]
    with pytest.raises(ValueError):
        FleetSystem("a=llama-30b/ecoserve/4;budget=8", slo,
                    hw="L20", tp=4, pp=1)


def test_single_pool_pinned_fleet_reproduces_scenario_grid_rows():
    """The conformance anchor: wrapping one pool in a fleet must not
    move a single bit of the golden regression rows."""
    golden = ExperimentRunner.load(SCENARIO_GOLDEN)
    rows = [c for c in golden["cells"]
            if c["scenario"] in ("poisson", "bursty")
            and c["strategy"] in ("ecoserve", "vllm", "mooncake")]
    assert len(rows) == 6
    for cell in rows:
        slo = DATASET_SLOS[cell["workload"]]
        spec = f"solo={cell['model']}/{cell['strategy']}/" \
               f"{cell['n_instances']}"

        def factory(cell=cell, slo=slo, spec=spec):
            return FleetSystem(spec, slo, hw=cell["hw"], tp=cell["tp"],
                               pp=cell["pp"], router="pinned")

        scen = make_scenario(cell["scenario"], cell["workload"],
                             cell["rate"], seed=cell["seed"])
        m = run_once(factory, scen, cell["rate"], slo,
                     duration=cell["duration"], warmup=cell["warmup"],
                     seed=cell["seed"])
        got = {k: m[k] for k in cell["metrics"] if k in m}
        assert json.dumps(got, sort_keys=True) == \
            json.dumps(cell["metrics"], sort_keys=True), (
            f"single-pool fleet drifted from the golden row for "
            f"{cell['strategy']}/{cell['scenario']}")
        # and the fleet-only accounting is consistent on top: one pool,
        # so the min IS that pool's score (pool scores count unfinished
        # requests against the pool, hence <= the finished-only scalar)
        assert set(m["attainment_by_pool"]) == {"solo"}
        assert m["attainment_pool_min"] == m["attainment_by_pool"]["solo"]
        assert m["attainment_pool_min"] <= m["attainment"] + 1e-12
        assert m["fleet"]["routed"]["solo"] >= m["finished"]


# --------------------------------------------------------------------- #
# rebalancer invariants: budget ceiling + one-instance floor
# --------------------------------------------------------------------- #
def _harness():
    fleet = _two_pool_fleet()
    engine = SimulationEngine(fleet)
    return FleetRebalanceHarness(fleet, engine).attach(), fleet


def _sigs(harness, depths):
    out = []
    for k, pool in enumerate(harness.fleet.pools):
        out.append({"t": 0.0, "rate_ewma": 0.0,
                    "queue_depth": float(depths[k]),
                    "kv_occupancy": 0.0, "attainment_window": None,
                    "arrivals_total": 0.0,
                    "n_instances": float(len(pool.instances))})
    return out


def _check_invariants(harness, wants_seq, depths_seq):
    fleet = harness.fleet
    now = 0.0
    for wants, depths in zip(wants_seq, depths_seq):
        now += 2.0
        harness._reconcile(list(wants), now, _sigs(harness, depths))
        assert harness.committed_devices() <= fleet.budget, (
            f"budget exceeded after wants={wants}")
        for act in harness.actuators:
            assert act.n_target >= 1, (
                f"pool emptied after wants={wants}")


def test_rebalancer_never_exceeds_budget_nor_empties_a_pool():
    rng = random.Random(20260809)
    harness, _ = _harness()
    wants_seq = [[rng.choice((-1, 0, 1)) for _ in range(2)]
                 for _ in range(200)]
    depths_seq = [[rng.choice((0, 2, 30)) for _ in range(2)]
                  for _ in range(200)]
    _check_invariants(harness, wants_seq, depths_seq)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(-1, 1), st.integers(-1, 1),
                              st.integers(0, 40), st.integers(0, 40)),
                    min_size=1, max_size=30))
    def test_rebalancer_invariants_hold_under_any_decision_stream(steps):
        harness, _ = _harness()
        wants_seq = [(a, b) for a, b, _, _ in steps]
        depths_seq = [(qa, qb) for _, _, qa, qb in steps]
        _check_invariants(harness, wants_seq, depths_seq)


def test_rebalancer_funds_a_grow_from_a_calm_donor():
    harness2, fleet2 = _harness()
    for act in harness2.actuators:
        assert act.n_target == 2
    # the TWO_POOL budget leaves 8 GPUs free, so the first grow would
    # just fit; pin the budget to the committed 16 to force a move
    fleet2.budget = 16
    # pool 0 wants to grow, pool 1 is calm with zero backlog: donor move
    harness2._reconcile([1, 0], 2.0, _sigs(harness2, (50, 0)))
    assert harness2.n_moves == 1
    assert harness2.actuators[0].n_target == 3
    assert harness2.actuators[1].n_target == 1
    assert harness2.committed_devices() <= fleet2.budget
    # nobody can fund a second grow (donor at its floor): the ask waits
    harness2._reconcile([1, 0], 4.0, _sigs(harness2, (50, 0)))
    assert harness2.actuators[0].n_target == 3
    assert harness2.n_moves == 1


# --------------------------------------------------------------------- #
# runner integration
# --------------------------------------------------------------------- #
def test_runner_rejects_fleet_misuse():
    kw = dict(strategies=("pinned",), scenarios=("poisson",),
              fleet="a=llama-30b/ecoserve/2")
    with pytest.raises(ValueError):
        ExperimentRunner(mode="goodput", **kw)
    with pytest.raises(ValueError):
        ExperimentRunner(calibration="report.json", **kw)
    with pytest.raises(ValueError):
        ExperimentRunner(slo_override=(2.0, 0.2), **kw)


def test_fleet_cells_are_seed_neutral_across_routers_and_control():
    runner = fleet_grid_runner()
    specs = runner.cells()
    assert len(specs) == 6  # 3 routers x {static, rebalance}
    assert len({s["seed"] for s in specs}) == 1
    # the seed label is the constant "fleet", not the router name
    extra = runner._seed_extra(8, (4, 1))
    assert specs[0]["seed"] == cell_seed(42, "fleet", "poisson", 6.0,
                                         extra=extra)
    # the model tag is part of the tenant seed encoding for 4-field
    # entries only — 3-field entries keep their pre-fleet seeds
    assert "llama-30b" in extra
    legacy = ExperimentRunner(
        strategies=("ecoserve",), scenarios=("poisson",),
        tenants=(("alpaca", 0.7, "bursty"), ("longbench", 0.3, "diurnal")))
    assert "alpaca:0.7:bursty+longbench:0.3:diurnal" in \
        legacy._seed_extra(8, (4, 1))


def test_strategies_default_to_routers_with_a_fleet():
    runner = ExperimentRunner(scenarios=("poisson",),
                              fleet="a=llama-30b/ecoserve/2")
    assert tuple(runner.strategies) == \
        ("pinned", "cheapest-feasible", "quality-tiered")


# --------------------------------------------------------------------- #
# model-tagged tenants (satellite: MixedScenario bit-stability)
# --------------------------------------------------------------------- #
def test_tenant_streams_bit_stable_when_other_tenants_change_model():
    """Per-tenant arrival streams are identity-seeded on the CLASS tag,
    so re-tagging one tenant's model must not move another tenant's
    stream by a bit (and must not move its own arrivals either)."""
    base = make_mixed_scenario(
        "poisson",
        (("sharegpt", 0.5, "shift:4,1", "qwen1.5-32b"),
         ("longbench", None, "shift:1,4", "llama-30b")),
        6.0, seed=7).generate(30.0)
    moved = make_mixed_scenario(
        "poisson",
        (("sharegpt", 0.5, "shift:4,1", "qwen1.5-32b"),
         ("longbench", None, "shift:1,4", "qwen1.5-32b")),
        6.0, seed=7).generate(30.0)

    def stream(reqs, cls):
        return [(r.arrival_time, r.prompt_len, r.output_len, r.model)
                for r in reqs if r.slo_class == cls]

    assert stream(base, "sharegpt") == stream(moved, "sharegpt")
    want = [t[:3] for t in stream(base, "longbench")]
    got = [t[:3] for t in stream(moved, "longbench")]
    assert want == got
    assert all(r.model == "qwen1.5-32b" for r in moved
               if r.slo_class == "longbench")
    assert all(r.model == ("qwen1.5-32b" if r.slo_class == "sharegpt"
                           else "llama-30b") for r in base)
