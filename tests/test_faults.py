"""The fault layer (``repro.faults``): seeded schedules, failure
policies, engine-loop injection, and the actuator's commission-cancel /
repair paths.

Property layer (hypothesis with seeded fallbacks, matching the repo's
derandomized CI profile):

* a fault schedule is a pure function of (spec, seed, duration);
* the retry budget is never exceeded and resubmitted requests keep
  their ORIGINAL arrival time (TTFT charges the full wait);
* notice-window migration moves decodes with token counts intact.

End-to-end layer: crashes, preemptions, and stragglers injected through
the live engine against EcoServe and the FuDG baselines, including the
all-decoders-dead FuDG cliff and the engine discarding the in-flight
slot of a crashed instance.
"""
import random
from collections import deque

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.request import Request, RequestState
from repro.core.slo import DATASET_SLOS
from repro.faults import (FaultInjector, MigrateFailure, ResubmitFailure,
                          SlowExecutor, make_failure_policy,
                          make_fault_schedule)
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.engine import SimulationEngine
from repro.simulator.scenarios import make_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


def _cost():
    return InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)


SLO = DATASET_SLOS["sharegpt"]


# --------------------------------------------------------------------- #
# schedules: pure functions of (spec, seed, duration)
# --------------------------------------------------------------------- #
def _assert_schedule_wellformed(sched, duration):
    times = [e.t for e in sched.events]
    assert times == sorted(times)
    assert all(0.0 <= e.pick < 1.0 for e in sched.events)
    for e in sched.events:
        assert e.kind in ("crash", "preempt", "slow")


def test_schedule_deterministic_under_seed():
    spec = "crash:mtbf=12;spot:mtbf=9,notice=2;slow:t=4,factor=3,dur=6"
    a = make_fault_schedule(spec, seed=77, duration=60.0)
    b = make_fault_schedule(spec, seed=77, duration=60.0)
    assert a == b and len(a) > 0
    _assert_schedule_wellformed(a, 60.0)
    # a different seed moves the recurring draws (same one-shots)
    c = make_fault_schedule(spec, seed=78, duration=60.0)
    mtbf_a = [e.t for e in a.events if e.t != 4.0]
    mtbf_c = [e.t for e in c.events if e.t != 4.0]
    assert mtbf_a != mtbf_c
    # and a different spec re-seeds even at the same cell seed
    d = make_fault_schedule(spec + ";crash:t=50", seed=77, duration=60.0)
    assert [e.t for e in d.events] != [e.t for e in a.events]


def test_spot_alias_and_clause_defaults():
    s = make_fault_schedule("spot:mtbf=5,notice=2", seed=1, duration=40.0)
    assert s.events and all(e.kind == "preempt" for e in s.events)
    assert all(e.notice == 2.0 for e in s.events)
    assert all(e.t < 40.0 for e in s.events)
    one = make_fault_schedule("slow:t=3", seed=1, duration=40.0)
    (ev,) = one.events
    assert (ev.factor, ev.duration) == (2.0, 5.0)   # documented defaults


def test_schedule_parse_errors():
    with pytest.raises(KeyError, match="unknown fault kind"):
        make_fault_schedule("meteor:t=3", seed=0, duration=10.0)
    with pytest.raises(ValueError, match="exactly one of"):
        make_fault_schedule("crash:t=3,mtbf=5", seed=0, duration=10.0)
    with pytest.raises(ValueError, match="exactly one of"):
        make_fault_schedule("crash:notice=2", seed=0, duration=10.0)
    with pytest.raises(ValueError, match="unknown fault options"):
        make_fault_schedule("crash:t=3,warp=9", seed=0, duration=10.0)
    with pytest.raises(ValueError, match="malformed"):
        make_fault_schedule("crash:t", seed=0, duration=10.0)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           mtbf=st.floats(min_value=1.0, max_value=50.0),
           duration=st.floats(min_value=5.0, max_value=120.0))
    def test_schedule_purity_property(seed, mtbf, duration):
        spec = f"crash:mtbf={mtbf:g};spot:mtbf={mtbf:g},notice=1"
        a = make_fault_schedule(spec, seed=seed, duration=duration)
        assert a == make_fault_schedule(spec, seed=seed, duration=duration)
        _assert_schedule_wellformed(a, duration)
        assert all(e.t < duration for e in a.events)


def test_schedule_purity_seeded():
    rng = random.Random(9)
    for _ in range(30):
        seed = rng.randrange(2**31)
        mtbf = rng.uniform(1.0, 50.0)
        duration = rng.uniform(5.0, 120.0)
        spec = f"crash:mtbf={mtbf:g};spot:mtbf={mtbf:g},notice=1"
        a = make_fault_schedule(spec, seed=seed, duration=duration)
        assert a == make_fault_schedule(spec, seed=seed, duration=duration)
        _assert_schedule_wellformed(a, duration)
        assert all(e.t < duration for e in a.events)


# --------------------------------------------------------------------- #
# failure policies: construction, retry budget, arrival-time contract
# --------------------------------------------------------------------- #
def test_make_failure_policy_specs_and_errors():
    assert make_failure_policy("drop").describe() == "drop"
    assert make_failure_policy("resubmit").describe() == "resubmit:2"
    assert make_failure_policy("resubmit:0").budget == 0
    assert make_failure_policy("migrate:3").describe() == "migrate:3"
    p = make_failure_policy("migrate")
    assert make_failure_policy(p) is p
    with pytest.raises(KeyError, match="unknown failure policy"):
        make_failure_policy("teleport")
    with pytest.raises(TypeError):
        make_failure_policy(42)


class _StatsSys:
    """Minimal surface ResubmitFailure needs: a queue and the stats."""

    def __init__(self):
        self.queue = deque()
        self.fault_stats = {"dropped": 0, "resubmitted": 0, "requeued": 0}


def _hit_until_dead(budget, hits):
    pol = ResubmitFailure(budget)
    sys_ = _StatsSys()
    req = Request(rid=1, arrival_time=1.5, prompt_len=16, output_len=4)
    req.tokens_generated = 2
    for _ in range(hits):
        if req.state == RequestState.FAILED:
            break
        sys_.queue.clear()           # the next fault takes it off-queue
        pol.on_instance_fault(sys_, None, [req], 0.0, None)
    return pol, sys_, req


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(budget=st.integers(0, 3), hits=st.integers(1, 6))
    def test_retry_budget_never_exceeded_property(budget, hits):
        _, sys_, req = _hit_until_dead(budget, hits)
        assert req.retries == min(hits, budget)
        assert req.arrival_time == 1.5          # never reset
        if hits > budget:
            assert req.state == RequestState.FAILED
            assert sys_.fault_stats["dropped"] == 1
        else:
            assert req.state == RequestState.QUEUED
            assert req.tokens_generated == 0    # lost work re-earned
            assert req in sys_.queue


def test_retry_budget_never_exceeded_seeded():
    rng = random.Random(5)
    for _ in range(40):
        budget, hits = rng.randint(0, 3), rng.randint(1, 6)
        _, sys_, req = _hit_until_dead(budget, hits)
        assert req.retries == min(hits, budget)
        assert req.arrival_time == 1.5
        assert (req.state == RequestState.FAILED) == (hits > budget)


def test_migration_preserves_token_counts_and_first_token_time():
    """Notice-window migration moves a decode through the serialized
    ``InstanceHandler`` path: token counts and TTFT history intact, no
    re-prefill."""
    system = make_system("ecoserve", _cost(), 2, SLO, failure="migrate")
    a, b = system.instances
    r = Request(rid=7, arrival_time=0.0, prompt_len=64, output_len=10)
    r.state = RequestState.DECODING
    r.tokens_generated = 3
    r.first_token_time = 0.5
    r.instance_id = a.iid
    a.add_decoding(r)
    system.detach_instance(a)
    system._evacuating[a.iid] = 5.0
    system.failure.on_evacuation_slot(system, a, 1.0, None)
    assert r in b.decoding and r not in a.decoding
    assert r.instance_id == b.iid
    assert r.tokens_generated == 3              # no work lost
    assert r.first_token_time == 0.5            # TTFT history intact
    assert system.fault_stats["migrated"] == 1
    assert a.iid not in system._evacuating      # fully evacuated


def test_migration_with_no_live_target_falls_back_to_resubmit():
    system = make_system("ecoserve", _cost(), 2, SLO, failure="migrate")
    a, b = system.instances
    r = Request(rid=8, arrival_time=0.0, prompt_len=64, output_len=10)
    r.state = RequestState.DECODING
    r.tokens_generated = 3
    a.add_decoding(r)
    b.alive = False                              # nowhere to go
    system.detach_instance(a)
    system._evacuating[a.iid] = 5.0
    system.failure.on_evacuation_slot(system, a, 1.0, None)
    assert r.state == RequestState.QUEUED and r.retries == 1
    assert r.tokens_generated == 0               # KV will be lost anyway


# --------------------------------------------------------------------- #
# end-to-end injection through the live engine
# --------------------------------------------------------------------- #
def _finished_are_complete(reqs):
    for r in reqs:
        if r.state == RequestState.FINISHED:
            assert r.tokens_generated == r.output_len, r.rid


def test_crash_resubmit_end_to_end():
    system = make_system("vllm", _cost(), 3, SLO, failure="resubmit:1")
    scen = make_scenario("poisson", "sharegpt", 6.0, seed=7)
    reqs = scen.generate(24.0)
    arrival = {r.rid: r.arrival_time for r in reqs}
    engine = SimulationEngine(system)
    sched = make_fault_schedule("crash:mtbf=9", seed=3, duration=24.0)
    inj = FaultInjector(sched, system).attach(engine)
    engine.run(reqs, horizon=60.0)
    assert system.fault_stats["crashes"] >= 1
    assert system.fault_stats["resubmitted"] >= 1
    assert all(r.retries <= 1 for r in reqs)
    assert all(arrival[r.rid] == r.arrival_time for r in reqs)
    failed = [r for r in reqs if r.state == RequestState.FAILED]
    assert len(failed) == system.fault_stats["dropped"]
    _finished_are_complete(reqs)
    # the injector's log matches the stats it reports
    s = inj.summary()
    assert s["applied"].get("crash", 0) == system.fault_stats["crashes"]
    assert s["stats"] == system.fault_stats


def test_preempt_notice_migrates_end_to_end():
    system = make_system("ecoserve", _cost(), 4, SLO, failure="migrate")
    scen = make_scenario("poisson", "sharegpt", 6.0, seed=11)
    reqs = scen.generate(24.0)
    engine = SimulationEngine(system)
    sched = make_fault_schedule("preempt:t=8,notice=2", seed=3,
                                duration=24.0)
    FaultInjector(sched, system).attach(engine)
    engine.run(reqs, horizon=60.0)
    assert system.fault_stats["preemptions"] == 1
    assert len(system.instances) == 3
    _finished_are_complete(reqs)
    # work was on the victim at notice time: it moved or requeued, and
    # nothing the policy handled was silently lost
    moved = (system.fault_stats["migrated"]
             + system.fault_stats["requeued"]
             + system.fault_stats["resubmitted"])
    assert moved >= 1
    # nothing is stranded on the preempted instance: whatever is still
    # running at horizon sits on a live survivor
    live_ids = {i.iid for i in system.instances}
    for r in reqs:
        if r.state == RequestState.DECODING:
            assert r.instance_id in live_ids


def test_engine_discards_in_flight_slot_of_crashed_instance():
    """The invariant behind hard kills: a busy instance always has an
    in-flight completion event; crashing it mid-slot must discard that
    completion (its requests were already re-routed) instead of applying
    it to a corpse."""
    system = make_system("vllm", _cost(), 2, SLO, failure="resubmit:2")
    r = Request(rid=1, arrival_time=0.0, prompt_len=256, output_len=4)
    engine = SimulationEngine(system)

    def kill():
        inst = next(i for i in system.instances if i.busy)
        system.fault_crash(inst, engine.now, engine)

    engine.push_call(0.01, kill)     # lands inside the first prefill slot
    engine.run([r], horizon=30.0)
    assert system.fault_stats["crashes"] == 1
    assert r.state == RequestState.FINISHED and r.retries == 1
    assert r.tokens_generated == r.output_len
    assert len(system.instances) == 1
    assert all(i.alive for i in system.instances)


def test_fudg_cliff_all_decoders_dead_loses_requests():
    """DistServe with its lone decode instance crashed: prefilled KV has
    nowhere to land, so the hand-off hook must route requests through
    ``fault_lost_requests`` (here: drop) instead of crashing on an empty
    ``min()``."""
    system = make_system("distserve", _cost(), 2, SLO, failure="drop",
                         prefill_ratio=0.5)
    assert len(system.decode_insts) == 1
    scen = make_scenario("poisson", "sharegpt", 4.0, seed=5)
    reqs = scen.generate(10.0)
    engine = SimulationEngine(system)
    engine.push_call(1.0, lambda: system.fault_crash(
        system.decode_insts[0], engine.now, engine))
    engine.run(reqs, horizon=40.0)
    assert system.fault_stats["crashes"] == 1
    assert not system.decode_insts          # routing dropped the corpse
    assert system.fault_stats["dropped"] >= 1
    failed = [r for r in reqs if r.state == RequestState.FAILED]
    assert len(failed) == system.fault_stats["dropped"]
    _finished_are_complete(reqs)


def test_slowdown_wraps_then_restores_executor():
    system = make_system("ecoserve", _cost(), 2, SLO)
    scen = make_scenario("poisson", "sharegpt", 4.0, seed=2)
    reqs = scen.generate(12.0)
    engine = SimulationEngine(system)
    sched = make_fault_schedule("slow:t=2,factor=4,dur=3", seed=1,
                                duration=12.0)
    FaultInjector(sched, system).attach(engine)
    engine.run(reqs, horizon=40.0)
    assert system.fault_stats["slowdowns"] == 1
    assert not any(isinstance(i.executor, SlowExecutor)
                   for i in system.instances)   # restored after dur
    _finished_are_complete(reqs)


def test_injector_never_kills_the_last_instance():
    system = make_system("vllm", _cost(), 2, SLO, failure="drop")
    engine = SimulationEngine(system)
    sched = make_fault_schedule("crash:mtbf=2", seed=4, duration=20.0)
    inj = FaultInjector(sched, system).attach(engine)
    engine.run([], horizon=30.0)
    assert len(system.instances) == 1           # one crash landed, rest
    s = inj.summary()                           # skipped at the floor
    assert s["applied"].get("crash") == 1
    assert s["n_skipped"] == len(sched.events) - 1
    assert all(e.get("skipped") == "last-instance"
               for e in s["log"][1:])


# --------------------------------------------------------------------- #
# actuator: down-during-provisioning cancel + fault repair
# --------------------------------------------------------------------- #
def _make_actuator(n=4, delay=5.0):
    from repro.control import ControllerConfig, ScalingTimeline
    from repro.control.actuator import Actuator
    system = make_system("ecoserve", _cost(), n, SLO)
    engine = SimulationEngine(system)
    cfg = ControllerConfig(provision_delay=delay)
    act = Actuator(system, engine, cfg, ScalingTimeline())
    return system, engine, act


_SIGNALS = {"queue_depth": 0.0, "attainment_window": 1.0}


def test_down_while_provisioning_cancels_the_commission():
    """Regression for the actuator race: a "down" decision while a
    commission was still in flight used to shrink the live pool AND let
    the provisioning instance join anyway — overshooting the target.
    The fix revokes the pending commission instead."""
    system, engine, act = _make_actuator(n=4, delay=5.0)
    assert act.apply(+1, 0.0, _SIGNALS)
    assert act.n_target == 5 and len(system.instances) == 4
    assert act.apply(-1, 1.0, _SIGNALS)          # delay > decision gap
    assert act.n_target == 4
    assert len(system.instances) == 4            # live pool untouched
    engine.run([], horizon=20.0)                 # commission event fires
    assert len(system.instances) == 4            # ...and was revoked
    assert act.n_target == 4
    downs = [e for e in act.timeline.events if e.action == "down"]
    assert downs and downs[0].t_effective == downs[0].t_decision


def test_down_cancels_only_one_of_two_pending_commissions():
    system, engine, act = _make_actuator(n=4, delay=5.0)
    act.apply(+1, 0.0, _SIGNALS)
    act.apply(+1, 0.5, _SIGNALS)
    act.apply(-1, 1.0, _SIGNALS)
    assert act.n_target == 5
    engine.run([], horizon=20.0)
    assert len(system.instances) == 5 and act.n_target == 5


def test_down_with_no_pending_commission_shrinks_live_pool():
    system, engine, act = _make_actuator(n=4, delay=5.0)
    assert act.apply(-1, 0.0, _SIGNALS)
    assert len(system.instances) == 3 and act.n_target == 3


def test_repair_recommissions_capacity_lost_to_faults():
    """The control loop's repair path: a crash drops ``n_live`` (and so
    ``n_target``) below the controller's last committed intent; repair
    commissions a replacement — and ONLY for fault losses, never after
    the controller's own down decisions."""
    system, engine, act = _make_actuator(n=4, delay=2.0)
    act.note_intent(act.n_target)                # controller committed 4
    assert act.repair(0.0, _SIGNALS) == 0        # nothing lost: no-op
    system.fault_crash(system.instances[0], 1.0, engine)
    assert act.n_target == 3
    assert act.repair(1.5, _SIGNALS) == 1
    assert act.n_target == 4                     # committed, not yet live
    engine.run([], horizon=10.0)
    assert len(system.instances) == 4            # replacement landed
    rep = [e for e in act.timeline.events if e.action == "repair"]
    assert len(rep) == 1
    assert rep[0].t_effective == pytest.approx(1.5 + 2.0)
    # a deliberate down must NOT be repaired: intent moves with it
    act.apply(-1, 5.0, _SIGNALS)
    act.note_intent(act.n_target)
    assert act.repair(5.5, _SIGNALS) == 0
