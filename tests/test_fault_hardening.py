"""Hardening layer riding on the PR 7 network plane: the actor-registry
leak fix, the per-class attainment guard, and the composed-storm
invariants.

* **registry leak** — ``fault_crash`` / the preemption deadline used to
  detach an instance without ``unregister_instance``, so every migration
  target that later died stayed in the module-global actor registry
  forever; a crash/preemption storm now leaves the registry holding live
  instances only;
* **per-class guard** — ``SignalCollector.attainment_window`` excludes
  classes with fewer than ``min_samples`` window completions from the
  min instead of letting one straggler read as an SLO collapse;
* **composed storms** — crash + preempt + slow + network clauses in one
  spec, injected end to end (hypothesis with a seeded fallback): no
  request both finishes and fails, the injector log matches
  ``fault_stats``, the registry stays bounded, and no retry budget —
  request resubmits or transport attempts — is ever exceeded.
"""
import random

import pytest

from repro.baselines import make_system
from repro.configs import get_config
from repro.core.mitosis import registry_size
from repro.core.request import Request, RequestState
from repro.core.slo import DATASET_SLOS, SLO, SLOClassSet
from repro.core.transport import TransportConfig
from repro.control.signals import SignalCollector
from repro.faults import FaultInjector, make_fault_schedule
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.engine import SimulationEngine
from repro.simulator.scenarios import make_scenario

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


def _cost():
    return InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)


SLO_SET = DATASET_SLOS["sharegpt"]


# --------------------------------------------------------------------- #
# satellite: the actor-registry leak through the fault paths
# --------------------------------------------------------------------- #
def _storm(seed, spec="crash:mtbf=7;spot:mtbf=6,notice=1.5"):
    """Run a crash/preemption storm on a baseline system whose migrate
    policy registers survivor handlers at every evacuation — the exact
    traffic that used to leak registry entries when a past target died."""
    system = make_system("vllm", _cost(), 5, SLO_SET, failure="migrate")
    scen = make_scenario("poisson", "sharegpt", 6.0, seed=seed)
    reqs = scen.generate(30.0)
    engine = SimulationEngine(system)
    sched = make_fault_schedule(spec, seed=seed, duration=30.0)
    inj = FaultInjector(sched, system).attach(engine)
    engine.run(reqs, horizon=90.0)
    return system, reqs, inj


def test_registry_bounded_through_crash_preempt_storm():
    baseline = registry_size()
    system, _, _ = _storm(seed=13)
    killed = (system.fault_stats["crashes"]
              + system.fault_stats["preemptions"])
    assert killed >= 3, "storm too gentle to exercise the leak"
    # every registered actor is a live pool member: dead instances were
    # unregistered by fault_crash / the preemption deadline, so repeated
    # storms cannot grow the module-global registry without bound
    assert registry_size() <= baseline + len(system.instances)
    from repro.core.mitosis import _ACTOR_REGISTRY
    for iid, inst in _ACTOR_REGISTRY.items():
        assert inst.alive, f"dead instance {iid} leaked in the registry"


def test_registry_does_not_grow_across_repeated_storms():
    baseline = registry_size()
    sizes = []
    for seed in (21, 22, 23):
        system, _, _ = _storm(seed=seed)
        sizes.append(registry_size())
    bound = baseline + 5                 # never above one pool's worth
    assert all(s <= bound for s in sizes), (baseline, sizes)


# --------------------------------------------------------------------- #
# satellite: per-class min_samples guard in the attainment window
# --------------------------------------------------------------------- #
def _finished_req(rid, t, ok, cls):
    r = Request(rid=rid, arrival_time=t, prompt_len=8, output_len=2,
                slo_class=cls)
    r.first_token_time = t + (0.2 if ok else 50.0)
    r.finish_time = r.first_token_time + 0.01
    r.tokens_generated = 2
    return r


def test_attainment_guard_is_per_class():
    classes = SLOClassSet.make({
        "default": SLO(ttft=1.0, tpot=0.1),
        "batch": SLO(ttft=1.0, tpot=0.1)})
    col = SignalCollector(classes, window=100.0, min_samples=4)
    # 6 healthy default completions + ONE missed batch straggler: the
    # straggler's class has 1 < min_samples window completions, so it is
    # excluded from the min — the signal reads the healthy class, not a
    # phantom 0.0 collapse
    done = [_finished_req(i, float(i), True, "default") for i in range(6)]
    done.append(_finished_req(99, 6.0, False, "batch"))
    col.consume_finished(done, 7.0)
    assert col.attainment_window() == 1.0
    # once the sparse class reaches min_samples it re-enters the min
    done += [_finished_req(100 + i, 8.0 + i, False, "batch")
             for i in range(3)]
    col.consume_finished(done, 12.0)
    assert col.attainment_window() == 0.0
    # and when NO class qualifies the whole signal is None
    sparse = SignalCollector(classes, window=100.0, min_samples=4)
    sparse.consume_finished(
        [_finished_req(0, 0.0, True, "default"),
         _finished_req(1, 0.0, False, "batch"),
         _finished_req(2, 0.0, True, "default"),
         _finished_req(3, 0.0, False, "batch")], 1.0)
    assert sparse.attainment_window() is None


def test_attainment_guard_single_class_unchanged():
    """With one class the per-class guard degrades to exactly the old
    global guard (the autoscale goldens depend on this)."""
    single = SLOClassSet.single(SLO(ttft=1.0, tpot=0.1))
    col = SignalCollector(single, window=100.0, min_samples=3)
    done = [_finished_req(i, float(i), i != 0, "default")
            for i in range(2)]
    col.consume_finished(done, 3.0)
    assert col.attainment_window() is None
    done.append(_finished_req(5, 2.5, True, "default"))
    col.consume_finished(done, 3.0)
    assert col.attainment_window() == pytest.approx(2 / 3)


# --------------------------------------------------------------------- #
# satellite: composed fault storms (crash + preempt + slow + network)
# --------------------------------------------------------------------- #
STORM_SPEC = ("crash:mtbf=14;spot:mtbf=11,notice=1.5;"
              "slow:t=5,factor=2.5,dur=8;"
              "netdelay:60;netloss:{p:g};netdegrade:3:10")


def _composed_storm(seed, p):
    system = make_system("mooncake", _cost(), 4, SLO_SET,
                         failure="migrate")
    scen = make_scenario("bursty", "sharegpt", 5.0, seed=seed)
    reqs = scen.generate(28.0)
    engine = SimulationEngine(system)
    spec = STORM_SPEC.format(p=p)
    sched = make_fault_schedule(spec, seed=seed, duration=28.0)
    inj = FaultInjector(sched, system).attach(engine)
    engine.run(reqs, horizon=90.0)
    return system, reqs, inj


def _assert_storm_invariants(system, reqs, inj, baseline_registry):
    # 1. no request is both finished and lost/failed, and finished means
    #    complete
    for r in reqs:
        if r.state == RequestState.FINISHED:
            assert r.tokens_generated == r.output_len, r.rid
    failed = [r for r in reqs if r.state == RequestState.FAILED]
    finished = {r.rid for r in reqs
                if r.state == RequestState.FINISHED}
    assert not finished & {r.rid for r in failed}
    assert len(failed) == system.fault_stats["dropped"]
    # 2. fault_stats is consistent with the injector's own log
    s = inj.summary()
    assert s["stats"] == system.fault_stats
    applied = s["applied"]
    assert applied.get("crash", 0) == system.fault_stats["crashes"]
    assert applied.get("preempt", 0) == system.fault_stats["preemptions"]
    assert s["n_skipped"] + sum(applied.values()) == s["n_scheduled"]
    assert len(s["log"]) == s["n_scheduled"]
    # 3. the actor registry stays bounded (dead instances unregistered)
    assert registry_size() <= baseline_registry + len(system.instances)
    # 4. no retry budget exceeded: request resubmits against the policy
    #    budget, transport attempts against the config budget
    for r in reqs:
        assert r.retries <= 3
    tr = system.transport
    assert tr.network is not None       # the net clauses attached a plane
    cap = TransportConfig().retries + 1
    for e in tr.log:
        assert 1 <= e["attempts"] <= cap, e
    assert tr.stats["delivered"] + tr.stats["lost"] == tr.stats["sent"]
    assert "transport" in s             # counters ride the summary


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           p=st.floats(min_value=0.0, max_value=0.25))
    def test_composed_storm_invariants_property(seed, p):
        baseline = registry_size()
        system, reqs, inj = _composed_storm(seed, p)
        _assert_storm_invariants(system, reqs, inj, baseline)


def test_composed_storm_invariants_seeded():
    rng = random.Random(4)
    for _ in range(4):
        seed = rng.randrange(2**31)
        p = rng.uniform(0.0, 0.25)
        baseline = registry_size()
        system, reqs, inj = _composed_storm(seed, p)
        _assert_storm_invariants(system, reqs, inj, baseline)
