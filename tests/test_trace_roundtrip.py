"""Round-trip/property tests for the JSONL trace codec with ``slo_class``
tags: ``write_trace`` -> ``TraceReplay.from_jsonl`` -> ``trace_lines``
must be lossless (tags included), untagged legacy JSONL must load with
the default class, and default-class traces must stay byte-identical to
the legacy three-key format.
"""
import json
import random

import pytest

from repro.core.request import Request
from repro.core.slo import DEFAULT_SLO_CLASS
from repro.simulator.scenarios import (TraceReplay, _parse_trace,
                                       make_mixed_scenario, trace_lines,
                                       write_trace)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

CLASSES = (DEFAULT_SLO_CLASS, "alpaca", "sharegpt", "longbench",
           "tenant-x")


def _requests(specs):
    """specs: [(arrival_time, prompt_len, output_len, slo_class)]"""
    return [Request(rid=i, arrival_time=t, prompt_len=p, output_len=o,
                    slo_class=c)
            for i, (t, p, o, c) in enumerate(specs)]


def _key(reqs):
    return [(r.rid, r.arrival_time, r.prompt_len, r.output_len,
             r.slo_class) for r in reqs]


def check_roundtrip_lossless(specs, tmp_path=None) -> None:
    """Codec round trip; with ``tmp_path`` the trip goes through a real
    JSONL file (``write_trace``/``from_jsonl``), otherwise in memory
    (hypothesis examples must not touch function-scoped fixtures)."""
    reqs = _requests(specs)
    if tmp_path is not None:
        path = tmp_path / "trace.jsonl"
        write_trace(reqs, path)
        replay = TraceReplay.from_jsonl(path)
    else:
        replay = TraceReplay("mem", _parse_trace(trace_lines(reqs)))
    back = replay.generate()
    assert _key(back) == _key(reqs)
    # second trip through the codec is a fixed point
    assert trace_lines(back) == trace_lines(reqs)


# --------------------------------------------------------------------- #
# hypothesis drive
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    SPEC = st.tuples(
        st.floats(min_value=0.0, max_value=1e4, allow_nan=False,
                  allow_infinity=False),
        st.integers(1, 4096),
        st.integers(1, 2048),
        st.sampled_from(CLASSES))

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(specs=st.lists(SPEC, min_size=0, max_size=40))
    def test_roundtrip_lossless_property(specs):
        check_roundtrip_lossless(specs)


# --------------------------------------------------------------------- #
# seeded fallback + fixed cases
# --------------------------------------------------------------------- #
def test_roundtrip_lossless_seeded(tmp_path):
    rng = random.Random(7)
    for trial in range(10):
        specs = [(rng.random() * 1e3, rng.randint(1, 4096),
                  rng.randint(1, 2048), rng.choice(CLASSES))
                 for _ in range(rng.randint(0, 40))]
        check_roundtrip_lossless(specs, tmp_path)


def test_mixed_scenario_trace_roundtrip(tmp_path):
    scen = make_mixed_scenario("bursty", ["alpaca", "longbench"], 8.0,
                               seed=11)
    reqs = scen.generate(45.0)
    assert {r.slo_class for r in reqs} == {"alpaca", "longbench"}
    check_roundtrip_lossless(
        [(r.arrival_time, r.prompt_len, r.output_len, r.slo_class)
         for r in reqs], tmp_path)


def test_untagged_legacy_jsonl_loads_with_default_class(tmp_path):
    path = tmp_path / "legacy.jsonl"
    path.write_text(
        '{"arrival_time": 0.25, "prompt_len": 64, "output_len": 8}\n'
        '\n'   # blank lines tolerated
        '{"arrival_time": 1.5, "prompt_len": 128, "output_len": 16}\n')
    reqs = TraceReplay.from_jsonl(path).generate()
    assert [r.slo_class for r in reqs] == [DEFAULT_SLO_CLASS] * 2
    assert [r.prompt_len for r in reqs] == [64, 128]


def test_default_class_traces_keep_legacy_byte_format():
    """Untagged requests serialize to exactly the historical three-key
    record — freezing a single-tenant workload cannot perturb existing
    trace files or their consumers."""
    r = Request(rid=0, arrival_time=0.125, prompt_len=7, output_len=3)
    (line,) = trace_lines([r])
    assert json.loads(line) == {"arrival_time": 0.125, "prompt_len": 7,
                                "output_len": 3}
    assert "slo_class" not in line
    tagged = Request(rid=1, arrival_time=0.5, prompt_len=9, output_len=2,
                     slo_class="alpaca")
    (tline,) = trace_lines([tagged])
    assert json.loads(tline)["slo_class"] == "alpaca"
