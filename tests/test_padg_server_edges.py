"""``PaDGServer.serve`` edge cases (fake backend: no jax required).

Covers the satellite checklist: empty traces keep the full summary key
set (stable JSONL schema), ``time_scale`` really dilates wall time,
over-long prompts take the all-rejected path without touching the
scheduler, and ``shutdown()`` releases every actor-registry entry taken
in ``__init__`` (the PR 6/7 mitosis-leak regression, server edition).
"""
import time

import pytest

from repro.core.mitosis import _ACTOR_REGISTRY, registry_size
from repro.core.request import Request, RequestState
from repro.core.slo import SLO
from repro.serving.padg_server import PaDGServer, ServeStats
from repro.serving.replay import SlotConfig, VirtualClock, WallClock
from repro.simulator.cost_model import FittedExecutor

B, S = 2, 64
SLO_SET = SLO(ttft=0.5, tpot=0.05)
SUMMARY_KEYS = {"finished", "rejected", "ttft_p50", "ttft_p90",
                "tpot_p50", "tokens"}


def model() -> FittedExecutor:
    return FittedExecutor(prefill_base=1e-3, prefill_per_token=1e-4,
                          decode_base=5e-4, decode_per_seq=2e-4,
                          kv_capacity=B * S)


def make_server() -> PaDGServer:
    return PaDGServer(None, n_instances=2, slo=SLO_SET,
                      econf=SlotConfig(max_batch=B, max_seq_len=S),
                      backend="fake", executor=model())


def reqs(n=4, span=0.05, plen=10, olen=3):
    gap = span / max(1, n - 1) if n > 1 else 0.0
    return [Request(rid=i, arrival_time=i * gap, prompt_len=plen,
                    output_len=olen) for i in range(n)]


# --------------------------------------------------------------------- #
def test_empty_trace_full_summary_schema():
    server = make_server()
    try:
        stats = server.serve([], clock=VirtualClock())
    finally:
        server.shutdown()
    assert stats.finished == [] and stats.rejected == []
    s = stats.summary()
    assert set(s) == SUMMARY_KEYS, "empty summary must keep the schema"
    assert s["finished"] == 0 and s["tokens"] == 0
    assert s["ttft_p50"] == 0.0 and s["tpot_p50"] == 0.0


def test_summary_schema_stable_empty_vs_loaded():
    """The JSONL schema contract: the key set must not depend on whether
    anything finished."""
    empty = ServeStats(finished=[]).summary()
    server = make_server()
    try:
        loaded = server.serve(reqs(), clock=VirtualClock()).summary()
    finally:
        server.shutdown()
    assert set(empty) == set(loaded) == SUMMARY_KEYS
    assert loaded["finished"] == 4 and loaded["tokens"] == 12


def test_all_requests_rejected():
    server = make_server()
    try:
        bad = [Request(rid=i, arrival_time=0.0, prompt_len=S + 10,
                       output_len=2) for i in range(3)]
        stats = server.serve(bad, clock=VirtualClock())
    finally:
        server.shutdown()
    assert stats.finished == []
    assert len(stats.rejected) == 3
    assert all(r.state is RequestState.FAILED for r in stats.rejected)
    s = stats.summary()
    assert set(s) == SUMMARY_KEYS
    assert s["finished"] == 0 and s["rejected"] == 3


def test_rejection_boundary_is_engine_seq_cap():
    """prompt_len == max_seq_len - 2 is the largest servable prompt (one
    slot position for the first token, one for the cap sentinel)."""
    server = make_server()
    try:
        ok = Request(rid=0, arrival_time=0.0, prompt_len=S - 2,
                     output_len=1)
        too_big = Request(rid=1, arrival_time=0.0, prompt_len=S - 1,
                          output_len=1)
        stats = server.serve([ok, too_big], clock=VirtualClock())
    finally:
        server.shutdown()
    assert [r.rid for r in stats.finished] == [0]
    assert [r.rid for r in stats.rejected] == [1]


def test_time_scale_dilates_wall_clock():
    span = 0.08
    elapsed = {}
    for scale in (1.0, 4.0):
        server = make_server()
        try:
            t0 = time.perf_counter()
            stats = server.serve(reqs(n=3, span=span), time_scale=scale)
            elapsed[scale] = time.perf_counter() - t0
        finally:
            server.shutdown()
        assert len(stats.finished) == 3
        # trace time is clock-paced: serving can't end before the last
        # arrival, i.e. span * scale wall seconds in
        assert elapsed[scale] >= span * scale * 0.9
    assert elapsed[4.0] > elapsed[1.0]
    # loose upper bound: the fake backend executes instantly, so wall
    # time is dominated by the dilated arrival span
    assert elapsed[4.0] < span * 4.0 + 1.0


def test_explicit_wall_clock_object():
    server = make_server()
    try:
        stats = server.serve(reqs(n=2, span=0.01), clock=WallClock(2.0))
    finally:
        server.shutdown()
    assert len(stats.finished) == 2
    for r in stats.finished:
        assert r.finish_time >= r.first_token_time >= 0.0


# --------------------------------------------------------------------- #
def test_registry_released_on_shutdown():
    snapshot = dict(_ACTOR_REGISTRY)
    server = make_server()
    assert registry_size() >= len(snapshot)
    assert all(inst.iid in _ACTOR_REGISTRY for inst in server.instances)
    server.serve(reqs(), clock=VirtualClock())
    server.shutdown()
    assert _ACTOR_REGISTRY == snapshot, (
        "PaDGServer leaked actor-registry entries across shutdown")
    server.shutdown()          # idempotent
    assert _ACTOR_REGISTRY == snapshot


def test_registry_released_by_context_manager():
    snapshot = dict(_ACTOR_REGISTRY)
    with make_server() as server:
        stats = server.serve(reqs(n=2), clock=VirtualClock())
        assert len(stats.finished) == 2
    assert _ACTOR_REGISTRY == snapshot


def test_fake_backend_requires_executor():
    with pytest.raises(ValueError, match="executor"):
        PaDGServer(None, n_instances=1, slo=SLO_SET,
                   econf=SlotConfig(max_batch=B, max_seq_len=S),
                   backend="fake")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        PaDGServer(None, n_instances=1, slo=SLO_SET,
                   econf=SlotConfig(max_batch=B, max_seq_len=S),
                   backend="quantum")
