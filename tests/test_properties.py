"""Property-based tests (hypothesis) for the system's invariants."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.constraints import check_constraints
from repro.core.instance import Instance, InstanceStatus
from repro.core.mitosis import OverallScheduler, register_instance
from repro.core.request import Request
from repro.core.slo import SLO
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.configs import get_config


class Exec:
    def prefill_time(self, lens):
        return 1e-4 * sum(lens)

    def decode_time(self, b, c):
        return 0.02


PRED = lambda n: 1e-4 * n


# --------------------------------------------------------------------- #
@settings(max_examples=60, deadline=None)
@given(
    pending=st.lists(st.integers(1, 4000), max_size=8),
    saved=st.lists(st.floats(-1.0, 10.0), max_size=8),
    new_len=st.integers(1, 4096),
    kv_free=st.integers(0, 100_000),
)
def test_constraint_check_is_safe(pending, saved, new_len, kv_free):
    """Whenever Algorithm 2 admits, the admitted prefill queue fits the
    TTFT budget, decode slack covers it, and memory suffices."""
    slo = SLO(ttft=1.0, tpot=0.1)
    status = InstanceStatus(
        iid=0, phase="prefill", pending_prefill_lens=pending,
        pending_prefill_tokens=sum(pending), num_decoding=len(saved),
        saved_tpots=saved, kv_tokens_used=100_000 - kv_free,
        kv_tokens_capacity=100_000, last_switch_time=0.0,
        decode_iter_time_plus_one=0.02)
    req = Request(rid=1, arrival_time=0.0, prompt_len=new_len, output_len=5)
    ok = check_constraints(status, req, slo, PRED, 0.0)
    t_total = PRED(new_len) + sum(PRED(n) for n in pending)
    if ok:
        assert t_total <= slo.ttft + 1e-9
        if saved:
            assert np.mean(saved) >= t_total - 1e-9
        assert 2 * new_len <= kv_free
    # conservative admission implies plain admission
    ok_cons = check_constraints(status, req, slo, PRED, 0.0,
                                conservative=True)
    if ok_cons:
        assert ok


# --------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(
    arrivals=st.lists(
        st.tuples(st.floats(0.0, 5.0), st.integers(1, 500),
                  st.integers(1, 20)),
        min_size=1, max_size=30),
)
def test_instance_conservation_and_monotonicity(arrivals):
    """Every admitted request finishes exactly once with exactly
    output_len tokens; event times are monotone per request."""
    inst = Instance(0, Exec(), kv_capacity_tokens=10**9)
    reqs = [Request(rid=i, arrival_time=t, prompt_len=p, output_len=o)
            for i, (t, p, o) in enumerate(arrivals)]
    now = 0.0
    idx = 0
    reqs.sort(key=lambda r: r.arrival_time)
    finished = []
    for _ in range(100_000):
        while idx < len(reqs) and reqs[idx].arrival_time <= now:
            inst.admit(reqs[idx], now)
            idx += 1
        kind, dur, batch = inst.next_slot(now)
        if kind == "idle":
            if idx >= len(reqs):
                break
            now = reqs[idx].arrival_time
            continue
        now += dur
        finished.extend(inst.complete_slot(kind, batch, now))
    assert len(finished) == len(reqs)
    assert sorted(r.rid for r in finished) == sorted(r.rid for r in reqs)
    for r in finished:
        assert r.tokens_generated == r.output_len
        assert r.first_token_time >= r.arrival_time
        assert r.finish_time >= r.first_token_time


# --------------------------------------------------------------------- #
@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.booleans(), min_size=1, max_size=60),
       n_l=st.integers(1, 4), n_u_extra=st.integers(0, 6))
def test_mitosis_invariants(ops, n_l, n_u_extra):
    """Under any add/remove sequence: macro sizes stay within [1, N_u],
    instance count is conserved, and at most two macros are non-full."""
    n_u = n_l + n_u_extra
    s = OverallScheduler(SLO(1.0, 0.1), PRED, n_lower=n_l, n_upper=n_u)
    count = 0
    nid = 0
    for add in ops:
        if add or count == 0:
            inst = Instance(nid, Exec(), kv_capacity_tokens=1000)
            register_instance(inst)
            s.add_instance(inst)
            nid += 1
            count += 1
        else:
            if s.remove_instance() is not None:
                count -= 1
    assert s.total_instances == count
    for m in s.macros:
        assert 1 <= m.size <= n_u


# --------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(
    lens=st.lists(st.integers(1, 4096), min_size=1, max_size=16),
    batch=st.integers(1, 256),
)
def test_cost_model_positive_and_monotone(lens, batch):
    cm = InstanceCostModel(cfg=get_config("llama-30b"), hw=GPU_L20, tp=4)
    t = cm.prefill_time(lens)
    assert t > 0 and math.isfinite(t)
    assert cm.prefill_time(lens + [128]) > t        # more work, more time
    ctxs = [100] * batch
    td = cm.decode_time(batch, ctxs)
    assert td > 0
    assert cm.decode_time(batch, [c * 2 for c in ctxs]) >= td
