"""Unit layer for the transport/network plane (PR 7).

The contract under test: with no ``NetworkModel`` attached the
``Transport`` is *ideal* and byte-identical to the historic direct
``engine.push(link.transfer(...), ...)`` path; with one attached, every
loss draw / backoff jitter is a pure function of (schedule seed, message
id, attempt), so two runs over the same seed produce identical transport
logs no matter what else the heap interleaves.
"""
import heapq
import itertools

import pytest

from repro.core.transport import (CTRL, POOL, CircuitBreaker, Transport,
                                  TransportConfig)
from repro.faults.network import NETWORK_KINDS, NetworkModel
from repro.simulator.engine import Link


class _Engine:
    """Minimal deterministic event heap standing in for the simulation
    engine (push / push_call / drain)."""

    def __init__(self):
        self.now = 0.0
        self._heap = []
        self._seq = itertools.count()

    def push(self, t, fn):
        heapq.heappush(self._heap, (t, next(self._seq), fn, ()))

    def push_call(self, t, fn, *args):
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def drain(self):
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = t
            fn(*args)


def _lossy(seed=7, p=1.0):
    net = NetworkModel(seed)
    if p > 0:
        net.apply("netloss", p)
    return net


# --------------------------------------------------------------------- #
# circuit breaker
# --------------------------------------------------------------------- #
def test_breaker_opens_on_threshold_and_half_opens_after_cooldown():
    br = CircuitBreaker(threshold=3, cooldown=4.0)
    assert br.allow(0.0)
    assert not br.record_fail(0.0)
    assert not br.record_fail(1.0)
    assert br.record_fail(2.0)          # third consecutive failure opens
    assert br.opens == 1
    assert not br.allow(5.9)            # open for the cooldown
    assert br.allow(6.0)                # half-open: next call probes
    assert br.record_fail(6.0) is False  # counter restarted at open
    br.record_ok()
    assert br.fails == 0 and br.allow(6.0)


def test_breaker_ok_resets_consecutive_count():
    br = CircuitBreaker(threshold=2, cooldown=1.0)
    br.record_fail(0.0)
    br.record_ok()
    assert not br.record_fail(0.5)      # streak restarted, not cumulative
    assert br.record_fail(0.6)


# --------------------------------------------------------------------- #
# ideal path: bit-identical to the pre-transport wiring
# --------------------------------------------------------------------- #
def test_clean_transfer_matches_direct_link_push():
    link_a = Link("nic", bandwidth=1e9, latency=2e-3)
    link_b = Link("nic", bandwidth=1e9, latency=2e-3)
    eng = _Engine()
    tr = Transport()
    got = []
    for i, nb in enumerate([1e6, 5e5, 2e6]):
        tr.transfer(eng, 0, 1, nb, 0.1 * i,
                    deliver=lambda: got.append(eng.now),
                    on_lost=lambda: got.append(None), link=link_a)
    eng.drain()
    want = [link_b.transfer(nb, 0.1 * i)
            for i, nb in enumerate([1e6, 5e5, 2e6])]
    assert got == want
    # the ideal path keeps zero accounting: no network, no message ids
    assert tr.summary()["sent"] == 0 and tr.log == []


def test_clean_plane_is_free_for_rpc_snapshot_and_reachability():
    tr = Transport()
    assert tr.try_rpc(1.0, CTRL, 3) is True
    assert tr.snapshot_channel(1.0) == ("ok", 0.0)
    insts = [object(), object()]
    assert tr.filter_reachable(insts, 1.0) is insts   # same list object
    assert tr.instance_reachable(99, 0.0)
    s = tr.summary()
    assert s.pop("links") == {}   # no degraded traffic -> no link rows
    assert all(v == 0 for v in s.values())


# --------------------------------------------------------------------- #
# degraded path: timeout/retry/backoff + loss accounting
# --------------------------------------------------------------------- #
def test_total_loss_exhausts_retry_budget_then_reports_lost():
    cfg = TransportConfig(retries=3)
    tr = Transport(cfg)
    tr.attach_network(_lossy(p=1.0))
    eng = _Engine()
    link = Link("nic", bandwidth=1e9, latency=1e-3)
    fate = []
    tr.transfer(eng, 0, 1, 1e6, 0.0, deliver=lambda: fate.append("ok"),
                on_lost=lambda: fate.append("lost"), link=link)
    eng.drain()
    assert fate == ["lost"]             # on_lost exactly once, no deliver
    s = tr.summary()
    assert s["sent"] == 1 and s["lost"] == 1 and s["delivered"] == 0
    assert s["retries"] == cfg.retries
    assert s["timeouts"] <= cfg.retries + 1
    (entry,) = [e for e in tr.log if e["outcome"] == "lost"]
    assert entry["attempts"] <= cfg.retries + 1
    # each in-flight loss is noticed only at the per-call timeout
    nominal = link.latency + 1e6 / link.bandwidth
    timeout = max(cfg.min_timeout, cfg.timeout_factor * nominal)
    assert entry["t1"] >= entry["t0"] + timeout


def test_degraded_delivery_applies_degrade_factor_and_extra_latency():
    net = NetworkModel(3)
    net.apply("netdegrade", 4.0)
    net.apply("netdelay", 0.25)
    tr = Transport()
    tr.attach_network(net)
    eng = _Engine()
    link = Link("nic", bandwidth=1e9, latency=1e-3)
    got = []
    tr.transfer(eng, 0, 1, 1e6, 0.0, deliver=lambda: got.append(eng.now),
                on_lost=lambda: got.append(None), link=link)
    eng.drain()
    want = Link("nic", 1e9, 1e-3).transfer(
        1e6, 0.0, factor=4.0, extra_latency=0.25)
    assert got == [want]
    assert tr.summary()["delivered"] == 1


def test_transport_log_is_bit_identical_across_identical_runs():
    def one_run():
        tr = Transport(TransportConfig(retries=2))
        tr.attach_network(_lossy(seed=1234, p=0.5))
        eng = _Engine()
        link = Link("nic", bandwidth=1e8, latency=1e-3)
        for i in range(40):
            tr.transfer(eng, i % 3, (i + 1) % 3, 1e5 * (1 + i % 7),
                        0.05 * i, deliver=lambda: None,
                        on_lost=lambda: None, link=link)
        eng.drain()
        return tr.log, tr.summary()
    a_log, a_sum = one_run()
    b_log, b_sum = one_run()
    assert a_log == b_log
    assert a_sum == b_sum
    assert a_sum["delivered"] + a_sum["lost"] == a_sum["sent"] == 40


def test_partitioned_endpoint_drops_messages_and_reads_unreachable():
    net = NetworkModel(5)
    tr = Transport(TransportConfig(retries=0))
    tr.attach_network(net)
    net.begin_partition(2)
    assert not tr.instance_reachable(2, 0.0)
    assert tr.instance_reachable(1, 0.0)
    eng = _Engine()
    fate = []
    tr.transfer(eng, 0, 2, 1e5, 0.0, deliver=lambda: fate.append("ok"),
                on_lost=lambda: fate.append("lost"),
                link=Link("nic", 1e9))
    eng.drain()
    assert fate == ["lost"]
    assert tr.try_rpc(0.0, CTRL, 2) is False
    net.end_partition(2)
    assert tr.instance_reachable(2, 100.0)
    assert tr.try_rpc(100.0, CTRL, 2) is True


def test_breaker_marks_destination_unreachable_until_cooldown():
    cfg = TransportConfig(retries=0, breaker_threshold=2,
                          breaker_cooldown=4.0)
    tr = Transport(cfg)
    tr.attach_network(_lossy(p=1.0))
    eng = _Engine()
    link = Link("nic", bandwidth=1e9, latency=1e-3)
    for i in range(3):
        tr.transfer(eng, 0, 1, 1e5, float(i), deliver=lambda: None,
                    on_lost=lambda: None, link=link)
    eng.drain()
    s = tr.summary()
    assert s["breaker_opens"] >= 1
    t_open = tr._dst_open[1]
    assert not tr.instance_reachable(1, t_open - 1e-9)
    assert tr.instance_reachable(1, t_open)
    # fast-fail path was exercised for sends into the open circuit
    assert s["breaker_fastfails"] >= 1


def test_rpc_retry_budget_and_accounting():
    tr = Transport(TransportConfig(retries=2))
    tr.attach_network(_lossy(seed=99, p=1.0))
    assert tr.try_rpc(0.0, CTRL, 1) is False
    s = tr.summary()
    assert s["rpc_calls"] == 1 and s["rpc_failures"] == 1
    assert s["rpc_retries"] == 2        # never exceeds the budget
    ok = Transport(TransportConfig(retries=2))
    ok.attach_network(_lossy(seed=99, p=0.0))
    assert ok.try_rpc(0.0, CTRL, 1) is True
    assert ok.summary()["rpc_retries"] == 0


def test_snapshot_channel_fates():
    delayed = NetworkModel(11)
    delayed.apply("netdelay", 0.3)
    tr = Transport()
    tr.attach_network(delayed)
    fate, d = tr.snapshot_channel(2.0)
    assert fate == "delay" and d == pytest.approx(0.3)
    assert tr.summary()["snapshots_delayed"] == 1
    tr2 = Transport()
    tr2.attach_network(_lossy(p=1.0))
    assert tr2.snapshot_channel(2.0) == ("drop", 0.0)
    assert tr2.summary()["snapshots_dropped"] == 1


# --------------------------------------------------------------------- #
# the network model itself
# --------------------------------------------------------------------- #
def test_network_model_composes_and_reverts_episodes():
    net = NetworkModel(1)
    net.apply("netdelay", 0.1)
    net.apply("netdelay", 0.2)
    assert net.delay() == pytest.approx(0.3)
    net.apply("netdegrade", 2.0)
    net.apply("netdegrade", 3.0)
    assert net.degrade() == pytest.approx(6.0)
    net.apply("netloss", 0.5)
    net.apply("netloss", 0.5)
    assert net.loss() == pytest.approx(0.75)   # 1 - (1-p)^2
    net.revert("netdelay", 0.2)
    net.revert("netdegrade", 3.0)
    net.revert("netloss", 0.5)
    assert net.delay() == pytest.approx(0.1)
    assert net.degrade() == pytest.approx(2.0)
    assert net.loss() == pytest.approx(0.5)
    with pytest.raises(KeyError):
        net.apply("crash", 1.0)


def test_network_draws_are_seeded_pure_functions():
    a, b = NetworkModel(42), NetworkModel(42)
    keys = [("loss", m, k) for m in range(20) for k in range(3)]
    va = [a.draw(*key) for key in keys]
    vb = [b.draw(*key) for key in keys]
    assert va == vb
    assert all(0.0 <= v < 1.0 for v in va)
    assert len(set(va)) > 30            # not degenerate
    c = NetworkModel(43)
    assert [c.draw(*k) for k in keys] != va


def test_network_kinds_cover_the_grammar():
    assert set(NETWORK_KINDS) == {
        "netdelay", "netloss", "netdegrade", "partition"}
    assert POOL != CTRL and POOL < 0 and CTRL < 0
