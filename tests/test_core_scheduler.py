"""Unit tests for the PaDG core: Algorithms 1+2, temporal disaggregation,
rolling activation, and the phase-switch bookkeeping."""
import pytest

from repro.core.constraints import check_constraints
from repro.core.instance import Instance, InstanceStatus
from repro.core.macro import MacroInstance
from repro.core.request import Request, RequestState
from repro.core.slo import SLO


class FixedExecutor:
    """Deterministic executor: prefill 10ms per 100 tokens, decode 20ms."""

    def prefill_time(self, lens):
        return 1e-4 * sum(lens)

    def decode_time(self, batch, ctxs):
        return 0.02


def make_instance(iid=0, cap=100_000):
    return Instance(iid, FixedExecutor(), kv_capacity_tokens=cap)


def req(rid, t=0.0, plen=100, out=10):
    return Request(rid=rid, arrival_time=t, prompt_len=plen, output_len=out)


SLO_T = SLO(ttft=1.0, tpot=0.1)
PREDICT = FixedExecutor().prefill_time


def _pred(n):
    return PREDICT([n])


# --------------------------------------------------------------------- #
def test_instance_prefill_priority_and_lifecycle():
    inst = make_instance()
    r = req(1, plen=200, out=3)
    inst.admit(r, 0.0)
    kind, dur, batch = inst.next_slot(0.0)
    assert kind == "prefill" and batch == [r]
    assert dur == pytest.approx(0.02)
    inst.complete_slot(kind, batch, 0.02)
    assert r.state == RequestState.DECODING
    assert r.first_token_time == pytest.approx(0.02)
    # two decode iterations finish the request (out=3: 1 from prefill)
    for i in range(2):
        kind, dur, batch = inst.next_slot(0.02)
        assert kind == "decode"
        inst.complete_slot(kind, batch, 0.02 + (i + 1) * dur)
    assert r.state == RequestState.FINISHED
    assert r.tokens_generated == 3


def test_temporal_disaggregation_phase_switches():
    """Admitting prefills during decode switches the phase at the slot
    boundary, not mid-slot."""
    inst = make_instance()
    a = req(1, plen=100, out=50)
    inst.admit(a, 0.0)
    k, d, b = inst.next_slot(0.0)
    inst.complete_slot(k, b, d)
    k2, _, b2 = inst.next_slot(d)
    assert k2 == "decode"
    # new admission -> next slot is prefill (prefill priority)
    b_req = req(2, plen=100)
    inst.admit(b_req, d)
    inst.complete_slot(k2, b2, d + 0.02)
    k3, _, b3 = inst.next_slot(d + 0.02)
    assert k3 == "prefill" and b3 == [b_req]


# --------------------------------------------------------------------- #
def test_constraint1_ttft_rejects_when_queue_too_long():
    inst = make_instance()
    # 9500 tokens of pending prefill ~ 0.95s; + 1000 more breaks 1s SLO
    for i in range(5):
        inst.admit(req(i, plen=1900), 0.0)
    status = inst.status(0.0, SLO_T.tpot)
    assert not check_constraints(status, req(99, plen=1000), SLO_T,
                                 _pred, 0.0)
    assert check_constraints(status, req(99, plen=100), SLO_T, _pred, 0.0)


def test_constraint2_tpot_saved_slack():
    inst = make_instance()
    r = req(1, plen=100, out=500)
    inst.admit(r, 0.0)
    k, d, b = inst.next_slot(0.0)
    inst.complete_slot(k, b, 0.01)
    # r decoding since t=0.01 with 1 token: at t=0.02 saved = 1*0.1-0.01
    status = inst.status(0.02, SLO_T.tpot)
    assert status.saved_tpots[0] == pytest.approx(0.09)
    # inserting 0.5s of prefill work would violate TPOT
    assert not check_constraints(status, req(2, plen=5000), SLO_T,
                                 _pred, 0.02)
    # tiny prefill is fine
    assert check_constraints(status, req(2, plen=100), SLO_T, _pred, 0.02)
    # after many on-time tokens the slack has grown; big prefill now fits
    r.tokens_generated = 40
    status = inst.status(0.5, SLO_T.tpot)
    assert check_constraints(status, req(2, plen=5000), SLO_T, _pred, 0.5)


def test_constraint3_memory():
    inst = make_instance(cap=1000)
    status = inst.status(0.0, SLO_T.tpot)
    assert not check_constraints(status, req(1, plen=600), SLO_T, _pred, 0.0)
    assert check_constraints(status, req(1, plen=400), SLO_T, _pred, 0.0)


# --------------------------------------------------------------------- #
def test_rolling_activation_cycles_instances():
    """When the sticky instance exhausts its TTFT budget, routing moves to
    the next instance cyclically (rolling activation)."""
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    # each request ~0.4s of prefill: two fit per instance within 1s TTFT
    routed = []
    for i in range(6):
        inst = macro.route(req(i, plen=4000), 0.0)
        assert inst is not None
        routed.append(inst.iid)
    assert routed == [0, 0, 1, 1, 2, 2]
    # all instances saturated now
    assert macro.route(req(99, plen=4000), 0.0) is None


def test_sticky_routing_prefers_last_instance():
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    a = macro.route(req(1, plen=100), 0.0)
    b = macro.route(req(2, plen=100), 0.0)
    assert a.iid == b.iid  # Algorithm 1 line 2: same instance first


def test_route_moves_sticky_pointer_to_admitting_instance():
    """After a cyclic hand-off the pointer stays on the new instance, so
    the next request does NOT re-probe the saturated one."""
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    for i in range(2):                       # saturate instance 0 (~0.8s)
        assert macro.route(req(i, plen=4000), 0.0).iid == 0
    moved = macro.route(req(10, plen=4000), 0.0)
    assert moved.iid == 1
    assert macro._active_idx == 1
    again = macro.route(req(11, plen=100), 0.0)
    assert again.iid == 1                    # sticky on the new instance


def test_route_wraps_cyclically_from_nonzero_pointer():
    """The probe order is (active, active+1, ...) mod n — instance 0 is
    still reachable once the pointer has moved past it."""
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    macro._active_idx = 2
    for i in range(2):                       # saturate instance 2
        assert macro.route(req(i, plen=4000), 0.0).iid == 2
    wrapped = macro.route(req(10, plen=4000), 0.0)
    assert wrapped.iid == 0                  # (2+1) % 3
    assert macro._active_idx == 0


def test_remove_instance_keeps_active_idx_in_range():
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    macro._active_idx = 2
    removed = macro.remove_instance()
    assert removed is not None
    assert 0 <= macro._active_idx < macro.size
    assert macro.route(req(1, plen=100), 0.0) is not None
    # shrink to empty: routing degrades gracefully, no IndexError
    macro.remove_instance()
    macro.remove_instance()
    assert macro.size == 0
    assert macro.remove_instance() is None
    assert macro.route(req(2, plen=100), 0.0) is None


def test_remove_instance_picks_emptiest():
    instances = [make_instance(i) for i in range(3)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    instances[0].admit(req(1, plen=500), 0.0)
    instances[2].admit(req(2, plen=300), 0.0)
    removed = macro.remove_instance()
    assert removed.iid == 1                  # zero KV tokens in flight


def test_route_forced_picks_max_free_kv():
    instances = [make_instance(0, cap=1_000), make_instance(1, cap=5_000),
                 make_instance(2, cap=2_000)]
    macro = MacroInstance(0, instances, SLO_T, _pred)
    # load the largest instance so free KV (capacity - used), not raw
    # capacity, decides: free = [1000, 5000-4200=800, 2000]
    instances[1].admit(req(1, plen=4200), 0.0)
    forced = macro.route_forced(req(9, plen=100), 0.0)
    assert forced.iid == 2
    assert macro.rejected == 1
    assert macro._active_idx == 2            # forced admission re-sticks
