"""End-to-end simulator behaviour: all five systems serve workloads to
completion; EcoServe (PaDG) sustains higher goodput than the baselines on
the commodity-interconnect cluster (the paper's headline claim)."""
import functools

import pytest

from repro.baselines import (DistServeSystem, MoonCakeSystem, SarathiSystem,
                             VLLMSystem)
from repro.configs import get_config
from repro.core.padg_system import EcoServeSystem
from repro.core.slo import DATASET_SLOS, attainment
from repro.simulator.cost_model import GPU_L20, InstanceCostModel
from repro.simulator.engine import SimulationEngine
from repro.simulator.metrics import run_once
from repro.simulator.workload import WORKLOADS, WorkloadGen

CFG = get_config("llama-30b")
COST = InstanceCostModel(cfg=CFG, hw=GPU_L20, tp=4)
SLO = DATASET_SLOS["sharegpt"]
N_INST = 8   # 32 GPUs / TP4, the paper's L20 setup


def _system(name):
    if name == "ecoserve":
        return EcoServeSystem(COST, N_INST, SLO, n_lower=4, n_upper=16)
    if name == "vllm":
        return VLLMSystem(COST, N_INST)
    if name == "sarathi":
        return SarathiSystem(COST, N_INST)
    if name == "distserve":
        return DistServeSystem(COST, N_INST, prefill_ratio=0.25)
    if name == "mooncake":
        return MoonCakeSystem(COST, N_INST, prefill_ratio=0.25)
    raise KeyError(name)


@pytest.mark.parametrize("name",
                         ["ecoserve", "vllm", "sarathi", "distserve",
                          "mooncake"])
def test_system_completes_all_requests(name):
    m = run_once(functools.partial(_system, name), WORKLOADS["sharegpt"],
                 rate=1.0, slo=SLO, duration=60.0, warmup=5.0)
    assert m["completion"] > 0.95, m
    assert m["finished"] > 20


def test_requests_complete_exactly_once_and_monotonic_times():
    system = _system("ecoserve")
    gen = WorkloadGen(WORKLOADS["sharegpt"], rate=2.0, seed=1)
    reqs = gen.generate(60.0)
    eng = SimulationEngine(system)
    done = eng.run(reqs, horizon=200.0)
    rids = [r.rid for r in done]
    assert len(rids) == len(set(rids))
    for r in done:
        assert r.first_token_time >= r.arrival_time
        assert r.finish_time >= r.first_token_time
        assert r.tokens_generated == r.output_len


def test_padg_beats_nodg_at_high_load():
    """Above vLLM's P90 capacity (~31 req/s in the Fig. 8 run), EcoServe
    keeps a higher share of requests within SLO."""
    rate = 34.0
    eco = run_once(functools.partial(_system, "ecoserve"),
                   WORKLOADS["sharegpt"], rate, SLO, duration=60.0)
    vllm = run_once(functools.partial(_system, "vllm"),
                    WORKLOADS["sharegpt"], rate, SLO, duration=60.0)
    assert eco["attainment"] > vllm["attainment"], (eco, vllm)


def test_fudg_suffers_on_commodity_ethernet():
    """MoonCake over 10 Gb Ethernet with an MHA model (huge KV) is
    transfer-bound at moderate load (paper Fig. 8, Llama-30B)."""
    rate = 16.0
    eco = run_once(functools.partial(_system, "ecoserve"),
                   WORKLOADS["sharegpt"], rate, SLO, duration=60.0)
    mc = run_once(functools.partial(_system, "mooncake"),
                  WORKLOADS["sharegpt"], rate, SLO, duration=60.0)
    # FuDG fails by *not finishing* requests (transfer queue grows without
    # bound): compare goodput-style attainment x completion
    eco_eff = eco["attainment"] * min(1.0, eco["completion"])
    mc_eff = mc["attainment"] * min(1.0, mc["completion"])
    assert eco_eff > mc_eff + 0.3, (eco, mc)
