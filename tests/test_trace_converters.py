"""Property tests for the real-trace ingestion pipeline
(``repro.traces``): CSV converters, transforms, and the JSONL bridge
into ``TraceReplay``.

Contracts under test:

* conversion -> ``records_to_jsonl`` -> ``TraceReplay.from_jsonl``
  preserves ordering and every field (times, lengths, tags) exactly;
* ``normalize_rate`` hits the target mean rate within float tolerance
  and is a pure time dilation (lengths, tags, and order untouched);
* converters sort + rebase arrivals, skip malformed/aborted rows, and
  clamp zero generations;
* ``downsample`` is deterministic per seed and order-preserving.

Hypothesis drives the record-level properties (fixed-seed profile from
``tests/conftest.py``); seeded fallbacks keep a bare interpreter green.
"""
import random

import pytest

from repro.simulator.scenarios import TraceReplay, _parse_trace
from repro.traces import (clip_horizon, convert_azure, convert_burstgpt,
                          downsample, load_fixture, normalize_rate,
                          records_to_jsonl, rescale_time, trace_stats)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


def _records(specs):
    """specs: [(dt_gap, prompt, out, tag)] -> converter-shaped records
    (cumulative arrival times so they are sorted and start at 0)."""
    out, t = [], 0.0
    for gap, p, o, tag in specs:
        rec = {"arrival_time": t, "prompt_len": p, "output_len": o}
        if tag:
            rec["slo_class"] = tag
        out.append(rec)
        t += gap
    return out


def check_jsonl_roundtrip_lossless(records) -> None:
    """records -> JSONL -> TraceReplay must preserve order + fields."""
    replay = TraceReplay("rt", _parse_trace(records_to_jsonl(records)))
    reqs = replay.generate()
    assert len(reqs) == len(records)
    for i, (rec, req) in enumerate(zip(records, reqs)):
        assert req.rid == i
        assert req.arrival_time == rec["arrival_time"]
        assert req.prompt_len == rec["prompt_len"]
        assert req.output_len == rec["output_len"]
        assert req.slo_class == rec.get("slo_class", "default")
        assert req.model == rec.get("model")


def check_rate_normalization(records, target) -> None:
    normed = normalize_rate(records, target)
    assert len(normed) == len(records)
    # pure time dilation: lengths, tags, and relative order untouched
    assert [(r["prompt_len"], r["output_len"], r.get("slo_class"))
            for r in normed] == \
        [(r["prompt_len"], r["output_len"], r.get("slo_class"))
         for r in records]
    times = [r["arrival_time"] for r in normed]
    assert times == sorted(times)
    assert trace_stats(normed)["mean_rate"] == \
        pytest.approx(target, rel=1e-9)


# --------------------------------------------------------------------- #
# hypothesis drives
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    SPEC = st.tuples(
        st.floats(min_value=1e-3, max_value=60.0, allow_nan=False),
        st.integers(1, 4096),
        st.integers(1, 2048),
        st.sampled_from((None, "alpaca", "sharegpt", "longbench")))

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(specs=st.lists(SPEC, min_size=0, max_size=40))
    def test_jsonl_roundtrip_lossless_property(specs):
        check_jsonl_roundtrip_lossless(_records(specs))

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(specs=st.lists(SPEC, min_size=2, max_size=40),
           target=st.floats(min_value=0.05, max_value=64.0))
    def test_rate_normalization_hits_target_property(specs, target):
        check_rate_normalization(_records(specs), target)


# --------------------------------------------------------------------- #
# seeded fallbacks
# --------------------------------------------------------------------- #
def test_jsonl_roundtrip_lossless_seeded():
    rng = random.Random(13)
    for _ in range(15):
        specs = [(rng.uniform(1e-3, 60.0), rng.randint(1, 4096),
                  rng.randint(1, 2048),
                  rng.choice((None, "alpaca", "longbench")))
                 for _ in range(rng.randint(0, 40))]
        check_jsonl_roundtrip_lossless(_records(specs))


def test_rate_normalization_hits_target_seeded():
    rng = random.Random(29)
    for _ in range(15):
        specs = [(rng.uniform(1e-3, 60.0), rng.randint(1, 4096),
                  rng.randint(1, 2048), None)
                 for _ in range(rng.randint(2, 40))]
        check_rate_normalization(_records(specs),
                                 rng.uniform(0.05, 64.0))


# --------------------------------------------------------------------- #
# converter schemas
# --------------------------------------------------------------------- #
AZURE_CSV = """TIMESTAMP,ContextTokens,GeneratedTokens
2023-11-16 18:17:05.5000000,120,30
2023-11-16 18:17:03.2910407,4402,13
2023-11-16 18:17:04.0000000,256,0
not-a-timestamp,9,9
2023-11-16 18:17:06.1234567,0,50
""".splitlines()

BURSTGPT_CSV = """Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type
10,GPT-4,900,250,1150,Conversation log
2,ChatGPT,470,180,650,Conversation log
5,ChatGPT,30,0,30,API log
bogus,ChatGPT,1,1,2,Conversation log
""".splitlines()


def test_azure_converter_sorts_rebase_and_skips_bad_rows():
    recs = convert_azure(AZURE_CSV)
    # malformed timestamp and zero-context rows dropped
    assert len(recs) == 3
    assert recs[0]["arrival_time"] == 0.0
    assert [r["prompt_len"] for r in recs] == [4402, 256, 120]
    # sub-second spacing survives (7th fractional digit truncated)
    assert recs[1]["arrival_time"] == pytest.approx(0.708960, abs=1e-5)
    assert recs[2]["arrival_time"] == pytest.approx(2.208960, abs=1e-5)
    # GeneratedTokens == 0 clamps to 1 (the simulator emits >= 1 token)
    assert recs[1]["output_len"] == 1
    assert all("slo_class" not in r for r in recs)


def test_burstgpt_converter_tags_by_model_when_asked():
    recs = convert_burstgpt(BURSTGPT_CSV, class_by_model=True)
    assert len(recs) == 3
    assert [r["arrival_time"] for r in recs] == [0.0, 3.0, 8.0]
    assert [r["slo_class"] for r in recs] == \
        ["sharegpt", "sharegpt", "longbench"]
    assert recs[1]["output_len"] == 1          # zero response clamped
    untagged = convert_burstgpt(BURSTGPT_CSV)
    assert all("slo_class" not in r for r in untagged)
    pinned = convert_burstgpt(BURSTGPT_CSV, slo_class="alpaca")
    assert {r["slo_class"] for r in pinned} == {"alpaca"}


def test_burstgpt_converter_preserves_raw_model_names():
    recs = convert_burstgpt(BURSTGPT_CSV)
    assert [r["model"] for r in recs] == ["ChatGPT", "ChatGPT", "GPT-4"]
    # the raw name rides alongside (not instead of) the class mapping
    tagged = convert_burstgpt(BURSTGPT_CSV, class_by_model=True)
    assert [(r["slo_class"], r["model"]) for r in tagged] == \
        [("sharegpt", "ChatGPT"), ("sharegpt", "ChatGPT"),
         ("longbench", "GPT-4")]
    # and survives JSONL -> TraceReplay -> Request for the fleet router
    replay = TraceReplay("m", _parse_trace(records_to_jsonl(recs)))
    assert [q.model for q in replay.generate()] == \
        ["ChatGPT", "ChatGPT", "GPT-4"]


def test_legacy_records_round_trip_byte_identically():
    # pre-fleet records (no "model") must serialize to the exact legacy
    # schema: no new key may appear on the wire
    legacy = _records([(1.0, 10, 5, "alpaca"), (2.0, 20, 6, None)])
    lines = records_to_jsonl(legacy)
    assert all("model" not in line for line in lines)
    assert records_to_jsonl(_parse_and_redump(lines)) == lines


def _parse_and_redump(lines):
    """JSONL -> parsed tuples -> converter-shaped dicts (the round-trip
    a re-export of a downloaded trace performs)."""
    out = []
    for t, p, o, cls, model in _parse_trace(lines):
        rec = {"arrival_time": t, "prompt_len": p, "output_len": o}
        if cls != "default":
            rec["slo_class"] = cls
        if model is not None:
            rec["model"] = model
        out.append(rec)
    return out


def test_converters_reject_wrong_schema():
    with pytest.raises(ValueError, match="missing column"):
        convert_azure(BURSTGPT_CSV)
    with pytest.raises(ValueError, match="missing column"):
        convert_burstgpt(AZURE_CSV)


# --------------------------------------------------------------------- #
# transforms
# --------------------------------------------------------------------- #
def test_rescale_and_clip_compose():
    recs = _records([(1.0, 10, 10, None)] * 10)
    fast = rescale_time(recs, 0.5)
    assert fast[-1]["arrival_time"] == pytest.approx(
        recs[-1]["arrival_time"] * 0.5)
    clipped = clip_horizon(fast, 2.0)
    assert all(r["arrival_time"] < 2.0 for r in clipped)
    assert len(clipped) < len(fast)
    # purity: inputs untouched
    assert recs[-1]["arrival_time"] == pytest.approx(9.0)


def test_downsample_is_deterministic_and_order_preserving():
    recs = _records([(0.5, i + 1, 5, None) for i in range(100)])
    a = downsample(recs, 0.3, seed=7)
    b = downsample(recs, 0.3, seed=7)
    assert a == b
    assert len(a) == 30
    times = [r["arrival_time"] for r in a]
    assert times == sorted(times)
    c = downsample(recs, 0.3, seed=8)
    assert c != a                    # a different seed moves the sample
    with pytest.raises(ValueError, match="keep_fraction"):
        downsample(recs, 0.0)


# --------------------------------------------------------------------- #
# checked-in fixtures stay bursty and replayable
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["azure", "burstgpt"])
def test_fixture_excerpts_are_bursty_and_replayable(name):
    recs = load_fixture(name)
    stats = trace_stats(recs)
    assert stats["n_requests"] >= 100
    # the excerpts exist to exercise non-stationarity: CV(gaps) must
    # stay well above the Poisson baseline of ~1
    assert stats["burstiness_cv"] > 1.2, stats
    check_jsonl_roundtrip_lossless(recs)
    check_rate_normalization(recs, 8.0)


def test_burstgpt_fixture_supports_model_class_tags():
    recs = load_fixture("burstgpt", class_by_model=True)
    assert {r["slo_class"] for r in recs} == {"sharegpt", "longbench"}
